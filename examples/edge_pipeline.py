"""Pipeline-parallel edge training demo: DynaComm-scheduled activations.

The tentpole of ``repro.pipeline``, end to end: a reduced transformer is
split into ``--stages`` balanced stages (min-max DP over profiled
fc + bc), micro-batched ``--microbatches`` ways under a GPipe or 1F1B
schedule, and trained with every inter-stage activation / activation-
gradient transfer planned by the *same* DP that schedules the paper's
push/pull traffic — chunks of the boundary tensor play the role of
layers, the receiving stage's compute plays the role of layer compute,
and ``dp_forward``/``dp_backward`` decide which chunks batch into one
message (amortizing Δt) versus segment to overlap with stage compute.

The run prints the stage partition, the per-boundary transfer plans
(segmented vs whole-tensor makespan), the simulated 1F1B timeline with
its bubble fraction, and the boundary-byte ledger.  Losses are
bit-identical to the single-stage execution of the same decomposition
at any stage count — verify with ``--stages 1``.

    PYTHONPATH=src python examples/edge_pipeline.py --steps 10
"""

import argparse

from repro.runtime import (MeasureConfig, NetworkConfig, PipelineConfig,
                           RuntimeConfig, ScheduleConfig, build_runtime)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--schedule", default="1f1b", choices=("gpipe", "1f1b"))
    ap.add_argument("--chunks", type=int, default=4)
    ap.add_argument("--bw-gbps", type=float, default=0.1,
                    help="edge uplink (default: the paper's 100 Mbps)")
    ap.add_argument("--worker-flops", type=float, default=1e10)
    args = ap.parse_args()

    config = RuntimeConfig(
        runtime="pipeline", arch=args.arch, batch=args.batch, seq=args.seq,
        pipeline=PipelineConfig(stages=args.stages,
                                microbatches=args.microbatches,
                                schedule=args.schedule, chunks=args.chunks),
        schedule=ScheduleConfig(
            network=NetworkConfig(bandwidth_gbps=args.bw_gbps)),
        measure=MeasureConfig(compute_flops_per_s=args.worker_flops))
    rt = build_runtime(config)
    tr = rt.trainer

    part = rt.partition
    print(f"arch: {args.arch} (reduced)  stages: {args.stages}  "
          f"micro-batches: {args.microbatches}  schedule: {args.schedule}")
    print(f"partition (by profiled fc+bc): "
          f"{[list(s) for s in part.segments]}  "
          f"loads: {[round(l, 4) for l in part.loads]}")

    losses = rt.fit(args.steps)
    print(f"\ntrained {len(losses)} steps: first loss {losses[0]:.4f}  "
          f"last loss {losses[-1]:.4f}")

    plans = tr.transfer_plans()
    if plans:
        print(f"\nboundary transfer plans at {args.bw_gbps:g} Gbps "
              f"(chunks={args.chunks}):")
        for p in plans:
            print(f"  boundary {p.boundary}: "
                  f"{len(p.decision[0])} fwd / {len(p.decision[1])} bwd "
                  f"segments  "
                  f"segmented {p.fwd_time + p.bwd_time:.4f}s vs "
                  f"whole {p.whole_fwd_time + p.whole_bwd_time:.4f}s  "
                  f"speedup {p.speedup:.3f}x")

    tl = tr.timeline()
    if tl is not None:
        print(f"\nsimulated {args.schedule} timeline: "
              f"makespan {tl.makespan * 1e3:.2f} ms  "
              f"bubble {tl.bubble_fraction:.3f}")

    led = rt.ledger
    print(f"\nledger: {led['num_pulls']} pulls "
          f"({led['pull_bytes'] / 1e6:.2f} MB activations) / "
          f"{led['num_pushes']} pushes "
          f"({led['push_bytes'] / 1e6:.2f} MB activation grads)")
    stats = tr.planner.stats if tr.planner is not None else None
    if stats is not None:
        print(f"planner: {stats.solves} solves, {stats.hits} hits "
              f"(homogeneous boundaries collapse to cache hits)")


if __name__ == "__main__":
    main()
