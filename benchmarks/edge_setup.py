"""Shared testbed model for the paper-faithful benchmarks.

Calibrated to the paper's Section V setup: 8 edge workers (4-core Xeon
E3-1220 ≈ 1e11 FLOP/s effective on conv nets), 4 parameter servers on a
10 Gbps cloud uplink (≈1.25 Gbps effective per worker with 8 workers), RTT
≈ 10.3 ms.  With these constants the absolute iteration times land in the
paper's ballpark (e.g. VGG-19 ≈ 7 s/iter ↔ the paper's 4.5 samples/s at
batch 32) — EXPERIMENTS.md §Faithful validates the *relative* claims.
"""

from __future__ import annotations

from repro.core import EdgeNetworkModel, LayerCosts, costs_from_profiles
from repro.models.cnn import PAPER_CNNS

WORKER_FLOPS = 1.0e11           # effective conv FLOP/s per edge worker
SERVER_BW_BPS = 10e9            # nominal cloud-side fabric
NET_EFFICIENCY = 0.4            # TCP/VM goodput factor on the 10 Gbps fabric
BWD_FWD_RATIO = 1.2             # measured MXNet conv bwd/fwd time ratio
DEFAULT_WORKERS = 8


def edge_network(workers: int = DEFAULT_WORKERS,
                 server_bw_bps: float = SERVER_BW_BPS) -> EdgeNetworkModel:
    per_worker = server_bw_bps * NET_EFFICIENCY / max(workers, 1)
    return EdgeNetworkModel(bandwidth_bps=per_worker)


def cnn_costs(model: str, *, batch: int = 32,
              workers: int = DEFAULT_WORKERS) -> LayerCosts:
    from repro.core.profiler import LayerProfile
    profiles = [
        LayerProfile(name=p.name, param_bytes=p.param_bytes,
                     flops_fwd=p.flops_fwd,
                     flops_bwd=BWD_FWD_RATIO * p.flops_fwd)
        for p in PAPER_CNNS[model](batch=batch)
    ]
    return costs_from_profiles(profiles, net=edge_network(workers),
                               compute_flops_per_s=WORKER_FLOPS)
