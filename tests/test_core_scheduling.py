"""Unit + property tests for the DynaComm core scheduling library."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    LayerCosts, backward_time, bruteforce_backward, bruteforce_forward,
    check_partial_orders, dp_backward, dp_forward, evaluate, forward_time,
    ibatch_backward, ibatch_forward, iteration_time, lbl_backward, lbl_forward,
    plan_from_decision, random_costs, schedule, sequential_backward,
    sequential_forward, simulate_iteration,
)
from repro.core.costmodel import (
    backward_segments_from_g, forward_segments_from_p, g_from_backward_segments,
    p_from_forward_segments, validate_backward_segments,
    validate_forward_segments,
)


def make_costs(pt, fc, bc, gt, dt):
    return LayerCosts(pt=np.array(pt, float), fc=np.array(fc, float),
                      bc=np.array(bc, float), gt=np.array(gt, float), dt=dt)


# ---------------------------------------------------------------------------
# decision representations
# ---------------------------------------------------------------------------

class TestDecisions:
    def test_p_roundtrip(self):
        p = (1, 0, 1, 1, 0)
        segs = forward_segments_from_p(p)
        assert segs == ((1, 1), (2, 3), (4, 4), (5, 6))
        assert p_from_forward_segments(segs) == p

    def test_g_roundtrip(self):
        # L = 6, g[l-1] cuts after layer L+1-l going downward
        g = (1, 0, 1, 0, 0)
        segs = backward_segments_from_g(g)
        validate_backward_segments(segs, 6)
        assert segs[0][1] == 6 and segs[-1][0] == 1
        assert g_from_backward_segments(segs) == g

    def test_sequential_lbl_shapes(self):
        assert sequential_forward(5) == ((1, 5),)
        assert lbl_forward(3) == ((1, 1), (2, 2), (3, 3))
        assert lbl_backward(3) == ((3, 3), (2, 2), (1, 1))
        validate_forward_segments(lbl_forward(7), 7)
        validate_backward_segments(lbl_backward(7), 7)

    def test_invalid_segments_raise(self):
        with pytest.raises(ValueError):
            validate_forward_segments(((1, 2), (4, 5)), 5)  # gap
        with pytest.raises(ValueError):
            validate_backward_segments(((1, 3), (4, 5)), 5)  # wrong order


# ---------------------------------------------------------------------------
# f_m cost model — hand-checked examples
# ---------------------------------------------------------------------------

class TestCostModel:
    def test_sequential_closed_form(self):
        c = make_costs([1, 2], [3, 4], [5, 6], [7, 8], dt=0.5)
        # forward: dt + sum(pt) then sum(fc)
        assert forward_time(c, sequential_forward(2)) == pytest.approx(0.5 + 3 + 7)
        # backward: sum(bc) then dt + sum(gt)
        assert backward_time(c, sequential_backward(2)) == pytest.approx(11 + 0.5 + 15)

    def test_lbl_overlap_example(self):
        # pt=[1,1], fc=[10,10]: layer 2's pull fully hides under layer 1's fc
        c = make_costs([1, 1], [10, 10], [1, 1], [1, 1], dt=0.0)
        assert forward_time(c, lbl_forward(2)) == pytest.approx(1 + 10 + 10)
        # sequential pays both pulls up front: 2 + 20
        assert forward_time(c, sequential_forward(2)) == pytest.approx(22)

    def test_dt_penalises_decomposition(self):
        # compute tiny: decomposition only adds dt
        c = make_costs([1, 1, 1], [0, 0, 0], [0, 0, 0], [1, 1, 1], dt=5.0)
        t_seq = forward_time(c, sequential_forward(3))
        t_lbl = forward_time(c, lbl_forward(3))
        assert t_seq == pytest.approx(5 + 3)
        assert t_lbl == pytest.approx(3 * 5 + 3)
        assert t_seq < t_lbl

    def test_backward_pipelining(self):
        # big bc hides gt of earlier segments
        c = make_costs([0, 0], [0, 0], [10, 10], [1, 1], dt=0.0)
        t = backward_time(c, lbl_backward(2))
        # bc2 ends at 10, gt2 ends 11; bc1 ends 20 > 11, gt1 ends 21
        assert t == pytest.approx(21)


# ---------------------------------------------------------------------------
# DP vs brute force — the optimality claim (Section IV-B3)
# ---------------------------------------------------------------------------

costs_strategy = st.integers(min_value=1, max_value=9).flatmap(
    lambda L: st.tuples(
        st.lists(st.floats(0.0, 50.0), min_size=L, max_size=L),
        st.lists(st.floats(0.0, 50.0), min_size=L, max_size=L),
        st.lists(st.floats(0.0, 50.0), min_size=L, max_size=L),
        st.lists(st.floats(0.0, 50.0), min_size=L, max_size=L),
        st.floats(0.0, 20.0),
    )
)


class TestDPOptimality:
    @settings(max_examples=200, deadline=None)
    @given(costs_strategy)
    def test_forward_dp_matches_bruteforce(self, tup):
        pt, fc, bc, gt, dt = tup
        c = make_costs(pt, fc, bc, gt, dt)
        res = dp_forward(c)
        _, best = bruteforce_forward(c)
        assert res.time == pytest.approx(best, rel=1e-9, abs=1e-9)

    @settings(max_examples=200, deadline=None)
    @given(costs_strategy)
    def test_backward_dp_matches_bruteforce(self, tup):
        pt, fc, bc, gt, dt = tup
        c = make_costs(pt, fc, bc, gt, dt)
        res = dp_backward(c)
        _, best = bruteforce_backward(c)
        assert res.time == pytest.approx(best, rel=1e-9, abs=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(costs_strategy)
    def test_dp_never_worse_than_any_baseline(self, tup):
        pt, fc, bc, gt, dt = tup
        c = make_costs(pt, fc, bc, gt, dt)
        L = c.num_layers
        fopt = dp_forward(c).time
        bopt = dp_backward(c).time
        eps = 1e-9
        for segs in (sequential_forward(L), lbl_forward(L), ibatch_forward(c)[0]):
            assert fopt <= forward_time(c, segs) + eps
        for segs in (sequential_backward(L), lbl_backward(L), ibatch_backward(c)[0]):
            assert bopt <= backward_time(c, segs) + eps

    def test_dp_larger_instances_beat_heuristics(self):
        for seed in range(5):
            c = random_costs(40, seed=seed, dt=2e-3)
            fopt = dp_forward(c).time
            assert fopt <= forward_time(c, lbl_forward(40)) + 1e-12
            assert fopt <= forward_time(c, ibatch_forward(c)[0]) + 1e-12

    def test_dp_segments_are_valid_and_match_time(self):
        c = random_costs(25, seed=3, dt=1e-3)
        f = dp_forward(c)
        b = dp_backward(c)
        validate_forward_segments(f.segments, 25)
        validate_backward_segments(b.segments, 25)
        assert forward_time(c, f.segments) == pytest.approx(f.time)
        assert backward_time(c, b.segments) == pytest.approx(b.time)


# ---------------------------------------------------------------------------
# iBatch reproduces its documented pathology
# ---------------------------------------------------------------------------

class TestIBatch:
    def test_valid_decisions(self):
        for seed in range(8):
            c = random_costs(30, seed=seed, dt=5e-3)
            fsegs, _ = ibatch_forward(c)
            bsegs, _ = ibatch_backward(c)
            validate_forward_segments(fsegs, 30)
            validate_backward_segments(bsegs, 30)

    def test_sometimes_worse_than_lbl(self):
        """Paper Fig. 5(c): the greedy can lose to plain layer-by-layer."""
        hits = 0
        for seed in range(60):
            c = random_costs(24, seed=seed, dt=5e-4)
            if ibatch_forward(c)[1] > forward_time(c, lbl_forward(24)) + 1e-12:
                hits += 1
        assert hits > 0, "expected at least one instance where iBatch < LBL"

    def test_single_layer(self):
        c = make_costs([1.0], [1.0], [1.0], [1.0], dt=0.1)
        assert ibatch_forward(c)[0] == ((1, 1),)
        assert ibatch_backward(c)[0] == ((1, 1),)


# ---------------------------------------------------------------------------
# simulator agrees with f_m and satisfies the partial orders
# ---------------------------------------------------------------------------

class TestSimulator:
    @settings(max_examples=60, deadline=None)
    @given(costs_strategy, st.randoms(use_true_random=False))
    def test_simulator_matches_fm(self, tup, rnd):
        pt, fc, bc, gt, dt = tup
        c = make_costs(pt, fc, bc, gt, dt)
        L = c.num_layers
        # random decision
        p = tuple(rnd.randint(0, 1) for _ in range(L - 1))
        g = tuple(rnd.randint(0, 1) for _ in range(L - 1))
        fsegs = forward_segments_from_p(p)
        bsegs = backward_segments_from_g(g)
        tl = simulate_iteration(c, fsegs, bsegs)
        assert tl.forward_time == pytest.approx(forward_time(c, fsegs), abs=1e-9)
        assert tl.backward_time == pytest.approx(backward_time(c, bsegs), abs=1e-9)
        assert tl.total == pytest.approx(iteration_time(c, fsegs, bsegs), abs=1e-9)
        check_partial_orders(tl, L)

    def test_breakdown_accounts_total(self):
        c = random_costs(12, seed=1, dt=1e-3)
        fsegs = dp_forward(c).segments
        bsegs = dp_backward(c).segments
        tl = simulate_iteration(c, fsegs, bsegs)
        for phase in ("forward", "backward"):
            br = tl.breakdown(phase)
            assert br.total == pytest.approx(
                br.comm_only + br.comp_only + br.overlap + br.idle, abs=1e-9)
            assert br.overlap >= -1e-12


# ---------------------------------------------------------------------------
# strategy registry + bucket plans
# ---------------------------------------------------------------------------

class TestSchedulerAPI:
    def test_registry_and_ordering(self):
        c = random_costs(16, seed=2, dt=1e-3)
        times = {name: evaluate(c, schedule(c, name))["total"]
                 for name in ("sequential", "lbl", "ibatch", "dynacomm")}
        assert times["dynacomm"] <= min(times.values()) + 1e-12

    def test_unknown_strategy(self):
        c = random_costs(4, seed=0)
        with pytest.raises(ValueError):
            schedule(c, "nope")

    def test_bucket_plan(self):
        c = random_costs(6, seed=0, dt=1e-3)
        f, b = schedule(c, "dynacomm")
        plan = plan_from_decision(f, b, 6)
        # forward buckets cover 0..5 in order
        assert [l for grp in plan.forward for l in grp] == list(range(6))
        # backward buckets cover 5..0 in reverse order
        assert [l for grp in plan.backward for l in grp] == list(range(5, -1, -1))

    def test_epoch_caching(self):
        from repro.core import DynaCommScheduler
        c = random_costs(10, seed=0, dt=1e-3)
        sched = DynaCommScheduler(strategy="dynacomm", reschedule_every=5)
        d0 = sched.decision_for_iteration(c)
        d1 = sched.decision_for_iteration(c)
        assert d0 == d1
        assert sched.last_scheduling_seconds >= 0.0
