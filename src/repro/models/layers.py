"""Shared neural-net building blocks (pure functional JAX)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_dense(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "geglu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# MLP (optionally gated: SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d_model, d_ff, dtype),
         "down": init_dense(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["gate"] = init_dense(ks[2], d_model, d_ff, dtype)
    return p


def apply_mlp(params, x, act_name: str):
    act = activation_fn(act_name)
    up = dense(x, params["up"])
    if "gate" in params:
        up = act(dense(x, params["gate"])) * up
    else:
        up = act(up)
    return dense(up, params["down"])


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, head_dim); positions: broadcastable to (..., T)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs       # (..., T, hd/2)
    angles = angles[..., None, :]                                    # (..., T, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def embed(tokens: jnp.ndarray, table: jnp.ndarray, scale: bool = True):
    x = jnp.take(table, tokens, axis=0)
    if scale:
        x = x * jnp.asarray(np.sqrt(table.shape[-1]), dtype=x.dtype)
    return x


def logits_from_embedding(x: jnp.ndarray, table: jnp.ndarray,
                          final_cap: float = 0.0) -> jnp.ndarray:
    out = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    return softcap(out, final_cap)
