"""ASCII timeline rendering of a scheduled iteration (Fig. 2/3 style).

``render_timeline`` draws the link lane and the compute lane of one phase
as a proportional text Gantt chart — the quickest way to *see* what a
decomposition decision does to the overlap structure.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.costmodel import LayerCosts, Segment
from repro.core.simulator import simulate_backward, simulate_forward


def _lane(events, t_end: float, width: int, fill: str) -> str:
    lane = [" "] * width
    for e in events:
        lo = int(round(e.start / t_end * (width - 1)))
        hi = max(lo + 1, int(round(e.end / t_end * (width - 1))))
        for i in range(lo, min(hi, width)):
            lane[i] = fill
        if hi - lo >= 3:
            label = f"{e.layers[0]}" if e.layers[0] == e.layers[1] \
                else f"{e.layers[0]}-{e.layers[1]}"
            for j, ch in enumerate(label[:hi - lo - 1]):
                lane[lo + j] = ch
    return "".join(lane)


def render_timeline(costs: LayerCosts, segments: Sequence[Segment], *,
                    phase: str = "forward", width: int = 78) -> str:
    if phase == "forward":
        events, t_end = simulate_forward(costs, segments)
        comm_kind, comp_kind = "pt", "fc"
    else:
        events, t_end = simulate_backward(costs, segments)
        comm_kind, comp_kind = "gt", "bc"
    comm = [e for e in events if e.kind == comm_kind]
    comp = [e for e in events if e.kind == comp_kind]
    lines = [
        f"{phase}: {len(segments)} transmission mini-procedure(s), "
        f"makespan {t_end:.4f}s",
        "link    |" + _lane(comm, t_end, width, "=") + "|",
        "compute |" + _lane(comp, t_end, width, "#") + "|",
    ]
    return "\n".join(lines)
