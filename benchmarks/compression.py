"""Compressed push-pull benchmarks (``repro.compress``).

Two benches, published together by CI as ``BENCH_compression.json``:

* ``compression_planning`` — how compressed gradient pushes re-shape the
  DP decomposition: per paper CNN and scheme (none / int8 / top-k), the
  consensus plan's segment counts, straggler makespan, and per-iteration
  push wire bytes over an edge fleet behind slow asymmetric uplinks.
  Shrinking gt makes the per-transmission Δt overhead relatively more
  expensive, so the DP merges pushes into fewer, larger segments *and*
  the makespan drops — the cost model and the wire savings compose.
* ``compression_training`` — the accuracy side: the smoke CNN driven
  through the bounded-staleness async PS loop under each scheme (error
  feedback on), reporting final loss vs the fp32 baseline, cumulative
  push wire bytes, and the measured ledger compression ratio.
"""

from __future__ import annotations

from typing import Dict, List

MODELS = ("vgg19", "googlenet", "inception-v4", "resnet152")
SCHEMES = (("none", None), ("int8", None), ("topk", 0.01))


def _edge_topology(workers: int = 4):
    """Heterogeneous edge fleet: 100 Mbps uplinks behind a 50 ms RTT +
    50 ms setup, half the workers at half compute.  In this regime the
    fp32 plan segments finely to hide the huge pushes; compressed gt is
    small enough that the per-transmission Δt dominates, so the DP merges
    backward segments (e.g. resnet152: 5 → 4 at int8, 5 → 3 at top-k)
    while the makespan still drops 14–52%."""
    from repro.ps import PSTopology, asymmetric_link
    return PSTopology(
        num_servers=2,
        links=tuple(asymmetric_link(2e9, 0.1e9, rtt_s=0.05, setup_s=0.05)
                    for _ in range(workers)),
        worker_flops=tuple(2e11 if w < workers // 2 else 1e11
                           for w in range(workers)))


def _compressor(scheme, fraction):
    from repro.compress import make_compressor
    return None if scheme == "none" else make_compressor(
        scheme, topk_fraction=fraction)


def compression_planning() -> List[Dict]:
    """Consensus plan + makespan + wire bytes per model and scheme."""
    from repro.core import consensus_decision
    from repro.models.cnn import PAPER_CNNS

    topo = _edge_topology()
    rows = []
    for model in MODELS:
        profiles = PAPER_CNNS[model](batch=32)
        logical = sum(p.param_bytes for p in profiles)
        base_makespan = base_bwd = None
        for scheme, fraction in SCHEMES:
            comp = _compressor(scheme, fraction)
            costs = topo.topology_costs(profiles, compressor=comp)
            decision, makespan = consensus_decision(costs, "dynacomm")
            if scheme == "none":
                base_makespan, base_bwd = makespan, len(decision[1])
            wire = logical if comp is None else float(
                sum(float(comp.wire_bytes(p.param_bytes)) for p in profiles)
                + comp.segment_overhead_bytes * len(decision[1]))
            rows.append({
                "model": model, "scheme": scheme,
                "fwd_segments": len(decision[0]),
                "bwd_segments": len(decision[1]),
                "bwd_coarser_than_fp32": len(decision[1]) < base_bwd,
                "sync_makespan_s": round(makespan, 4),
                "makespan_vs_fp32_pct": round(
                    100 * (1 - makespan / base_makespan), 2),
                "push_logical_mb": round(logical / 1e6, 2),
                "push_wire_mb": round(wire / 1e6, 2),
                "wire_ratio": round(logical / wire, 2),
            })
    return rows


def compression_training() -> List[Dict]:
    """Async PS smoke-CNN training under each scheme (error feedback)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import plan_from_decision
    from repro.models.cnn import small_cnn_init, small_cnn_loss
    from repro.optim import sgd
    from repro.ps import AsyncPSTrainer, PSTopology, asymmetric_link

    topo = PSTopology(
        num_servers=2,
        links=tuple(asymmetric_link(10e9, 1e9) for _ in range(3)),
        worker_flops=(1e10,) * 3)

    def loss_fn(layers, batch):
        return small_cnn_loss({"layers": layers}, batch["images"],
                              batch["labels"])

    def batch_fn(w, i):
        r = np.random.default_rng(100003 * w + i)
        return {"images": jnp.asarray(r.normal(size=(8, 32, 32, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, 10, size=(8,)),
                                      jnp.int32)}

    pushes = 30
    rows = []
    base_final = None
    for scheme, fraction in SCHEMES:
        params = small_cnn_init(jax.random.PRNGKey(0))
        L = len(params["layers"])
        plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)
        tr = AsyncPSTrainer(init_layers=params["layers"], loss_fn=loss_fn,
                            optimizer=sgd(0.02), topology=topo, plan=plan,
                            staleness=1,
                            compressor=_compressor(scheme, fraction))
        log = tr.run(pushes, batch_fn)
        led = tr.server.ledger
        final = log.losses[-1]
        if scheme == "none":
            base_final = final
        rows.append({
            "scheme": scheme,
            "pushes": len(log.accepted),
            "push_logical_mb": round(
                sum(led.pushed_bytes.values()) / 1e6, 3),
            "push_wire_mb": round(
                sum(led.pushed_wire_bytes.values()) / 1e6, 3),
            "wire_ratio": round(led.compression_ratio("push"), 3),
            "sim_makespan_s": round(log.makespan, 4),
            "first_loss": round(log.losses[0], 4),
            "final_loss": round(final, 4),
            "final_loss_delta_vs_fp32_pct": round(
                100 * (final - base_final) / base_final, 3),
        })
    return rows


COMPRESSION_BENCHES = {
    "compression_planning": compression_planning,
    "compression_training": compression_training,
}
