"""Model builder: init / forward / loss / decode over an ArchConfig.

Parameter layout (the "scheduling view" DynaComm consumes)::

    params = {
      "embed":  {...}          # sched layer 0   (token table / input proj)
      "layers": [block_0, ...] # sched layers 1..L
      "final":  {...}          # sched layer L+1 (final norm + untied head)
    }

``num_sched_layers = cfg.num_layers + 2``; per-sched-layer byte counts and
FLOPs come from ``profiles.py`` and feed the DP scheduler directly.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import blocks
from repro.models.layers import (embed, init_dense, init_embedding,
                                 logits_from_embedding, rms_norm, dense)

Params = Dict[str, Any]


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Params:
    kinds = cfg.layer_kinds()
    keys = jax.random.split(key, cfg.num_layers + 2)
    p: Params = {"embed": {}, "layers": [], "final": {}}

    if cfg.frontend != "audio":
        p["embed"]["table"] = init_embedding(keys[0], cfg.vocab_size,
                                             cfg.d_model, dtype)
    else:
        # audio: frames arrive pre-embedded (stub frontend); learn a proj
        p["embed"]["in_proj"] = init_dense(keys[0], cfg.d_model, cfg.d_model,
                                           dtype)

    for i, kind in enumerate(kinds):
        p["layers"].append(blocks.init_block(keys[1 + i], cfg, kind, dtype))

    p["final"]["norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        p["final"]["head"] = init_dense(keys[-1], cfg.d_model, cfg.vocab_size,
                                        dtype)
    return p


def _embed_inputs(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    """Produce the (B, T, d) input sequence from the modality-specific batch."""
    if cfg.frontend == "audio":
        return dense(batch["frames"], params["embed"]["in_proj"])
    x = embed(batch["tokens"], params["embed"]["table"])
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        v = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([v, x], axis=1)
    return x


def _head(cfg: ArchConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final"]["norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return logits_from_embedding(x, params["embed"]["table"],
                                     cfg.final_logit_softcap)
    from repro.models.layers import softcap
    return softcap(dense(x, params["final"]["head"]), cfg.final_logit_softcap)


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            mode: str = "train", caches: Optional[List[Any]] = None,
            remat: bool = False, last_only: bool = False
            ) -> Tuple[jnp.ndarray, Optional[List[Any]], jnp.ndarray]:
    """Returns (logits, new_caches_or_None, aux_loss)."""
    kinds = cfg.layer_kinds()
    if mode == "decode":
        x = embed(batch["token"], params["embed"]["table"]) \
            if cfg.frontend != "audio" else None
        if x is None:
            raise ValueError("encoder-only model has no decode mode")
    else:
        x = _embed_inputs(cfg, params, batch)

    aux = jnp.zeros((), jnp.float32)
    new_caches: List[Any] = []
    for i, kind in enumerate(kinds):
        cache_i = caches[i] if caches is not None else None
        apply = blocks.apply_block
        if remat and mode == "train":
            apply = jax.checkpoint(
                lambda p, h, _cfg=cfg, _k=kind:
                blocks.apply_block(p, h, _cfg, _k, mode="train", cache=None))
            x, c, a = apply(params["layers"][i], x)
        else:
            x, c, a = apply(params["layers"][i], x, cfg, kind,
                            mode=mode, cache=cache_i)
        new_caches.append(c)
        aux = aux + a

    if last_only:
        x = x[:, -1:]           # narrow before the (huge) vocab projection
    logits = _head(cfg, params, x)
    out_caches = new_caches if mode in ("prefill", "decode") else None
    return logits, out_caches, aux


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.float32) -> List[Any]:
    return [blocks.init_block_cache(cfg, kind, batch, max_len, dtype)
            for kind in cfg.layer_kinds()]


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over positions with label >= 0.

    Written in one-hot select-reduce form (not take_along_axis): a gather
    along a vocab-sharded axis would force GSPMD to all-gather the *global*
    logits; iota-compare-select partitions cleanly along both batch and
    vocab axes.
    """
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    x = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    picked = jnp.sum(jnp.where(iota == safe[..., None], x, 0.0), axis=-1)
    return jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_loss(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray],
               *, aux_weight: float = 0.01, remat: bool = False) -> jnp.ndarray:
    logits, _, aux = forward(cfg, params, batch, mode="train", remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # vision tokens prepended: pad labels with ignore for those positions
        nv = logits.shape[1] - labels.shape[1]
        pad = jnp.full(labels.shape[:1] + (nv,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return cross_entropy(logits, labels) + aux_weight * aux


def decode_step(cfg: ArchConfig, params: Params, token: jnp.ndarray,
                caches: List[Any]) -> Tuple[jnp.ndarray, List[Any]]:
    """serve_step: one token (B, 1) against the caches → (logits, caches)."""
    logits, new_caches, _ = forward(cfg, params, {"token": token},
                                    mode="decode", caches=caches)
    return logits, new_caches


# ---------------------------------------------------------------------------
# scheduling view
# ---------------------------------------------------------------------------


def num_sched_layers(cfg: ArchConfig) -> int:
    return cfg.num_layers + 2


def sched_layer_trees(params: Params) -> List[Any]:
    """Per-sched-layer parameter pytrees (embed, blocks..., final)."""
    return [params["embed"], *params["layers"], params["final"]]


def params_from_sched_layers(trees: List[Any]) -> Params:
    return {"embed": trees[0], "layers": list(trees[1:-1]), "final": trees[-1]}


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def sched_layer_bytes(cfg: ArchConfig, dtype=jnp.float32) -> List[int]:
    """Per-sched-layer parameter bytes, via eval_shape (no allocation)."""
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    return [tree_bytes(t) for t in sched_layer_trees(shapes)]


def param_count(cfg: ArchConfig) -> int:
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k, jnp.float32), jax.random.PRNGKey(0))
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(shapes))
