"""End-to-end driver: train a ~100M-parameter model with the DynaComm
bucketed ZeRO trainer for a few hundred steps.

Runs on however many host devices exist (set
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` for a multi-device
CPU demo).  The per-epoch re-scheduling loop (paper Section IV-C) is live:
cost vectors come from the analytic profiler, the DP re-plans every
``reschedule_every`` steps, and the trainer rebuilds its buckets when the
decision changes.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/edge_training.py --steps 200
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core import (DynaCommScheduler, EdgeNetworkModel,
                        costs_from_profiles, plan_from_decision)
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticText
from repro.dist.zero import ZeroTrainer
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--strategy", default="dynacomm")
    ap.add_argument("--reschedule-every", type=int, default=100)
    args = ap.parse_args()

    # ~100M-param reduced variant of the chosen architecture
    cfg = dataclasses.replace(
        get_config(args.arch).reduced(num_layers=args.layers,
                                      d_model=args.d_model, vocab=8192),
        name=f"{args.arch}-demo")
    n_dev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(n_dev,), ("data",))
    print(f"devices: {n_dev}  arch: {cfg.name}  layers: {cfg.num_layers}  "
          f"d_model: {cfg.d_model}")

    # run-time profiling → DP decision → bucket plan (paper Fig. 4 loop)
    shape = InputShape("demo", args.seq, args.batch, "train")
    costs = costs_from_profiles(
        layer_profiles(cfg, shape),
        net=EdgeNetworkModel(bandwidth_bps=1e9), compute_flops_per_s=1e12)
    scheduler = DynaCommScheduler(strategy=args.strategy,
                                  reschedule_every=args.reschedule_every)
    Ls = num_sched_layers(cfg)

    decision = scheduler.decision_for_iteration(costs)
    plan = plan_from_decision(*decision, Ls)
    print(f"strategy {args.strategy}: {len(plan.forward)} pull buckets, "
          f"{len(plan.backward)} push buckets "
          f"(scheduling took {scheduler.last_scheduling_seconds * 1e3:.2f} ms)")

    trainer = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=adamw(3e-4))
    state = trainer.init_state(jax.random.PRNGKey(0))
    step_fn = jax.jit(trainer.build_train_step())

    pipe = SyntheticText(cfg.vocab_size, args.seq, args.batch, seed=0)
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = pipe.batch(i)
        # per-epoch re-scheduling: rebuild buckets if the decision changed
        new_decision = scheduler.decision_for_iteration(costs)
        if new_decision != decision:
            decision = new_decision
            plan = plan_from_decision(*decision, Ls)
            trainer = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan,
                                  optimizer=adamw(3e-4))
            step_fn = jax.jit(trainer.build_train_step())
        state, loss = step_fn(state, batch)
        if (i + 1) % 20 == 0:
            dt = (time.perf_counter() - t0) / (i + 1)
            print(f"step {i + 1:4d}  loss {float(loss):.4f}  {dt:.3f}s/step")
    print("done.")


if __name__ == "__main__":
    main()
