"""§Faithful: machine-checked versions of the paper's headline claims."""

import numpy as np
import pytest

from benchmarks.edge_setup import cnn_costs
from benchmarks.paper_figures import (MODELS, fig9a_batch_sensitivity,
                                      fig9b_bandwidth_sensitivity,
                                      total_iteration_reduction)
from repro.core import (backward_time, bruteforce_backward,
                        bruteforce_forward, dp_backward, dp_forward,
                        evaluate, forward_time, schedule)
from repro.core.baselines import lbl_forward


class TestOptimalityOnPaperModels:
    """Claim: "DynaComm manages to achieve optimal layer-wise scheduling
    for ALL cases compared to competing strategies"."""

    @pytest.mark.parametrize("model", MODELS)
    @pytest.mark.parametrize("batch", [16, 32])
    def test_dynacomm_beats_all_competitors(self, model, batch):
        costs = cnn_costs(model, batch=batch)
        times = {s: evaluate(costs, schedule(costs, s))["total"]
                 for s in ("sequential", "lbl", "ibatch", "dynacomm")}
        eps = 1e-9
        assert times["dynacomm"] <= times["lbl"] + eps
        assert times["dynacomm"] <= times["ibatch"] + eps
        assert times["dynacomm"] <= times["sequential"] + eps

    @pytest.mark.parametrize("model", MODELS)
    def test_dp_is_exactly_optimal_truncated(self, model):
        """Exhaustive check on a 14-layer prefix of each CNN's cost table
        (full tables are beyond brute force, as the paper notes)."""
        full = cnn_costs(model, batch=32)
        from repro.core import LayerCosts
        costs = LayerCosts(pt=full.pt[:14], fc=full.fc[:14],
                           bc=full.bc[:14], gt=full.gt[:14], dt=full.dt)
        assert dp_forward(costs).time == pytest.approx(
            bruteforce_forward(costs)[1], rel=1e-9)
        assert dp_backward(costs).time == pytest.approx(
            bruteforce_backward(costs)[1], rel=1e-9)


class TestReductionMagnitudes:
    """Claim: total iteration time reduced by up to 41.92%; per-model
    reductions in the 28-47% band under the paper's testbed constants."""

    def test_total_reduction_band(self):
        rows = total_iteration_reduction()
        best = max(r["dynacomm_reduced_pct"] for r in rows)
        assert 35.0 <= best <= 55.0, f"headline reduction {best}%"
        for r in rows:
            assert r["dynacomm_reduced_pct"] >= 25.0, r

    def test_vgg19_near_paper_numbers(self):
        """Paper: VGG-19 total reduction 41.10% (bs 32)."""
        rows = [r for r in total_iteration_reduction()
                if r["model"] == "vgg19" and r["batch"] == 32]
        assert abs(rows[0]["dynacomm_reduced_pct"] - 41.1) < 8.0


class TestIBatchPathology:
    """Claim (Fig. 5c): iBatch sometimes performs worse than plain LBL."""

    def test_ibatch_loses_to_lbl_somewhere(self):
        hits = 0
        for model in MODELS:
            for batch in (16, 32):
                costs = cnn_costs(model, batch=batch)
                t_ib = evaluate(costs, schedule(costs, "ibatch"))["total"]
                t_lbl = evaluate(costs, schedule(costs, "lbl"))["total"]
                if t_ib > t_lbl + 1e-9:
                    hits += 1
        assert hits >= 1, "iBatch never lost to LBL on the paper models"

    def test_dynacomm_never_loses(self):
        for model in MODELS:
            for batch in (8, 16, 24, 32, 48):
                costs = cnn_costs(model, batch=batch)
                t = {s: evaluate(costs, schedule(costs, s))["total"]
                     for s in ("lbl", "ibatch", "dynacomm")}
                assert t["dynacomm"] <= min(t.values()) + 1e-9


class TestSensitivity:
    """Fig. 9: reduction peaks where compute/comm are balanced."""

    def test_batch_sweep_has_interior_peak_or_plateau(self):
        rows = [r for r in fig9a_batch_sensitivity()
                if r["strategy"] == "dynacomm"]
        vals = [r["reduced_pct"] for r in rows]
        # reduction should not be monotone increasing across the whole sweep
        assert max(vals) >= vals[-1]

    def test_bandwidth_nonmonotone(self):
        """Paper: poor at 1 Gbps, peak at 5 Gbps, lower again at 10 Gbps."""
        rows = {(r["bandwidth_gbps"]): r["reduced_pct"]
                for r in fig9b_bandwidth_sensitivity()
                if r["strategy"] == "dynacomm"}
        assert rows[5] > rows[1]
        assert rows[5] > rows[10]


class TestComplexity:
    """Fig. 12 / Section IV-B4: O(L^3) scheduling, negligible vs iteration."""

    def test_cubic_growth(self):
        import time
        from repro.core import random_costs
        ts = {}
        for L in (64, 128, 256):
            costs = random_costs(L, seed=0, dt=1e-3)
            t0 = time.perf_counter()
            dp_forward(costs)
            ts[L] = time.perf_counter() - t0
        # doubling L should multiply time by ~8 (allow 3x-32x: numpy consts)
        r1 = ts[128] / ts[64]
        r2 = ts[256] / ts[128]
        assert 2.0 < r2 < 40.0 and r2 > r1 * 0.5

    def test_scheduling_negligible_vs_iteration(self):
        """Table I / II: scheduling cost ≪ iteration time on paper models."""
        import time
        for model in MODELS:
            costs = cnn_costs(model, batch=32)
            t0 = time.perf_counter()
            dp_forward(costs)
            dp_backward(costs)
            sched_t = time.perf_counter() - t0
            iter_t = evaluate(costs, schedule(costs, "dynacomm"))["total"]
            assert sched_t < 0.05 * iter_t, (model, sched_t, iter_t)


class TestSchedulerHiding:
    """Section IV-C: the scheduler fits in the idle window (Δt + gt^1)."""

    def test_idle_window_hides_scheduling(self):
        from repro.core import DynaCommScheduler
        for model in MODELS:
            costs = cnn_costs(model, batch=32)
            sched = DynaCommScheduler(strategy="dynacomm")
            sched.decision_for_iteration(costs)
            assert sched.scheduling_overhead_hidden(costs), model
