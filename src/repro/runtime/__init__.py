"""One composable Trainer API over every execution regime.

``repro.runtime`` is the layer that makes launchers, examples, and
benchmarks *thin clients*: a frozen, JSON-round-trippable
:class:`RuntimeConfig` names a registered runtime (``zero`` | ``dynamic``
| ``ps`` | ``ps-async`` | ``dynamic-ps`` | ``dynamic-ps-async`` |
``local``), and :func:`build_runtime` turns it into an object
implementing the :class:`Trainer` protocol — ``fit`` / ``step`` /
``events`` / ``timeline`` / ``ledger`` / ``save_state`` /
``restore_state`` — regardless of which of the six underlying trainers
executes underneath.  New regimes cost one ``@register_runtime`` entry,
not a new hand-wired launcher branch.

The run-time re-planning machinery the dynamic drivers share
(:class:`PlanStepCache`, :class:`RescheduleEvent`, the Table I
idle-window bookkeeping) lives here too, in :mod:`repro.runtime.replan`.
"""

from repro.runtime.config import (DYNAMIC_RUNTIMES, RUNTIME_REGIMES,
                                  CompressionConfig, ExecutionConfig,
                                  FleetConfig, FleetEventConfig,
                                  MeasureConfig, NetworkConfig,
                                  PipelineConfig, RuntimeConfig,
                                  ScheduleConfig, TopologyConfig)
from repro.runtime.protocol import EvalEvent, Trainer
from repro.runtime.replan import (PlanStepCache, ReplanMixin,
                                  RescheduleEvent, hlo_collective_counts,
                                  sequential_plan)

__all__ = [
    "RuntimeConfig", "ScheduleConfig", "ExecutionConfig", "MeasureConfig",
    "NetworkConfig", "TopologyConfig", "CompressionConfig",
    "FleetConfig", "FleetEventConfig", "PipelineConfig",
    "RUNTIME_REGIMES", "DYNAMIC_RUNTIMES",
    "Trainer", "EvalEvent",
    "PlanStepCache", "RescheduleEvent", "ReplanMixin",
    "hlo_collective_counts", "sequential_plan",
    "build_runtime", "register_runtime", "runtime_names", "RUNTIMES",
]

_REGISTRY_NAMES = ("build_runtime", "register_runtime", "runtime_names",
                   "RUNTIMES")


def __getattr__(name: str):
    # the registry pulls in the trainer stack (dist/ps); load it lazily so
    # `repro.dist` ← `repro.runtime.replan` stays cycle-free
    if name in _REGISTRY_NAMES:
        from repro.runtime import registry
        return getattr(registry, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
