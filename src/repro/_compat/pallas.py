"""Pallas API compatibility aliases (jax renamed these across versions)."""

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes this as TPUCompilerParams, newer jax as CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
