"""Minimal stand-in for the slice of the `hypothesis` API this repo's tests
use, for containers where the real package cannot be installed.

The real dependency is declared in ``pyproject.toml`` (extra ``test``) and is
always preferred: ``conftest.py`` calls :func:`install` only when
``import hypothesis`` fails.  The fallback runs each ``@given`` test against
``max_examples`` deterministically-seeded samples (seeded per test, endpoints
included with elevated probability) — no shrinking, no example database, but
the property tests here assert for-all invariants of pure numpy code, so any
legal sample is a valid probe.

Supported surface: ``given``, ``settings(max_examples=, deadline=)``, and
``strategies.{floats, integers, lists, tuples, booleans, just, sampled_from}``
with ``.map`` / ``.filter`` / ``.flatmap``.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, f):
        return Strategy(lambda rng: f(self._draw(rng)))

    def flatmap(self, f):
        return Strategy(lambda rng: f(self._draw(rng))._draw(rng))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                x = self._draw(rng)
                if pred(x):
                    return x
            raise RuntimeError("filter predicate rejected 1000 samples")
        return Strategy(draw)


def floats(min_value=0.0, max_value=1.0, **_kw):
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return lo + (hi - lo) * rng.random()
    return Strategy(draw)


def integers(min_value=0, max_value=2 ** 31 - 1):
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def lists(elements, *, min_size=0, max_size=None, **_kw):
    hi = min_size + 10 if max_size is None else max_size

    def draw(rng):
        n = int(rng.integers(min_size, hi + 1))
        return [elements._draw(rng) for _ in range(n)]
    return Strategy(draw)


def tuples(*strategies):
    return Strategy(lambda rng: tuple(s._draw(rng) for s in strategies))


def booleans():
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def just(value):
    return Strategy(lambda rng: value)


def sampled_from(elements):
    seq = list(elements)
    return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def randoms(note_method_calls=False, use_true_random=False, **_kw):
    import random as _random
    return Strategy(lambda rng: _random.Random(int(rng.integers(0, 2 ** 32))))


def given(*strategies):
    def decorate(fn):
        # Like real hypothesis, strategies bind to the RIGHTMOST params —
        # by name, so fixtures passed by pytest as kwargs cannot collide.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        kept = params[:len(params) - len(strategies)]
        bound_names = [p.name for p in params[len(params) - len(strategies):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            rng = np.random.default_rng(
                zlib.adler32(fn.__qualname__.encode()))
            for _ in range(n):
                example = {name: s._draw(rng)
                           for name, s in zip(bound_names, strategies)}
                try:
                    fn(*args, **kwargs, **example)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (hypothesis fallback): "
                        f"{example!r}") from e
        wrapper._is_hypothesis_fallback = True
        # pytest must not mistake example-bound params for fixtures: hide
        # __wrapped__ and expose a signature without the trailing params.
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper
    return decorate


def settings(max_examples=DEFAULT_MAX_EXAMPLES, **_kw):
    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return decorate


def install() -> None:
    """Register the fallback as ``hypothesis`` in ``sys.modules``."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    mod.given, mod.settings = given, settings
    st = types.ModuleType("hypothesis.strategies")
    for name in ("floats", "integers", "lists", "tuples", "booleans", "just",
                 "sampled_from", "randoms"):
        setattr(st, name, globals()[name])
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
