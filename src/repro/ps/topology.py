"""Parameter-server topology: S server shards × W edge workers.

The paper's deployment (Section II): parameter servers hold the model,
edge devices pull parameters down and push gradients up.  ``PSTopology``
describes that fabric explicitly —

* ``num_servers`` server shards, each owning a contiguous block of sched
  layers (``shard_of_layer``); a DynaComm transmission segment is one
  message against the shard owning its first layer (``owner_of_bucket``);
* one :class:`LinkModel` per worker: an *asymmetric* pair of
  ``core.netmodel`` network models — ``down`` times the parameter pull
  (server → worker), ``up`` times the gradient push (worker → server).
  Edge uplinks are routinely 5-20× slower than downlinks, which is what
  makes per-direction Δt/bandwidth worth modelling;
* per-worker compute rates (``worker_flops``) — heterogeneous edge
  hardware.

``worker_costs`` / ``topology_costs`` project the topology onto the
scheduler's cost interface: per-worker ``LayerCosts`` whose pt/Δt come
from the downlink, gt/Δt_bwd from the uplink, and fc/bc from that
worker's own compute rate — so DynaComm plans *per topology* rather than
per homogeneous cluster.

``TopologySchedule`` is the time-varying regime: a piecewise-constant
sequence of topologies indexed by epoch (mirroring
``core.netmodel.NetworkSchedule``) — an edge fleet whose uplinks degrade,
whose devices throttle thermally, or whose membership is re-provisioned
on epoch boundaries.  ``repro.ps.dynamic.DynamicPSTrainer`` re-plans
against the active topology once per topology epoch.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence, Tuple

import numpy as np

from repro.core.costmodel import LayerCosts, TopologyCosts
from repro.core.netmodel import EdgeNetworkModel
from repro.core.profiler import LayerProfile


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One worker's asymmetric path to the parameter servers.

    ``down`` and ``up`` are network models exposing ``dt`` and
    ``transfer_time(nbytes)`` (any ``core.netmodel`` model qualifies).
    """

    down: Any                  # server → worker: parameter pulls
    up: Any                    # worker → server: gradient pushes

    def __post_init__(self):
        for name in ("down", "up"):
            m = getattr(self, name)
            if not hasattr(m, "dt") or not hasattr(m, "transfer_time"):
                raise TypeError(f"{name} model {m!r} lacks the network "
                                f"interface (dt + transfer_time)")


def asymmetric_link(down_bps: float, up_bps: float, *,
                    rtt_s: float = EdgeNetworkModel.rtt_s,
                    setup_s: float = EdgeNetworkModel.setup_s) -> LinkModel:
    """The common edge case: one RTT, different bandwidth per direction."""
    return LinkModel(
        down=EdgeNetworkModel(bandwidth_bps=down_bps, rtt_s=rtt_s,
                              setup_s=setup_s),
        up=EdgeNetworkModel(bandwidth_bps=up_bps, rtt_s=rtt_s,
                            setup_s=setup_s))


@dataclasses.dataclass(frozen=True)
class PSTopology:
    """S server shards × W edge workers with per-link, per-worker costs."""

    num_servers: int
    links: Tuple[LinkModel, ...]          # one per worker
    worker_flops: Tuple[float, ...]       # compute rate per worker (FLOP/s)

    def __post_init__(self):
        object.__setattr__(self, "links", tuple(self.links))
        object.__setattr__(self, "worker_flops",
                           tuple(float(f) for f in self.worker_flops))
        if self.num_servers < 1:
            raise ValueError(f"num_servers must be >= 1, got "
                             f"{self.num_servers}")
        if not self.links:
            raise ValueError("a topology needs at least one worker link")
        if len(self.worker_flops) != len(self.links):
            raise ValueError(f"{len(self.worker_flops)} worker_flops for "
                             f"{len(self.links)} links")
        if any(f <= 0 for f in self.worker_flops):
            raise ValueError("worker_flops must be positive")

    @property
    def num_workers(self) -> int:
        return len(self.links)

    @classmethod
    def uniform(cls, num_servers: int, num_workers: int, *,
                down_bps: float = 10e9, up_bps: float = 1e9,
                flops: float = 1e10,
                rtt_s: float = EdgeNetworkModel.rtt_s,
                setup_s: float = EdgeNetworkModel.setup_s) -> "PSTopology":
        """Homogeneous workers behind identical asymmetric links."""
        link = asymmetric_link(down_bps, up_bps, rtt_s=rtt_s,
                               setup_s=setup_s)
        return cls(num_servers=num_servers, links=(link,) * num_workers,
                   worker_flops=(flops,) * num_workers)

    # ------------------------------------------------------------------
    # server sharding
    # ------------------------------------------------------------------

    def shard_of_layer(self, layer: int, num_layers: int) -> int:
        """Owning server shard of 0-indexed sched layer ``layer``.

        Layers are split into ``num_servers`` contiguous blocks (block s
        holds layers [s*L/S, (s+1)*L/S)), so DynaComm's contiguous
        transmission segments mostly stay within one shard."""
        if not 0 <= layer < num_layers:
            raise ValueError(f"layer {layer} outside 0..{num_layers - 1}")
        return min(layer * self.num_servers // num_layers,
                   self.num_servers - 1)

    def owner_of_bucket(self, bucket: Sequence[int], num_layers: int) -> int:
        """The shard a segment's single pull/push message is routed to:
        the owner of the segment's lowest layer."""
        if not bucket:
            raise ValueError("empty bucket has no owner")
        return self.shard_of_layer(min(bucket), num_layers)

    def layers_of_shard(self, shard: int, num_layers: int) -> Tuple[int, ...]:
        if not 0 <= shard < self.num_servers:
            raise ValueError(f"shard {shard} outside 0..{self.num_servers - 1}")
        return tuple(l for l in range(num_layers)
                     if self.shard_of_layer(l, num_layers) == shard)

    # ------------------------------------------------------------------
    # projection onto the scheduler's cost interface
    # ------------------------------------------------------------------

    def worker_costs(self, worker: int, *, param_bytes: Sequence[float],
                     flops_fwd: Sequence[float],
                     flops_bwd: Sequence[float] | None = None,
                     grad_bytes: Sequence[float] | None = None,
                     compressor: Any | None = None) -> LayerCosts:
        """This worker's per-layer cost vectors.

        pt/Δt from its downlink, gt/Δt_bwd from its uplink, fc/bc from its
        own compute rate (bc defaults to 2× fc FLOPs).  With a
        ``compressor``, gradient pushes are timed on the *wire* payload
        (``compressor.wire_bytes``), and each push segment's Δt grows by
        the compressor's per-segment header cost over this uplink; pulls
        stay fp32."""
        if not 0 <= worker < self.num_workers:
            raise ValueError(f"worker {worker} outside "
                             f"0..{self.num_workers - 1}")
        link = self.links[worker]
        pb = np.asarray(param_bytes, dtype=np.float64)
        gb = pb if grad_bytes is None else np.asarray(grad_bytes, np.float64)
        ff = np.asarray(flops_fwd, dtype=np.float64)
        fb = 2.0 * ff if flops_bwd is None else np.asarray(flops_bwd,
                                                           np.float64)
        rate = self.worker_flops[worker]
        dt_bwd = link.up.dt
        if compressor is not None:
            gb = np.asarray(compressor.wire_bytes(gb), np.float64)
            dt_bwd += float(
                link.up.transfer_time(compressor.segment_overhead_bytes))
        return LayerCosts(pt=link.down.transfer_time(pb), fc=ff / rate,
                          bc=fb / rate, gt=link.up.transfer_time(gb),
                          dt=link.down.dt, dt_bwd=dt_bwd)

    def topology_costs(self, profiles: Sequence[LayerProfile], *,
                       compressor: Any | None = None) -> TopologyCosts:
        """Per-worker ``LayerCosts`` from one set of layer workloads."""
        pb = [p.param_bytes for p in profiles]
        gb = [p.gbytes for p in profiles]
        ff = [p.flops_fwd for p in profiles]
        fb = [p.bwd for p in profiles]
        return TopologyCosts(workers=tuple(
            self.worker_costs(w, param_bytes=pb, flops_fwd=ff, flops_bwd=fb,
                              grad_bytes=gb, compressor=compressor)
            for w in range(self.num_workers)))

    def topology_costs_measured(self, profiles: Sequence[LayerProfile], *,
                                fc: Sequence[float], bc: Sequence[float],
                                ref_flops: float | None = None,
                                compressor: Any | None = None
                                ) -> TopologyCosts:
        """Per-worker costs from *measured* per-layer fc/bc wall times.

        The measured vectors describe one physical host; they are taken
        as the timings of a worker running at ``ref_flops`` (default: the
        fleet's fastest rate) and rescaled to each worker's own compute
        rate — ``fc_w = fc * ref_flops / worker_flops[w]`` — while
        transmission costs (pt/gt/Δt per direction) still come from each
        worker's own links.  Byte payloads come from ``profiles``.
        """
        ref = max(self.worker_flops) if ref_flops is None else float(ref_flops)
        if ref <= 0:
            raise ValueError(f"ref_flops must be positive, got {ref}")
        pb = np.asarray([p.param_bytes for p in profiles], np.float64)
        gb = np.asarray([p.gbytes for p in profiles], np.float64)
        fc = np.asarray(fc, np.float64)
        bc = np.asarray(bc, np.float64)
        if fc.shape != (len(profiles),) or bc.shape != (len(profiles),):
            raise ValueError(f"fc/bc must have one entry per layer "
                             f"({len(profiles)}), got {fc.shape}/{bc.shape}")
        workers = []
        for w in range(self.num_workers):
            link = self.links[w]
            scale = ref / self.worker_flops[w]
            gb_w, dt_bwd = gb, link.up.dt
            if compressor is not None:
                gb_w = np.asarray(compressor.wire_bytes(gb), np.float64)
                dt_bwd += float(
                    link.up.transfer_time(compressor.segment_overhead_bytes))
            workers.append(LayerCosts(
                pt=link.down.transfer_time(pb), fc=fc * scale,
                bc=bc * scale, gt=link.up.transfer_time(gb_w),
                dt=link.down.dt, dt_bwd=dt_bwd))
        return TopologyCosts(workers=tuple(workers))


# ---------------------------------------------------------------------------
# Time-varying topologies (the dynamic-PS workload)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """Piecewise-constant time-varying :class:`PSTopology`.

    ``knots`` is a sequence of ``(start_epoch, topology)`` pairs with
    strictly increasing epochs starting at 0 (the ``NetworkSchedule``
    contract, applied to whole topologies): ``topology_at(e)`` returns the
    topology of the last knot whose start epoch is <= ``e``, so a shift
    applies to the boundary epoch itself.  Zero-length epochs (two knots
    at the same epoch) are rejected.

    Every knot must keep ``num_workers`` fixed — workers map 1:1 onto mesh
    devices (sync) or event-loop actors (async), neither of which can be
    re-provisioned mid-run; links, compute rates, and the server-shard
    count may all drift freely.
    """

    knots: Tuple[Tuple[int, PSTopology], ...]

    def __post_init__(self):
        knots = tuple((int(e), t) for e, t in self.knots)
        object.__setattr__(self, "knots", knots)
        if not knots:
            raise ValueError("TopologySchedule needs at least one knot")
        for e, topo in knots:
            if not isinstance(topo, PSTopology):
                raise TypeError(f"knot at epoch {e} is {type(topo).__name__},"
                                f" not PSTopology")
        epochs = [e for e, _ in knots]
        if epochs[0] != 0:
            raise ValueError(f"first knot must start at epoch 0, got "
                             f"{epochs[0]}")
        if any(b <= a for a, b in zip(epochs, epochs[1:])):
            raise ValueError(f"knot epochs must be strictly increasing, got "
                             f"{epochs}")
        workers = {t.num_workers for _, t in knots}
        if len(workers) != 1:
            raise ValueError(f"knots disagree on num_workers: "
                             f"{sorted(workers)} — workers cannot join or "
                             f"leave mid-run")

    @property
    def num_knots(self) -> int:
        return len(self.knots)

    @property
    def num_workers(self) -> int:
        return self.knots[0][1].num_workers

    def topology_at(self, epoch: int) -> PSTopology:
        if epoch < 0:
            raise ValueError(f"epoch must be >= 0, got {epoch}")
        active = self.knots[0][1]
        for start, topo in self.knots:
            if start > epoch:
                break
            active = topo
        return active

    def shift_epochs(self) -> Tuple[int, ...]:
        """Epochs at which the active topology changes (knots after the
        first)."""
        return tuple(e for e, _ in self.knots[1:])


def as_topology_schedule(topo) -> TopologySchedule:
    """Wrap a static ``PSTopology`` as a one-knot schedule (idempotent)."""
    if isinstance(topo, TopologySchedule):
        return topo
    return TopologySchedule(knots=((0, topo),))


def uplink_degradation(base: PSTopology, *, factor: float,
                       at_epoch: int) -> TopologySchedule:
    """The canonical drift demo: every worker's uplink bandwidth divided
    by ``factor`` at ``at_epoch`` (downlinks, RTTs, and compute rates
    unchanged) — gradient pushes suddenly dominate and the backward
    decomposition must re-segment."""
    if at_epoch < 1:
        raise ValueError(f"at_epoch must be >= 1, got {at_epoch}")
    if factor <= 0:
        raise ValueError(f"factor must be positive, got {factor}")
    degraded = []
    for w, link in enumerate(base.links):
        up = link.up
        # LinkModel's contract is duck-typed (dt + transfer_time); this
        # helper additionally needs a bandwidth-parameterized uplink
        for attr in ("bandwidth_bps", "rtt_s", "setup_s"):
            if not hasattr(up, attr):
                raise TypeError(
                    f"worker {w}'s uplink {up!r} has no {attr}; "
                    f"uplink_degradation needs EdgeNetworkModel-style "
                    f"uplinks — build the degraded TopologySchedule "
                    f"explicitly instead")
        degraded.append(LinkModel(
            down=link.down,
            up=EdgeNetworkModel(bandwidth_bps=up.bandwidth_bps / factor,
                                rtt_s=up.rtt_s, setup_s=up.setup_s)))
    after = PSTopology(num_servers=base.num_servers, links=tuple(degraded),
                       worker_flops=base.worker_flops)
    return TopologySchedule(knots=((0, base), (at_epoch, after)))
