"""Subprocess helper: multi-device checks for the sync PS trainer.

Run with 4 forged host devices.  Prints one JSON line the parent asserts
on:

1. **bit-identity** — sync-mode ``PSTrainer`` losses are bit-identical to
   ``ZeroTrainer`` on the same ``BucketPlan`` (the PS sync path *is* the
   co-located sharded-PS deployment of the ZeRO step);
2. **transfer structure** — per strategy, the compiled HLO carries
   exactly one all-gather (pull) per forward segment and one
   reduce-scatter (push) per backward segment: total transfers ==
   2 collectives per (pull, push) segment pair;
3. **consensus scheduling** — the heterogeneous topology's consensus plan
   minimizes the synchronous straggler makespan over the per-worker
   candidates.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import (consensus_decision, plan_from_decision,
                        schedule_topology)
from repro.data.pipeline import SyntheticText
from repro.dist.zero import ZeroTrainer
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw
from repro.ps import PSTopology, PSTrainer, asymmetric_link
from repro.runtime.replan import hlo_collective_counts

B, T, STEPS = 8, 32, 3


def hlo_counts(step, state, batch):
    hlo = step.lower(state, batch).compile().as_text()
    return hlo_collective_counts(hlo)


def main():
    cfg = get_config("granite-3-2b").reduced()
    Ls = num_sched_layers(cfg)
    mesh = Mesh(np.array(jax.devices()).reshape(4,), ("data",))
    pipe = SyntheticText(cfg.vocab_size, T, B, seed=0)
    shape = InputShape("ps-check", T, B, "train")

    # heterogeneous: two fast workers, two slow ones on degraded links
    topo = PSTopology(
        num_servers=2,
        links=(asymmetric_link(10e9, 1e9), asymmetric_link(10e9, 1e9),
               asymmetric_link(2.5e9, 0.25e9), asymmetric_link(2.5e9, 0.25e9)),
        worker_flops=(1e10, 1e10, 2.5e9, 2.5e9))
    topo_costs = topo.topology_costs(layer_profiles(cfg, shape))

    out = {"strategies": {}}
    for strat in ("sequential", "lbl", "ibatch", "dynacomm"):
        decision, makespan = consensus_decision(topo_costs, strat)
        plan = plan_from_decision(*decision, Ls)
        ps = PSTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=adamw(1e-3),
                       topology=topo)
        state = ps.init_state(jax.random.PRNGKey(0))
        step = jax.jit(ps.build_train_step())
        ag, rs = hlo_counts(step, state, pipe.batch(0))
        losses = []
        for i in range(STEPS):
            state, loss = step(state, pipe.batch(i))
            losses.append(float(loss))

        # the reference: the plain ZeRO trainer on the identical plan
        zt = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=adamw(1e-3))
        zstate = zt.init_state(jax.random.PRNGKey(0))
        zstep = jax.jit(zt.build_train_step())
        zlosses = []
        for i in range(STEPS):
            zstate, zloss = zstep(zstate, pipe.batch(i))
            zlosses.append(float(zloss))

        pulls, pushes = ps.expected_transfers
        out["strategies"][strat] = {
            "fwd_segments": pulls, "bwd_segments": pushes,
            "ag": ag, "rs": rs,
            "losses": losses, "zero_losses": zlosses,
            "makespan": makespan,
        }

    # consensus optimality over the per-worker candidate decisions
    candidates = schedule_topology(topo_costs, "dynacomm")
    out["consensus"] = {
        "makespan": out["strategies"]["dynacomm"]["makespan"],
        "candidate_makespans": [topo_costs.makespan(*d) for d in candidates],
    }
    print(json.dumps(out))


if __name__ == "__main__":
    main()
