"""Elastic worker fleets on a deterministic event-queue engine.

The PS regime's async core (``repro.ps.async_mode``) assumes a fixed
worker set; this package is the fleet-scale layer above it:

* :mod:`repro.fleet.engine` — ``EventQueue``, the heap-based
  discrete-event core with stable ``(time, seq, worker)`` tie-breaking
  that the async trainer's loop now runs on, bit-reproducible at
  hundreds-to-thousands of simulated workers;
* :mod:`repro.fleet.membership` — ``FleetSchedule`` of join/leave/fail/
  drift events, failure injection (crash mid-push, silent stall), and
  the ``FleetMembership`` tracker that maps the live worker set onto a
  ``PSTopology``;
* :mod:`repro.fleet.drift` — ``FleetDriftDetector``, per-worker EWMA
  drift detection over *observed* commit gaps (the fleet-scale successor
  of ``core.profiler.EwmaDriftDetector``);
* :mod:`repro.fleet.trainer` — ``FleetTrainer``, the elastic
  bounded-staleness trainer: membership events re-plan through
  ``TopologyScheduler``, the server re-shards without losing versioned
  state, and the whole loop save/restores bit-identically.

``FleetTrainer`` is exported lazily: ``trainer`` imports ``repro.ps``,
which itself imports :mod:`repro.fleet.engine`, so the eager surface of
this package must stay dependency-free to keep the import graph acyclic.
"""

from repro.fleet.engine import Event, EventQueue

__all__ = [
    "Event", "EventQueue",
    "FAIL_MODES", "FLEET_EVENT_KINDS", "FleetEvent", "FleetMembership",
    "FleetSchedule", "WorkerSpec",
    "FleetDriftDetector",
    "FleetReplanEvent", "FleetTrainer", "MembershipChange",
]

_LAZY = {
    "FAIL_MODES": "repro.fleet.membership",
    "FLEET_EVENT_KINDS": "repro.fleet.membership",
    "FleetEvent": "repro.fleet.membership",
    "FleetMembership": "repro.fleet.membership",
    "FleetSchedule": "repro.fleet.membership",
    "WorkerSpec": "repro.fleet.membership",
    "FleetDriftDetector": "repro.fleet.drift",
    "FleetReplanEvent": "repro.fleet.trainer",
    "FleetTrainer": "repro.fleet.trainer",
    "MembershipChange": "repro.fleet.trainer",
}


def __getattr__(name):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module 'repro.fleet' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(module), name)
