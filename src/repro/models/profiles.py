"""Analytic per-sched-layer FLOP counts → LayerProfile vectors.

These feed (a) the DynaComm scheduler's cost vectors in analytic mode and
(b) the roofline's MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) sanity term.
Forward FLOPs are matmul-dominated counts (2·M·N·K per matmul); backward
defaults to 2× forward.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.core.profiler import LayerProfile
from repro.models.moe import expert_capacity
from repro.models.model import sched_layer_bytes


def _attn_flops(cfg: ArchConfig, b: int, t: int, kv_len: int, local: bool) -> float:
    eff_kv = min(kv_len, cfg.sliding_window) if (local and cfg.sliding_window) \
        else kv_len
    proj = 2.0 * b * t * cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
    scores = 4.0 * b * cfg.num_heads * t * eff_kv * cfg.head_dim
    out = 2.0 * b * t * cfg.q_dim * cfg.d_model
    return proj + scores + out


def _mlp_flops(cfg: ArchConfig, b: int, t: int) -> float:
    mats = 3 if cfg.gated_mlp else 2
    return 2.0 * b * t * cfg.d_model * cfg.d_ff * mats


def _moe_flops(cfg: ArchConfig, b: int, t: int) -> float:
    n = b * t
    cap = expert_capacity(n, cfg)
    mats = 3 if cfg.gated_mlp else 2
    router = 2.0 * n * cfg.d_model * cfg.num_experts
    experts = 2.0 * cfg.num_experts * cap * cfg.d_model * cfg.d_ff * mats
    return router + experts


def _mlstm_flops(cfg: ArchConfig, b: int, t: int, quadratic: bool) -> float:
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    hd = di // cfg.num_heads
    proj = 2.0 * b * t * d * di * 2 + 2.0 * b * t * di * (3 * di + 2 * cfg.num_heads)
    cell = 4.0 * b * cfg.num_heads * t * t * hd if quadratic \
        else 6.0 * b * cfg.num_heads * t * hd * hd
    down = 2.0 * b * t * di * d
    return proj + cell + down


def _slstm_flops(cfg: ArchConfig, b: int, t: int) -> float:
    d = cfg.d_model
    return 2.0 * b * t * d * d * 8 + 2.0 * b * t * d * d


def _rglru_flops(cfg: ArchConfig, b: int, t: int) -> float:
    d = cfg.d_model
    w = cfg.rglru_lru_width or d
    proj = 2.0 * b * t * d * w * 2
    conv = 2.0 * b * t * w * 4
    gates = 2.0 * b * t * w * w * 2
    scan = 10.0 * b * t * w
    out = 2.0 * b * t * w * d
    return proj + conv + gates + scan + out


def block_forward_flops(cfg: ArchConfig, kind: str, b: int, t: int,
                        kv_len: int, mode: str) -> float:
    if kind in ("global_attn", "local_attn"):
        f = _attn_flops(cfg, b, t, kv_len, kind == "local_attn")
    elif kind == "mlstm":
        f = _mlstm_flops(cfg, b, t, quadratic=(mode != "decode"))
    elif kind == "slstm":
        f = _slstm_flops(cfg, b, t)
    elif kind == "rglru":
        f = _rglru_flops(cfg, b, t)
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        f += _moe_flops(cfg, b, t) if cfg.is_moe else _mlp_flops(cfg, b, t)
    return f


def layer_profiles(cfg: ArchConfig, shape: InputShape,
                   param_dtype=jnp.float32) -> List[LayerProfile]:
    """One LayerProfile per sched layer (embed, blocks..., head)."""
    b = shape.global_batch
    if shape.mode == "decode":
        t, kv_len = 1, shape.seq_len
    else:
        t = shape.seq_len
        kv_len = shape.seq_len
    pbytes = sched_layer_bytes(cfg, param_dtype)
    kinds = cfg.layer_kinds()

    profs = [LayerProfile(name="embed", param_bytes=pbytes[0],
                          flops_fwd=2.0 * b * t * cfg.d_model)]
    for i, kind in enumerate(kinds):
        profs.append(LayerProfile(
            name=f"block{i}:{kind}",
            param_bytes=pbytes[1 + i],
            flops_fwd=block_forward_flops(cfg, kind, b, t, kv_len, shape.mode),
        ))
    head_flops = 2.0 * b * t * cfg.d_model * cfg.vocab_size
    profs.append(LayerProfile(name="head", param_bytes=pbytes[-1],
                              flops_fwd=head_flops))
    return profs


def model_flops_per_token(cfg: ArchConfig) -> float:
    """The roofline's MODEL_FLOPS/token: 6·N (dense) or 6·N_active (MoE)."""
    from repro.models.model import param_count
    n = param_count(cfg)
    if cfg.is_moe:
        # subtract inactive expert params
        mats = 3 if cfg.gated_mlp else 2
        per_expert = mats * cfg.d_model * cfg.d_ff
        inactive = (cfg.num_experts - cfg.top_k) * per_expert * cfg.num_layers
        n = n - inactive
    return 6.0 * n
