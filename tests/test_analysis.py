"""Tests for ``repro.analysis``: the structured HLO parser over golden
fixtures, schedule-conformance over all four scheduling strategies,
mutation self-tests (corrupted plans / tampered HLO / lying compressors
must be flagged), the AST determinism lints, and the CLI."""

import json
import os
import pathlib
import re
import subprocess
import sys
from types import SimpleNamespace

import pytest

from repro.analysis import (collective_counts, collective_summary,
                            lint_paths, lint_source, parse_hlo, type_bytes,
                            verify_cache, verify_fleet_membership,
                            verify_no_collectives, verify_push_ledger,
                            verify_schedule, verify_wire_model)
from repro.analysis.conformance import (INT8_TILE, expected_ag_bytes,
                                        expected_rs_bytes,
                                        independent_wire_bytes,
                                        segment_wire_bytes)
from repro.analysis.findings import Finding, findings_to_json
from repro.analysis.lints import LintConfig
from repro.core import plan_from_decision, random_costs, schedule
from repro.core.buckets import BucketPlan

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src" / "repro"
FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "hlo"
CONFIGS = REPO / "examples" / "runtime_configs"

STRATEGIES = ("sequential", "lbl", "ibatch", "dynacomm")


def fixture(name):
    return (FIXTURES / name).read_text()


# ---------------------------------------------------------------------------
# HLO parser over golden fixtures (no compile)
# ---------------------------------------------------------------------------

class TestHloParser:

    def test_type_bytes(self):
        assert type_bytes("f32[2,3]{1,0}") == 24
        assert type_bytes("bf16[8,128]") == 2048
        assert type_bytes("f32[]") == 4
        assert type_bytes("pred[7]") == 7
        # tuple types sum all leaves
        assert type_bytes("(f32[4]{0}, f32[8]{0})") == 48
        assert type_bytes("(f32[2,2], s8[3])") == 19

    def test_inline_operands_fixture(self):
        mod = parse_hlo(fixture("inline_operands.txt"))
        counts = collective_counts(mod)
        assert counts == {"all-gather": 1, "all-reduce": 1,
                          "reduce-scatter": 2, "all-to-all": 0,
                          "collective-permute": 0}
        summary = collective_summary(mod)
        assert [b for _, b in summary["all-gather"]] == [4 * 721536]
        assert sorted(b for _, b in summary["reduce-scatter"]) == \
            [4 * 2 * 128, 4 * 2 * 721408]
        assert [b for _, b in summary["all-reduce"]] == [4]
        # non-collective instructions are parsed too
        assert mod.find("fusion")[0].name == "fusion.7"

    def test_bare_operands_resolved_via_defs(self):
        # the second printer style: operands are bare %names whose types
        # come from the defining instruction, even when defined later
        mod = parse_hlo(fixture("bare_operands.txt"))
        summary = collective_summary(mod)
        assert [b for _, b in summary["all-gather"]] == [4 * 16]
        assert [b for _, b in summary["reduce-scatter"]] == [4 * 4 * 8]
        assert [b for _, b in summary["all-reduce"]] == [4]

    def test_async_pairs_count_once(self):
        # -start carries the operand and counts; -done consumes the
        # start's tuple and must not double-count
        mod = parse_hlo(fixture("async_pairs.txt"))
        counts = collective_counts(mod)
        assert counts["all-gather"] == 1
        assert counts["reduce-scatter"] == 1
        assert counts["all-reduce"] == 1
        summary = collective_summary(mod)
        assert [b for _, b in summary["all-gather"]] == [4 * 64]
        assert [b for _, b in summary["reduce-scatter"]] == [4 * 4 * 32]
        assert [b for _, b in summary["all-reduce"]] == [4 * 2 * 2]
        done = [i for i in mod.instructions if i.is_async_done]
        assert len(done) == 3 and not any(i.is_collective for i in done)

    def test_collective_bytes_contract(self):
        # launch.hlo_analysis.collective_bytes keeps its dict contract on
        # top of the structured walker
        from repro.launch.hlo_analysis import collective_bytes
        out = collective_bytes(fixture("async_pairs.txt"))
        assert out["all-gather"] == 4 * 64
        assert out["reduce-scatter"] == 4 * 4 * 32
        assert out["all-reduce"] == 16
        assert out["all-to-all"] == 0
        assert out["_counts"]["all-gather"] == 1
        assert out["_counts"]["reduce-scatter"] == 1


# ---------------------------------------------------------------------------
# schedule conformance over synthesized HLO (single process, no compile)
# ---------------------------------------------------------------------------

def fake_specs(num_layers, axis_size=2, base=256):
    """FlatSpec stand-ins: ``total`` deliberately not axis-aligned so
    ``padded`` differs, exercising the padded-vs-total distinction."""
    specs = []
    for l in range(num_layers):
        total = base * (l + 1) + 3
        padded = -(-total // axis_size) * axis_size
        specs.append(SimpleNamespace(total=total, padded=padded,
                                     axis_size=axis_size))
    return specs


def synth_hlo(specs, plan, *, zero3=False, extra_lines=()):
    """Emit module text with exactly the collectives the plan
    prescribes, using the empirically pinned operand shapes."""
    axis = specs[0].axis_size
    lines = ["HloModule synth", "", "ENTRY %main.1 (p: f32[1,1]) {"]
    n = 0

    def gather(bucket):
        nonlocal n
        n += 1
        shard = sum(specs[l].padded // axis for l in bucket)
        lines.append(
            f"  %all-gather.{n} = f32[{axis},{shard}] "
            f"all-gather(f32[1,{shard}] %concat.{n}), "
            f"replica_groups={{{{0,1}}}}, dimensions={{0}}")

    for bucket in plan.forward:
        gather(bucket)
    if zero3:
        num_layers = len(specs)
        for bucket in plan.backward:
            if any(0 < l < num_layers - 1 for l in bucket):
                gather(bucket)
    for bucket in plan.backward:
        n += 1
        shard = sum(specs[l].padded for l in bucket) // axis
        lines.append(
            f"  %reduce-scatter.{n} = f32[1,{shard}] "
            f"reduce-scatter(f32[{axis},{shard}] %grad.{n}), "
            f"replica_groups={{{{0,1}}}}, dimensions={{0}}, "
            f"to_apply=%sum")
    lines.append("  %all-reduce.loss = f32[] all-reduce(f32[] %l), "
                 "to_apply=%sum")
    lines.extend(extra_lines)
    lines.append("  ROOT %tuple.99 = f32[1,1] copy(f32[1,1] %p)")
    lines.append("}")
    return "\n".join(lines)


def plan_for(strat, num_layers=8):
    costs = random_costs(num_layers, seed=0, dt=1e-3)
    f, b = schedule(costs, strat)
    return plan_from_decision(f, b, num_layers)


class TestConformance:

    @pytest.mark.parametrize("strat", STRATEGIES)
    @pytest.mark.parametrize("zero3", [False, True],
                             ids=["zero", "zero3"])
    def test_all_strategies_conform(self, strat, zero3):
        plan = plan_for(strat)
        specs = fake_specs(8)
        hlo = synth_hlo(specs, plan, zero3=zero3)
        assert verify_schedule(hlo, plan, specs, zero3=zero3) == []

    @pytest.mark.parametrize("strat", STRATEGIES)
    @pytest.mark.parametrize("scheme", ["int8", "topk"])
    def test_ps_wire_model_exact(self, strat, scheme):
        # the repo's own Compressor accounting must match the
        # independent byte formulas exactly, per backward segment
        from repro.compress.compressor import make_compressor
        kwargs = {"topk_fraction": 0.01} if scheme == "topk" else {}
        comp = make_compressor(scheme, **kwargs)
        plan = plan_for(strat)
        specs = fake_specs(8)
        assert verify_wire_model(specs, plan, comp) == []
        hlo = synth_hlo(specs, plan)
        assert verify_schedule(hlo, plan, specs, compressor=comp) == []

    def test_corrupted_plan_flagged(self):
        plan = plan_for("dynacomm")
        specs = fake_specs(8)
        hlo = synth_hlo(specs, plan)
        # merge the first two forward buckets: fewer gathers prescribed
        # than compiled, and the byte multiset shifts
        corrupted = BucketPlan(
            forward=(plan.forward[0] + plan.forward[1],)
            + plan.forward[2:],
            backward=plan.backward)
        findings = verify_schedule(hlo, corrupted, specs)
        assert findings
        assert {f.code for f in findings} <= {
            "SCHED-AG-COUNT", "SCHED-AG-BYTES"}

    def test_tampered_bytes_flagged(self):
        plan = plan_for("sequential")
        specs = fake_specs(8)
        hlo = synth_hlo(specs, plan)
        first_rs = next(line for line in hlo.splitlines()
                        if "reduce-scatter" in line)
        shard = int(re.search(r"f32\[2,(\d+)\]", first_rs).group(1))
        tampered = hlo.replace(
            first_rs, first_rs.replace(f"[2,{shard}]", f"[2,{shard + 7}]"))
        assert tampered != hlo
        codes = {f.code for f in verify_schedule(tampered, plan, specs)}
        assert "SCHED-RS-BYTES" in codes

    def test_stray_collectives_flagged(self):
        plan = plan_for("lbl")
        specs = fake_specs(8)
        hlo = synth_hlo(specs, plan, extra_lines=[
            "  %all-to-all.50 = f32[2,64] all-to-all(f32[2,64] %x.1), "
            "replica_groups={{0,1}}, dimensions={0}",
            "  %all-reduce.51 = f32[1,4096] all-reduce(f32[1,4096] %g.9), "
            "to_apply=%sum",
        ])
        findings = verify_schedule(hlo, plan, specs)
        assert [f.code for f in findings] == ["SCHED-STRAY-COLLECTIVE"] * 2
        flagged = {f.detail["opcode"] for f in findings}
        assert flagged == {"all-to-all", "all-reduce"}

    def test_single_device_only_stray_checks(self):
        # axis_size == 1: XLA elides the plan's collectives, so counts
        # and bytes are skipped — but big stray traffic is still flagged
        plan = plan_for("dynacomm")
        specs = fake_specs(8, axis_size=1)
        assert verify_schedule("HloModule m\nENTRY %e (p: f32[1]) {\n"
                               "  ROOT %p = f32[1] parameter(0)\n}",
                               plan, specs) == []
        big = ("HloModule m\nENTRY %e (p: f32[1]) {\n"
               "  %all-reduce.1 = f32[4096] all-reduce(f32[4096] %g), "
               "to_apply=%sum\n"
               "  ROOT %p = f32[1] parameter(0)\n}")
        codes = {f.code for f in verify_schedule(big, plan, specs)}
        assert codes == {"SCHED-STRAY-COLLECTIVE"}

    def test_verify_no_collectives(self):
        clean = ("HloModule m\nENTRY %e (p: f32[8]) {\n"
                 "  %all-reduce.1 = f32[] all-reduce(f32[] %l), "
                 "to_apply=%sum\n"
                 "  ROOT %p = f32[8] parameter(0)\n}")
        assert verify_no_collectives(clean) == []
        findings = verify_no_collectives(fixture("inline_operands.txt"))
        assert findings
        assert all(f.code == "SCHED-STRAY-COLLECTIVE" for f in findings)

    def test_expected_byte_math(self):
        plan = plan_for("ibatch", num_layers=6)
        specs = fake_specs(6, axis_size=2)
        ag = expected_ag_bytes(specs, plan)
        assert len(ag) == len(plan.forward)
        assert ag[0] == 4 * sum(specs[l].padded // 2
                                for l in plan.forward[0])
        rs = expected_rs_bytes(specs, plan)
        assert len(rs) == len(plan.backward)
        assert rs[-1] == 4 * sum(specs[l].padded
                                 for l in plan.backward[-1])
        extra = expected_ag_bytes(specs, plan, zero3=True)
        mid = sum(1 for b in plan.backward if any(0 < l < 5 for l in b))
        assert len(extra) == len(plan.forward) + mid


class TestWireModel:

    def test_int8_tile_pinned_to_kernel(self):
        # conformance re-derives the int8 layout independently; this pin
        # is the one place the two constants are allowed to meet
        from repro.kernels.compress.ops import TILE
        assert INT8_TILE == TILE

    def test_independent_formulas(self):
        assert independent_wire_bytes(None, 4096.0) == 4096.0
        int8 = SimpleNamespace(scheme="int8")
        n = 4096 / 4
        assert independent_wire_bytes(int8, 4096.0) == n + 4.0 * 2
        topk = SimpleNamespace(scheme="topk", fraction=0.01)
        assert independent_wire_bytes(topk, 4096.0) == 8.0 * 11
        # floor: at least one (index, value) pair
        assert independent_wire_bytes(topk, 4.0) == 8.0

    def test_lying_compressor_flagged(self):
        class Lying:
            scheme = "int8"
            segment_overhead_bytes = 0.0

            def wire_bytes(self, logical_bytes):
                return logical_bytes   # claims no compression happened

        plan = plan_for("dynacomm")
        specs = fake_specs(8)
        findings = verify_wire_model(specs, plan, Lying())
        assert findings
        assert all(f.code == "SCHED-WIRE-BYTES" for f in findings)


# ---------------------------------------------------------------------------
# cache + ledger audits (fake doubles, mutation-style)
# ---------------------------------------------------------------------------

class FakeCache:

    def __init__(self, plans, traces=None, counts=None):
        self.plans = list(plans)
        self.traces = len(self.plans) if traces is None else traces
        self._counts = counts or {}

    def hlo_counts(self, plan):
        if plan in self._counts:
            return self._counts[plan]
        return (len(plan.forward), len(plan.backward))


class TestCacheAudit:

    def test_clean_cache(self):
        plans = [plan_for(s) for s in ("sequential", "dynacomm")]
        assert verify_cache(FakeCache(plans)) == []

    def test_retrace_flagged(self):
        plans = [plan_for("sequential")]
        findings = verify_cache(FakeCache(plans, traces=3))
        assert [f.code for f in findings] == ["SCHED-CACHE-RETRACE"]

    def test_count_mismatch_flagged(self):
        plan = plan_for("lbl")
        cache = FakeCache([plan], counts={plan: (0, 0)})
        findings = verify_cache(cache)
        assert [f.code for f in findings] == ["SCHED-CACHE-COUNTS"]

    def test_single_device_accepts_elided_or_degenerate(self):
        # one device: XLA may elide the collectives or compile them as
        # degenerate ops — both pass, anything else is flagged
        plan = plan_for("lbl")
        specs = fake_specs(8, axis_size=1)
        assert verify_cache(FakeCache([plan], counts={plan: (0, 0)}),
                            specs=specs) == []
        assert verify_cache(FakeCache([plan]), specs=specs) == []
        partial = FakeCache([plan], counts={plan: (1, 0)})
        findings = verify_cache(partial, specs=specs)
        assert [f.code for f in findings] == ["SCHED-CACHE-COUNTS"]


class TestPushLedgerAudit:

    def _setup(self, scheme="int8"):
        from repro.compress.compressor import make_compressor
        kwargs = {"topk_fraction": 0.01} if scheme == "topk" else {}
        comp = make_compressor(scheme, **kwargs) if scheme != "none" \
            else None
        plans = {0: plan_for("dynacomm"), 1: plan_for("sequential")}
        specs = fake_specs(8)
        return comp, plans, specs

    def _ledger_for(self, plans, specs, comp, segments_by_worker):
        pushed, wire, n_push = {}, {}, 0
        for w, nseg in segments_by_worker.items():
            bwd = plans[w].backward
            pushed[w] = sum(
                sum(specs[l].total * 4 for l in bwd[i % len(bwd)])
                for i in range(nseg))
            wire[w] = sum(
                segment_wire_bytes(specs, bwd[i % len(bwd)], comp)
                for i in range(nseg))
            n_push += nseg
        return SimpleNamespace(pushed_bytes=pushed,
                               pushed_wire_bytes=wire,
                               num_pushes=n_push)

    @pytest.mark.parametrize("scheme", ["none", "int8", "topk"])
    def test_clean_ledger(self, scheme):
        comp, plans, specs = self._setup(scheme)
        # worker 0: two full iterations + a partial; worker 1: one full
        nseg = {0: 2 * len(plans[0].backward) + 1,
                1: len(plans[1].backward)}
        ledger = self._ledger_for(plans, specs, comp, nseg)
        assert verify_push_ledger(ledger, plans, specs, comp) == []

    def test_undecomposable_bytes_flagged(self):
        comp, plans, specs = self._setup()
        ledger = self._ledger_for(plans, specs, comp,
                                  {0: len(plans[0].backward)})
        ledger.pushed_bytes[0] += 1
        findings = verify_push_ledger(ledger, plans, specs, comp)
        # the broken decomposition also desyncs the message count
        assert findings
        assert all(f.code == "SCHED-LEDGER" for f in findings)
        assert any("decompose" in f.message for f in findings)

    def test_wire_mismatch_flagged(self):
        comp, plans, specs = self._setup()
        ledger = self._ledger_for(plans, specs, comp,
                                  {0: len(plans[0].backward)})
        ledger.pushed_wire_bytes[0] -= 1
        findings = verify_push_ledger(ledger, plans, specs, comp)
        assert any("wire bytes" in f.message for f in findings)
        assert all(f.code == "SCHED-LEDGER" for f in findings)

    def test_message_count_mismatch_flagged(self):
        comp, plans, specs = self._setup()
        ledger = self._ledger_for(plans, specs, comp, {0: 3, 1: 2})
        ledger.num_pushes += 1
        findings = verify_push_ledger(ledger, plans, specs, comp)
        assert any("push messages" in f.message for f in findings)


class TestElasticLedgerAudit:
    """verify_push_ledger over FleetTrainer-style push *histories*: a
    worker that was re-planned mid-run maps to ``(plan, full_iterations,
    extra_segments)`` entries instead of one plan."""

    def _setup(self, scheme="none"):
        from repro.compress.compressor import make_compressor
        comp = make_compressor(scheme) if scheme != "none" else None
        plan_a, plan_b = plan_for("dynacomm"), plan_for("sequential")
        specs = fake_specs(8)
        return comp, plan_a, plan_b, specs

    def _ledger_for(self, history_by_worker, specs, comp):
        pushed, wire, n_push = {}, {}, 0
        for w, history in history_by_worker.items():
            logical = wb = 0
            for plan, full, extra in history:
                seg_l = [sum(specs[l].total * 4 for l in b)
                         for b in plan.backward]
                seg_w = [segment_wire_bytes(specs, b, comp)
                         for b in plan.backward]
                logical += full * sum(seg_l) + sum(seg_l[:extra])
                wb += full * sum(seg_w) + sum(seg_w[:extra])
                n_push += full * len(seg_l) + extra
            pushed[w], wire[w] = logical, wb
        return SimpleNamespace(pushed_bytes=pushed,
                               pushed_wire_bytes=wire,
                               num_pushes=n_push)

    @pytest.mark.parametrize("scheme", ["none", "int8"])
    def test_clean_history(self, scheme):
        comp, plan_a, plan_b, specs = self._setup(scheme)
        # re-planned after 2 iterations, then crashed 1 segment into an
        # iteration under the new plan — the departed ledger closes
        histories = {0: ((plan_a, 2, 0), (plan_b, 3, 1))}
        ledger = self._ledger_for(histories, specs, comp)
        assert verify_push_ledger(ledger, histories, specs, comp) == []

    def test_mixed_elastic_and_static_workers(self):
        comp, plan_a, plan_b, specs = self._setup()
        histories = {0: ((plan_a, 1, 0), (plan_b, 1, 0)),
                     1: plan_a}          # static worker: one plain plan
        pushed = self._ledger_for({0: histories[0]}, specs, comp)
        seg_l = [sum(specs[l].total * 4 for l in b)
                 for b in plan_a.backward]
        pushed.pushed_bytes[1] = sum(seg_l)
        pushed.pushed_wire_bytes[1] = sum(
            segment_wire_bytes(specs, b, comp) for b in plan_a.backward)
        pushed.num_pushes += len(plan_a.backward)
        assert verify_push_ledger(pushed, histories, specs, comp) == []

    def test_history_byte_mismatch_flagged(self):
        comp, plan_a, plan_b, specs = self._setup()
        histories = {0: ((plan_a, 2, 0), (plan_b, 1, 2))}
        ledger = self._ledger_for(histories, specs, comp)
        ledger.pushed_bytes[0] += 4
        findings = verify_push_ledger(ledger, histories, specs, comp)
        assert findings
        assert all(f.code == "SCHED-LEDGER" for f in findings)
        assert any("push history" in f.message for f in findings)

    def test_history_wire_mismatch_flagged(self):
        comp, plan_a, plan_b, specs = self._setup("int8")
        histories = {0: ((plan_a, 2, 1),)}
        ledger = self._ledger_for(histories, specs, comp)
        ledger.pushed_wire_bytes[0] -= 1
        findings = verify_push_ledger(ledger, histories, specs, comp)
        assert any("wire bytes" in f.message for f in findings)
        assert all(f.code == "SCHED-LEDGER" for f in findings)


class TestFleetMembershipAudit:
    """verify_fleet_membership over crafted run logs + roster history."""

    @staticmethod
    def _event(worker, t, version, staleness):
        return SimpleNamespace(worker=worker, sim_time=t, version=version,
                               result=SimpleNamespace(staleness=staleness))

    @staticmethod
    def _log(events):
        return SimpleNamespace(accepted=list(events))

    def test_clean_run(self):
        log = self._log([
            self._event(0, 0.1, 0, 0),
            self._event(7, 0.6, 5, 1),    # joined at v5, pushes from v5
            self._event(0, 0.7, 6, 2),
        ])
        joined = {0: (0.0, 0), 7: (0.5, 5)}
        departed = {1: (0.4, "crash")}
        assert verify_fleet_membership(log, joined, departed,
                                       staleness_bound=2) == []

    def test_staleness_breach_flagged(self):
        log = self._log([self._event(0, 0.1, 0, 3)])
        findings = verify_fleet_membership(log, {0: (0.0, 0)}, {},
                                           staleness_bound=2)
        assert [f.code for f in findings] == ["FLEET-STALENESS"]

    def test_commit_before_join_flagged(self):
        log = self._log([self._event(7, 0.3, 5, 0)])
        findings = verify_fleet_membership(log, {7: (0.5, 5)}, {},
                                           staleness_bound=2)
        assert [f.code for f in findings] == ["FLEET-MEMBER"]
        assert "before its join" in findings[0].message

    def test_push_older_than_join_version_flagged(self):
        log = self._log([self._event(7, 0.6, 3, 1)])
        findings = verify_fleet_membership(log, {7: (0.5, 5)}, {},
                                           staleness_bound=2)
        assert [f.code for f in findings] == ["FLEET-MEMBER"]
        assert "older than the head at its join" in findings[0].message

    def test_commit_after_departure_flagged(self):
        log = self._log([self._event(1, 0.9, 8, 0)])
        findings = verify_fleet_membership(log, {1: (0.0, 0)},
                                           {1: (0.4, "crash")},
                                           staleness_bound=2)
        assert [f.code for f in findings] == ["FLEET-MEMBER"]
        assert "after its departure" in findings[0].message

    def test_never_joined_flagged(self):
        log = self._log([self._event(9, 0.2, 1, 0)])
        findings = verify_fleet_membership(log, {0: (0.0, 0)}, {},
                                           staleness_bound=2)
        assert [f.code for f in findings] == ["FLEET-MEMBER"]
        assert "never joined" in findings[0].message


# ---------------------------------------------------------------------------
# AST lints: each seeded hazard fires; suppression works; src/ is clean
# ---------------------------------------------------------------------------

def codes(source, path="src/repro/some/module.py", config=None):
    return [f.code for f in lint_source(source, path, config)]


class TestLints:

    def test_global_random_draw(self):
        assert codes("import random\nrandom.random()\n") == ["DET-RANDOM"]
        assert codes("import random\nrandom.shuffle(xs)\n") == \
            ["DET-RANDOM"]

    def test_numpy_global_random(self):
        assert codes("import numpy as np\nnp.random.rand(3)\n") == \
            ["DET-RANDOM"]
        assert codes("import numpy.random as npr\nnpr.standard_normal()\n"
                     ) == ["DET-RANDOM"]

    def test_seeded_constructions_are_safe(self):
        assert codes("import numpy as np\n"
                     "rng = np.random.default_rng(0)\nrng.random()\n") == []
        assert codes("import random\nr = random.Random(0)\n") == []

    def test_unseeded_ctor(self):
        assert codes("import random\nr = random.Random()\n") == \
            ["DET-RANDOM"]
        assert codes("import numpy as np\n"
                     "rng = np.random.default_rng()\n") == ["DET-RANDOM"]

    def test_from_import_draw(self):
        assert codes("from random import random\n") == ["DET-RANDOM"]
        assert codes("from numpy.random import rand\n") == ["DET-RANDOM"]
        assert codes("from random import Random\n") == []

    def test_wall_clock_scoped_to_deterministic_modules(self):
        src = "import time\nt = time.time()\n"
        assert codes(src, path="src/repro/ps/async_mode.py") == \
            ["DET-WALL-CLOCK"]
        assert codes(src, path="src/repro/core/simulator.py") == \
            ["DET-WALL-CLOCK"]
        # the fleet event engine and everything feeding it must stay
        # wall-clock-free (bit-reproducibility at scale)
        for mod in ("engine", "membership", "drift", "trainer"):
            assert codes(src, path=f"src/repro/fleet/{mod}.py") == \
                ["DET-WALL-CLOCK"], mod
        # wall clock is fine in profiling / launch code
        assert codes(src, path="src/repro/launch/bench.py") == []

    def test_wall_clock_datetime_and_from_import(self):
        assert codes("from datetime import datetime\n"
                     "t = datetime.now()\n",
                     path="src/repro/core/simulator.py") == \
            ["DET-WALL-CLOCK"]
        assert codes("from time import monotonic\n",
                     path="src/repro/ps/server.py") == ["DET-WALL-CLOCK"]

    def test_dict_order_walks(self):
        assert codes("for k, v in params.items():\n    pass\n") == \
            ["DET-DICT-ORDER"]
        assert codes("xs = [k for k in grad_tree.keys()]\n") == \
            ["DET-DICT-ORDER"]
        # sorted() canonicalizes the walk
        assert codes("for k in sorted(params.keys()):\n    pass\n") == []
        # non-param-tree dicts are out of scope
        assert codes("for k, v in cache.items():\n    pass\n") == []

    def test_kernel_interpret(self):
        call = "pl.pallas_call(kern, interpret=True)\n"
        assert codes(call, path="src/repro/kernels/foo/foo.py") == \
            ["KERNEL-INTERPRET"]
        assert codes(call, path="src/repro/dist/zero.py") == []
        default = "def op(x, interpret: bool = False):\n    return x\n"
        assert codes(default, path="src/repro/kernels/foo/ops.py") == \
            ["KERNEL-INTERPRET"]
        ok = "def op(x, interpret=None):\n    return x\n"
        assert codes(ok, path="src/repro/kernels/foo/ops.py") == []

    def test_deprecated_alias_imports(self):
        assert codes("from repro.dist.dynamic import PlanStepCache\n") == \
            ["DEPRECATED-IMPORT"]
        assert codes("from repro.ps.dynamic import sequential_plan\n") == \
            ["DEPRECATED-IMPORT"]
        # the classes that still live there are fine
        assert codes("from repro.dist.dynamic import DynamicTrainer\n") == []
        assert codes(
            "from repro.runtime.replan import PlanStepCache\n") == []

    def test_noqa_suppression(self):
        assert codes("import random\nrandom.random()  # noqa\n") == []
        assert codes("import random\n"
                     "random.random()  # noqa: DET-RANDOM\n") == []
        # an unrelated code does not suppress
        assert codes("import random\n"
                     "random.random()  # noqa: DET-DICT-ORDER\n") == \
            ["DET-RANDOM"]

    def test_parse_error_reported(self):
        assert codes("def broken(:\n") == ["PARSE-ERROR"]

    def test_src_tree_is_clean(self):
        # the CI gate: the repo's own sources produce zero findings
        findings = lint_paths([str(SRC)])
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_custom_config_scoping(self):
        cfg = LintConfig(deterministic_modules=("sim/loop.py",),
                         kernel_dirs=("fastpath",))
        assert codes("import time\ntime.time()\n",
                     path="pkg/sim/loop.py", config=cfg) == \
            ["DET-WALL-CLOCK"]
        assert codes("f(interpret=False)\n",
                     path="pkg/fastpath/k.py", config=cfg) == \
            ["KERNEL-INTERPRET"]


# ---------------------------------------------------------------------------
# findings serialization
# ---------------------------------------------------------------------------

class TestFindings:

    def test_json_roundtrip(self):
        fs = [Finding(code="SCHED-AG-COUNT", message="m",
                      detail={"expected": 3, "observed": 2}),
              Finding(code="DET-RANDOM", message="n", severity="warning",
                      path="a.py", line=7)]
        doc = json.loads(findings_to_json(fs, command="lint"))
        assert doc["num_findings"] == 2
        assert doc["num_errors"] == 1
        assert doc["command"] == "lint"
        assert doc["findings"][0]["detail"] == {"expected": 3,
                                                "observed": 2}
        assert doc["findings"][1]["path"] == "a.py"

    def test_format_includes_location(self):
        f = Finding(code="DET-RANDOM", message="msg", path="a.py", line=3)
        assert f.format() == "a.py:3: error[DET-RANDOM] msg"


# ---------------------------------------------------------------------------
# in-process runtime verification (1 device; the subprocess CLI sweep
# below covers the forged-2-device paths)
# ---------------------------------------------------------------------------

class TestVerifyRuntimeInProcess:

    def _verify(self, name, **kwargs):
        from repro.analysis.runtime_verify import verify_runtime
        from repro.runtime.config import RuntimeConfig
        config = RuntimeConfig.load(str(CONFIGS / name))
        findings, info = verify_runtime(config, **kwargs)
        assert findings == [], "\n".join(f.format() for f in findings)
        return info

    def test_local(self):
        info = self._verify("local.json")
        assert info["checked"] == ["no-collectives"]

    def test_static_ps(self):
        info = self._verify("ps.json")
        assert "ledger" in info["checked"]
        assert info["steps_run"] == 1

    def test_dynamic_cache(self):
        info = self._verify("dynamic.json")
        assert info["plans_seen"] >= 1
        assert info["traces"] == info["plans_seen"]

    def test_async_int8_exact_wire(self):
        info = self._verify("ps_async_int8.json")
        assert info["compression"] == "int8"
        assert "push-ledger" in info["checked"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_cli(*args, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=str(REPO))


class TestCli:

    def test_main_in_process(self, tmp_path, capsys):
        # the entry point itself, without a subprocess: lint a hazard,
        # then verify the cheapest config with --devices 0 (leave the
        # already-initialized jax alone)
        from repro.analysis.cli import main
        bad = tmp_path / "bad.py"
        bad.write_text("import random\nrandom.random()\n")
        out_json = tmp_path / "lint.json"
        assert main(["lint", str(bad), "--json", str(out_json)]) == 1
        assert json.loads(out_json.read_text())["num_errors"] == 1
        assert "DET-RANDOM" in capsys.readouterr().out
        assert main(["verify", "--config", str(CONFIGS / "local.json"),
                     "--devices", "0"]) == 0
        assert "no findings" in capsys.readouterr().out

    def test_lint_clean_tree_exits_zero(self, tmp_path):
        out_json = tmp_path / "findings.json"
        res = run_cli("lint", str(SRC), "--json", str(out_json))
        assert res.returncode == 0, res.stdout + res.stderr
        assert "no findings" in res.stdout
        doc = json.loads(out_json.read_text())
        assert doc["num_findings"] == 0
        assert doc["command"] == "lint"

    def test_lint_hazard_exits_nonzero(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("import random\nrandom.random()\n")
        out_json = tmp_path / "findings.json"
        res = run_cli("lint", str(bad), "--json", str(out_json))
        assert res.returncode == 1
        assert "DET-RANDOM" in res.stdout
        doc = json.loads(out_json.read_text())
        assert doc["num_errors"] == 1
        assert doc["findings"][0]["code"] == "DET-RANDOM"

    def test_verify_local_config(self, tmp_path):
        # the cheapest config: single-jit local step, no collectives
        out_json = tmp_path / "verify.json"
        res = run_cli("verify", "--config",
                      str(CONFIGS / "local.json"), "--json", str(out_json))
        assert res.returncode == 0, res.stdout + res.stderr
        doc = json.loads(out_json.read_text())
        assert doc["num_findings"] == 0
        assert doc["command"] == "verify"

    @pytest.mark.slow
    @pytest.mark.parametrize("config", sorted(
        p.name for p in CONFIGS.glob("*.json")))
    def test_verify_all_smoke_configs(self, config, tmp_path):
        out_json = tmp_path / "verify.json"
        res = run_cli("verify", "--config", str(CONFIGS / config),
                      "--json", str(out_json))
        assert res.returncode == 0, res.stdout + res.stderr
        assert json.loads(out_json.read_text())["num_findings"] == 0
