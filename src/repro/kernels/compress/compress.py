"""Pallas kernels: fused gradient compression on the transmission path.

Two payload formats, both extending the ``bucket_pack`` streaming-copy
pattern (scalar-prefetched offsets, grid ``(K, Lmax // TILE)``, scratch
tile redirect for out-of-range programs):

* ``quantize_pack``   — fp32 segments → int8 payload + per-TILE fp32
  scales, in one HBM→VMEM→HBM pass.  Per tile: ``scale = absmax/127``,
  ``q = round(x * 127/absmax)``; the inverse ``dequantize_unpack``
  restores zero-padded (K, Lmax) rows as ``q * scale``.
* ``sparsify``/``densify`` — magnitude top-k payloads.  Index *selection*
  is data-dependent and happens outside the kernel (shared jnp helper in
  ``ops.py`` so kernel and oracle agree bit-exactly); the kernels do the
  bandwidth-bound gather/scatter as one-hot masked reductions, with -1
  index slots self-masking.

Every entry point takes ``interpret=None`` → backend auto-detect via
``repro._compat.pallas.resolve_interpret``.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._compat.pallas import resolve_interpret
from repro.kernels.bucket_pack.bucket_pack import (TILE, _check_aligned_lengths,
                                                   _pack_index_out,
                                                   _unpack_index_in, aligned)

__all__ = ["TILE", "aligned", "quantize_pack_pallas",
           "dequantize_unpack_pallas", "sparsify_pallas", "densify_pallas"]


def _quantize_pack_kernel(offsets_ref, seg_ref, q_ref, scale_ref):
    tile = seg_ref[...]
    absmax = jnp.max(jnp.abs(tile))
    inv = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
    q_ref[...] = jnp.round(tile * inv).astype(jnp.int8)
    scale_ref[...] = jnp.full((1,), absmax / 127.0, seg_ref.dtype)


def _scale_index_out(k, t, offsets_ref):
    # one scale per TILE; out-of-range tiles land in the trailing scratch slot
    base = offsets_ref[k] // TILE
    ntiles = offsets_ref[k + 1] // TILE - base
    in_range = t < ntiles
    return (jnp.where(in_range, base + t, offsets_ref[-1] // TILE),)


def _offsets(aligned_lengths: Sequence[int]) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(aligned_lengths)]).astype(np.int32)


def quantize_pack_pallas(segments: jnp.ndarray,
                         aligned_lengths: Sequence[int], *,
                         interpret: Optional[bool] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(K, Lmax) f32 → (int8 payload (total,), f32 scales (total//TILE,))."""
    interpret = resolve_interpret(interpret)
    if segments.ndim != 2:
        raise ValueError(f"segments must be (K, Lmax), got {segments.shape}")
    if segments.dtype != jnp.float32:
        raise ValueError(f"quantize_pack expects float32 segments, got "
                         f"{segments.dtype}")
    k_count, lmax = segments.shape
    if lmax % TILE:
        raise ValueError(f"segment row length {lmax} is not a multiple of "
                         f"TILE={TILE}")
    _check_aligned_lengths(aligned_lengths, k_count)
    offsets = _offsets(aligned_lengths)
    total = int(offsets[-1])
    ntiles = total // TILE

    grid = (k_count, lmax // TILE)
    payload, scales = pl.pallas_call(
        _quantize_pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((None, TILE), lambda k, t, offs: (k, t))],
            out_specs=[pl.BlockSpec((TILE,), _pack_index_out),
                       pl.BlockSpec((1,), _scale_index_out)],
        ),
        out_shape=[jax.ShapeDtypeStruct((total + TILE,), jnp.int8),
                   jax.ShapeDtypeStruct((ntiles + 1,), segments.dtype)],
        interpret=interpret,
    )(jnp.asarray(offsets), segments)
    return payload[:total], scales[:ntiles]


def _dequantize_unpack_kernel(offsets_ref, q_ref, scale_ref, out_ref):
    k = pl.program_id(0)
    t = pl.program_id(1)
    ntiles = (offsets_ref[k + 1] - offsets_ref[k]) // TILE

    @pl.when(t < ntiles)
    def _():
        out_ref[...] = q_ref[...].astype(out_ref.dtype) * scale_ref[0]

    @pl.when(t >= ntiles)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)


def _scale_index_in(k, t, offsets_ref):
    base = offsets_ref[k] // TILE
    ntiles = offsets_ref[k + 1] // TILE - base
    in_range = t < ntiles
    return (jnp.where(in_range, base + t, 0),)


def dequantize_unpack_pallas(payload: jnp.ndarray, scales: jnp.ndarray,
                             aligned_lengths: Sequence[int], lmax: int, *,
                             interpret: Optional[bool] = None) -> jnp.ndarray:
    """(int8 payload, per-TILE scales) → (K, Lmax) f32 zero-padded rows."""
    interpret = resolve_interpret(interpret)
    if lmax % TILE:
        raise ValueError(f"lmax {lmax} is not a multiple of TILE={TILE}")
    k_count = len(aligned_lengths)
    _check_aligned_lengths(aligned_lengths, k_count)
    offsets = _offsets(aligned_lengths)
    total = int(offsets[-1])
    if payload.shape != (total,):
        raise ValueError(f"payload shape {payload.shape} != ({total},) "
                         f"implied by aligned lengths")
    if scales.shape != (total // TILE,):
        raise ValueError(f"scales shape {scales.shape} != ({total // TILE},) "
                         f"(one per TILE={TILE})")

    grid = (k_count, lmax // TILE)
    out = pl.pallas_call(
        _dequantize_unpack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((TILE,), _unpack_index_in),
                      pl.BlockSpec((1,), _scale_index_in)],
            out_specs=pl.BlockSpec((None, TILE), lambda k, t, offs: (k, t)),
        ),
        out_shape=jax.ShapeDtypeStruct((k_count, lmax), scales.dtype),
        interpret=interpret,
    )(jnp.asarray(offsets), payload, scales)
    return out


def _sparsify_kernel(idx_ref, seg_ref, out_ref):
    idx = idx_ref[...]
    seg = seg_ref[...]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], seg.shape[0]),
                                       1) == idx[:, None]).astype(seg.dtype)
    out_ref[...] = jnp.sum(onehot * seg[None, :], axis=1)


def _check_sparse_shapes(indices: jnp.ndarray, k_count: int) -> None:
    if indices.ndim != 2 or indices.shape[0] != k_count:
        raise ValueError(f"indices must be (K, kmax) with K={k_count}, got "
                         f"{indices.shape}")
    if not jnp.issubdtype(indices.dtype, jnp.integer):
        raise ValueError(f"indices must be integer, got {indices.dtype}")


def sparsify_pallas(segments: jnp.ndarray, indices: jnp.ndarray, *,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Gather (K, kmax) values from (K, Lmax) rows; -1 slots yield 0."""
    interpret = resolve_interpret(interpret)
    if segments.ndim != 2:
        raise ValueError(f"segments must be (K, Lmax), got {segments.shape}")
    k_count, lmax = segments.shape
    _check_sparse_shapes(indices, k_count)
    kmax = indices.shape[1]

    return pl.pallas_call(
        _sparsify_kernel,
        grid=(k_count,),
        in_specs=[pl.BlockSpec((None, kmax), lambda k: (k, 0)),
                  pl.BlockSpec((None, lmax), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((None, kmax), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((k_count, kmax), segments.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), segments)


def _densify_kernel(idx_ref, val_ref, out_ref):
    idx = idx_ref[...]
    val = val_ref[...]
    onehot = (jax.lax.broadcasted_iota(jnp.int32,
                                       (idx.shape[0], out_ref.shape[0]), 1)
              == idx[:, None]).astype(val.dtype)
    out_ref[...] = jnp.sum(onehot * val[:, None], axis=0)


def densify_pallas(values: jnp.ndarray, indices: jnp.ndarray, lmax: int, *,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Scatter (K, kmax) values back to dense (K, lmax); -1 slots drop."""
    interpret = resolve_interpret(interpret)
    if values.ndim != 2:
        raise ValueError(f"values must be (K, kmax), got {values.shape}")
    k_count, kmax = values.shape
    _check_sparse_shapes(indices, k_count)
    if indices.shape != values.shape:
        raise ValueError(f"indices shape {indices.shape} != values shape "
                         f"{values.shape}")

    return pl.pallas_call(
        _densify_kernel,
        grid=(k_count,),
        in_specs=[pl.BlockSpec((None, kmax), lambda k: (k, 0)),
                  pl.BlockSpec((None, kmax), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((None, lmax), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((k_count, lmax), values.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), values)
