"""Pure-jnp oracle for bucket pack/unpack."""

from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp


def bucket_pack_ref(segments: jnp.ndarray, lengths: Sequence[int]
                    ) -> jnp.ndarray:
    """segments: (K, Lmax); lengths[i] <= Lmax → flat (sum(lengths),)."""
    return jnp.concatenate([segments[i, :l] for i, l in enumerate(lengths)])


def bucket_unpack_ref(flat: jnp.ndarray, lengths: Sequence[int],
                      lmax: int) -> jnp.ndarray:
    """flat (sum(lengths),) → (K, Lmax) zero-padded."""
    out, off = [], 0
    for l in lengths:
        seg = flat[off:off + l]
        out.append(jnp.pad(seg, (0, lmax - l)))
        off += l
    return jnp.stack(out)
