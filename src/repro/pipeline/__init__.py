"""repro.pipeline: stage-partitioned pipeline-parallel training.

The DynaComm treatment of pipeline parallelism: stages come from the
same family of DPs as the paper's transmission schedules
(:func:`repro.core.dp.dp_partition`), micro-batch orders are explicit
deterministic event streams (:mod:`repro.pipeline.schedule`), and the
inter-stage activation traffic is scheduled through the *existing*
push/pull cost model — each boundary is a virtual layer stack that
``dp_forward``/``dp_backward`` segment to overlap with stage compute
(:mod:`repro.pipeline.transfer`).  :class:`PipelineTrainer` executes the
result with per-stage jitted applies and losses bit-identical to the
single-device reference.
"""

from repro.pipeline.partition import (StagePartition, partition_loads,
                                      partition_profiles)
from repro.pipeline.schedule import (BACKWARD, FORWARD, SCHEDULES,
                                     PipelineSchedule, PipelineTimeline,
                                     StageTask, analytic_bubble_fraction,
                                     gpipe_schedule, make_schedule,
                                     one_f_one_b_schedule, simulate)
from repro.pipeline.trainer import EMBED_LINK, PipelineTrainer
from repro.pipeline.transfer import (TransferPlan, boundary_costs,
                                     plan_boundary, whole_tensor_decision)

__all__ = [
    "BACKWARD",
    "EMBED_LINK",
    "FORWARD",
    "PipelineSchedule",
    "PipelineTimeline",
    "PipelineTrainer",
    "SCHEDULES",
    "StagePartition",
    "StageTask",
    "TransferPlan",
    "analytic_bubble_fraction",
    "boundary_costs",
    "gpipe_schedule",
    "make_schedule",
    "one_f_one_b_schedule",
    "partition_loads",
    "partition_profiles",
    "plan_boundary",
    "simulate",
    "whole_tensor_decision",
]
