"""Exact brute-force oracle for the Zero-One Integer Programming problem.

Enumerates all 2^(L-1) decomposition decisions per direction and evaluates
``f_m`` for each — the O(L * 2^L) search the paper rules out at scale
(Section III-B) but which serves here as the optimality oracle for the DP
(used by the hypothesis property tests and the §Faithful experiments).
"""

from __future__ import annotations

from typing import Tuple

from repro.core.costmodel import (LayerCosts, Segment, backward_time,
                                  backward_segments_from_g, forward_time,
                                  forward_segments_from_p)

_MAX_L = 18


def _check(L: int) -> None:
    if L > _MAX_L:
        raise ValueError(f"brute force limited to L<={_MAX_L}, got {L}")


def bruteforce_forward(costs: LayerCosts) -> Tuple[Tuple[Segment, ...], float]:
    L = costs.num_layers
    _check(L)
    best_t, best_segs = float("inf"), None
    for mask in range(1 << (L - 1)):
        p = tuple((mask >> i) & 1 for i in range(L - 1))
        segs = forward_segments_from_p(p)
        t = forward_time(costs, segs)
        if t < best_t:
            best_t, best_segs = t, segs
    return best_segs, best_t


def bruteforce_backward(costs: LayerCosts) -> Tuple[Tuple[Segment, ...], float]:
    L = costs.num_layers
    _check(L)
    best_t, best_segs = float("inf"), None
    for mask in range(1 << (L - 1)):
        g = tuple((mask >> i) & 1 for i in range(L - 1))
        segs = backward_segments_from_g(g)
        t = backward_time(costs, segs)
        if t < best_t:
            best_t, best_segs = t, segs
    return best_segs, best_t
