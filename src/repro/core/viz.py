"""ASCII timeline rendering of a scheduled iteration (Fig. 2/3 style).

``render_timeline`` draws the link lane and the compute lane of one phase
as a proportional text Gantt chart — the quickest way to *see* what a
decomposition decision does to the overlap structure.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.costmodel import LayerCosts, Segment, TopologyCosts
from repro.core.simulator import (simulate_backward, simulate_forward,
                                  simulate_ps_iteration)


def _lane(events, t_end: float, width: int, fill: str) -> str:
    lane = [" "] * width
    for e in events:
        lo = int(round(e.start / t_end * (width - 1)))
        hi = max(lo + 1, int(round(e.end / t_end * (width - 1))))
        for i in range(lo, min(hi, width)):
            lane[i] = fill
        if hi - lo >= 3:
            label = f"{e.layers[0]}" if e.layers[0] == e.layers[1] \
                else f"{e.layers[0]}-{e.layers[1]}"
            for j, ch in enumerate(label[:hi - lo - 1]):
                lane[lo + j] = ch
    return "".join(lane)


def render_timeline(costs: LayerCosts, segments: Sequence[Segment], *,
                    phase: str = "forward", width: int = 78) -> str:
    if phase == "forward":
        events, t_end = simulate_forward(costs, segments)
        comm_kind, comp_kind = "pt", "fc"
    else:
        events, t_end = simulate_backward(costs, segments)
        comm_kind, comp_kind = "gt", "bc"
    comm = [e for e in events if e.kind == comm_kind]
    comp = [e for e in events if e.kind == comp_kind]
    lines = [
        f"{phase}: {len(segments)} transmission mini-procedure(s), "
        f"makespan {t_end:.4f}s",
        "link    |" + _lane(comm, t_end, width, "=") + "|",
        "compute |" + _lane(comp, t_end, width, "#") + "|",
    ]
    return "\n".join(lines)


def render_ps_timeline(topo: TopologyCosts, decisions, *,
                       width: int = 78) -> str:
    """Per-worker lanes of one PS iteration, on a shared time axis.

    Each worker gets a link lane (``=`` pulls / pushes, labelled with the
    1-indexed layer range of the segment) and a compute lane (``#``); all
    lanes are normalized to the topology *makespan* so straggling and
    barrier idle time are visible at a glance.  ``decisions`` follows
    :func:`repro.core.simulator.simulate_ps_iteration` (one shared decision
    or one per worker)."""
    tl = simulate_ps_iteration(topo, decisions)
    span = tl.makespan
    lines = [f"PS iteration: {tl.num_workers} worker(s), makespan "
             f"{span:.4f}s (straggler: worker {tl.straggler})"]
    for w, wtl in enumerate(tl.workers):
        fwd, bwd = wtl.forward_events, wtl.backward_events
        # backward events happen after the forward phase on this worker
        shifted = [dataclasses.replace(e, start=e.start + wtl.forward_time,
                                       end=e.end + wtl.forward_time)
                   for e in bwd]
        comm = [e for e in list(fwd) + shifted if e.kind in ("pt", "gt")]
        comp = [e for e in list(fwd) + shifted if e.kind in ("fc", "bc")]
        wait = span - wtl.total
        lines.append(f"worker {w}: iter {wtl.total:.4f}s, barrier wait "
                     f"{wait:.4f}s")
        lines.append("  link    |" + _lane(comm, span, width, "=") + "|")
        lines.append("  compute |" + _lane(comp, span, width, "#") + "|")
    return "\n".join(lines)
