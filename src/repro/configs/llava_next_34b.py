"""llava-next-34b [hf:llava-hf/llava-v1.6-mistral-7b-hf] — anyres tiling.

Language backbone only; the SigLIP/ViT tower + projector is a stub that
supplies precomputed patch embeddings (``num_vision_tokens`` anyres tokens
prepended to the text sequence).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    activation="silu",
    gated_mlp=True,
    frontend="vision",
    num_vision_tokens=2880,   # anyres: 5 tiles x 576 patches
)
