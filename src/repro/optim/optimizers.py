"""SGD(+momentum) and AdamW as pure pytree transformations."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any
Grads = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment / momentum (pytree or None)
    nu: Any          # second moment (pytree or None)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Grads, OptState, Params], Tuple[Params, OptState]]


def _zeros_like_f32(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        mu = _zeros_like_f32(params) if momentum else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)

    def update(grads, state, params):
        def upd(g, p, m):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if momentum:
                m = momentum * m + g
                step_dir = m
            else:
                step_dir = g
            new_p = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
            return new_p, m

        if momentum:
            out = jax.tree_util.tree_map(upd, grads, params, state.mu)
            flat, treedef = jax.tree_util.tree_flatten(
                out, is_leaf=lambda x: isinstance(x, tuple))
            new_params = jax.tree_util.tree_unflatten(
                treedef, [t[0] for t in flat])
            new_mu = jax.tree_util.tree_unflatten(
                treedef, [t[1] for t in flat])
        else:
            new_params = jax.tree_util.tree_map(
                lambda g, p: upd(g, p, None)[0], grads, params)
            new_mu = None
        return new_params, OptState(step=state.step + 1, mu=new_mu, nu=None)

    return Optimizer(init=init, update=update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_zeros_like_f32(params),
                        nu=_zeros_like_f32(params))

    def update(grads, state, params):
        step = state.step + 1
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, p, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / b1c
            vhat = v / b2c
            step_dir = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step_dir = step_dir + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
            return new_p, m, v

        out = jax.tree_util.tree_map(upd, grads, params, state.mu, state.nu)
        flat, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
        new_params = jax.tree_util.tree_unflatten(treedef, [t[0] for t in flat])
        new_mu = jax.tree_util.tree_unflatten(treedef, [t[1] for t in flat])
        new_nu = jax.tree_util.tree_unflatten(treedef, [t[2] for t in flat])
        return new_params, OptState(step=step, mu=new_mu, nu=new_nu)

    return Optimizer(init=init, update=update)
