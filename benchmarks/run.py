"""Benchmark harness: one function per paper table/figure + roofline table.

``python -m benchmarks.run`` prints, per bench, a CSV block
(``name,us_per_call,derived``-style: each row carries the bench name, the
wall time of producing it, and the derived metrics as key=value pairs).

``--json-out FILE`` additionally writes the selected benches as one JSON
document ``{bench: {"elapsed_s": ..., "rows": [...]}}`` — CI uses this to
publish the PS scenario trajectory as a ``BENCH_ps.json`` artifact.
"""

from __future__ import annotations

import argparse
import json
import time


def _print_block(name: str, rows, elapsed_s: float) -> None:
    us = 1e6 * elapsed_s / max(len(rows), 1)
    for row in rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a comma-separated subset of benches by name")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--json-out", default=None,
                    help="also write results as JSON to this path")
    args, _ = ap.parse_known_args()

    from benchmarks.compression import COMPRESSION_BENCHES
    from benchmarks.fleet_churn import FLEET_BENCHES
    from benchmarks.paper_figures import ALL_BENCHES
    from benchmarks.pipeline_overlap import PIPELINE_BENCHES
    from benchmarks.ps_scenarios import PS_BENCHES
    from benchmarks.runtime_matrix import MATRIX_BENCHES
    benches = dict(ALL_BENCHES)
    benches.update(PS_BENCHES)
    benches.update(COMPRESSION_BENCHES)
    benches.update(FLEET_BENCHES)
    benches.update(PIPELINE_BENCHES)
    benches.update(MATRIX_BENCHES)

    if not args.skip_roofline:
        from benchmarks.roofline_report import roofline_rows
        benches["roofline_single_pod"] = \
            lambda: roofline_rows("dryrun_single_pod.jsonl")
        benches["roofline_multi_pod"] = \
            lambda: roofline_rows("dryrun_multi_pod.jsonl")

    selected = None if args.only is None else {
        n.strip() for n in args.only.split(",") if n.strip()}
    if selected:
        unknown = selected - set(benches)
        if unknown:
            raise SystemExit(f"unknown benches {sorted(unknown)}; choose "
                             f"from {sorted(benches)}")

    results = {}
    for name, fn in benches.items():
        if selected and name not in selected:
            continue
        t0 = time.perf_counter()
        rows = fn()
        elapsed = time.perf_counter() - t0
        _print_block(name, rows, elapsed)
        results[name] = {"elapsed_s": round(elapsed, 3), "rows": rows}

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json_out} ({len(results)} benches)")


if __name__ == "__main__":
    main()
