"""Optimizers, checkpointing, data pipeline, train loop, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import SyntheticCIFAR, SyntheticText
from repro.models import init_params, train_loss
from repro.models.cnn import small_cnn_init, small_cnn_loss
from repro.optim import adamw, sgd
from repro.train.loop import TrainLoop, build_train_step


class TestOptimizers:
    def test_sgd_matches_manual(self):
        params = {"w": jnp.array([1.0, 2.0])}
        grads = {"w": jnp.array([0.5, -1.0])}
        opt = sgd(lr=0.1)
        state = opt.init(params)
        new, _ = opt.update(grads, state, params)
        np.testing.assert_allclose(np.asarray(new["w"]), [0.95, 2.1])

    def test_sgd_momentum(self):
        params = {"w": jnp.zeros(1)}
        grads = {"w": jnp.ones(1)}
        opt = sgd(lr=1.0, momentum=0.9)
        state = opt.init(params)
        p1, state = opt.update(grads, state, params)
        p2, state = opt.update(grads, state, p1)
        # v1 = 1, v2 = 1.9 → p = -(1 + 1.9)
        np.testing.assert_allclose(np.asarray(p2["w"]), [-2.9])

    def test_adamw_first_step_is_lr_sized(self):
        params = {"w": jnp.array([0.0])}
        grads = {"w": jnp.array([3.0])}
        opt = adamw(lr=1e-2)
        state = opt.init(params)
        new, _ = opt.update(grads, state, params)
        # bias-corrected first step ≈ lr * sign(g)
        np.testing.assert_allclose(np.asarray(new["w"]), [-1e-2], rtol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(st.floats(1e-4, 1e-1), st.integers(0, 5))
    def test_adamw_descends_quadratic(self, lr, seed):
        key = jax.random.PRNGKey(seed)
        target = jax.random.normal(key, (8,))
        params = {"w": jnp.zeros(8)}
        opt = adamw(lr=lr)
        state = opt.init(params)

        def loss_fn(p):
            return jnp.sum((p["w"] - target) ** 2)

        l0 = float(loss_fn(params))
        for _ in range(50):
            g = jax.grad(loss_fn)(params)
            params, state = opt.update(g, state, params)
        assert float(loss_fn(params)) < l0

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.array([10.0])}
        grads = {"w": jnp.array([0.0])}
        opt = adamw(lr=0.1, weight_decay=0.1)
        state = opt.init(params)
        new, _ = opt.update(grads, state, params)
        assert float(new["w"][0]) < 10.0


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = get_config("granite-3-2b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(1e-3)
        state = {"params": params, "opt": opt.init(params)}
        path = str(tmp_path / "ckpt.npz")
        save_checkpoint(path, state, step=42)
        restored, step = load_checkpoint(path, state)
        assert step == 42
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "c.npz")
        save_checkpoint(path, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            load_checkpoint(path, {"w": jnp.zeros((3, 2))})


class TestTrainLoop:
    def test_loss_descends_small_transformer(self):
        cfg = get_config("granite-3-2b").reduced()
        pipe = SyntheticText(cfg.vocab_size, 32, 8, seed=0)
        loop = TrainLoop(cfg=cfg, optimizer=adamw(1e-3), log_every=0)
        _, _, losses = loop.run(jax.random.PRNGKey(0), iter(pipe),
                                num_steps=20)
        assert losses[-1] < losses[0]

    def test_accum_steps_match_full_batch(self):
        """Gradient accumulation over k microbatches == one big batch."""
        cfg = get_config("granite-3-2b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = sgd(0.1)
        batch = SyntheticText(cfg.vocab_size, 16, 8, seed=1).batch(0)
        s1 = build_train_step(cfg, opt, accum_steps=1, remat=False)
        s4 = build_train_step(cfg, opt, accum_steps=4, remat=False)
        p1, _, l1 = jax.jit(s1)(params, opt.init(params), batch)
        p4, _, l4 = jax.jit(s4)(params, opt.init(params), batch)
        np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)

    def test_remat_matches_no_remat(self):
        cfg = get_config("gemma2-2b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = SyntheticText(cfg.vocab_size, 16, 4, seed=2).batch(0)
        g1 = jax.grad(lambda p: train_loss(cfg, p, batch, remat=False))(params)
        g2 = jax.grad(lambda p: train_loss(cfg, p, batch, remat=True))(params)
        for a, b in zip(jax.tree_util.tree_leaves(g1),
                        jax.tree_util.tree_leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)


class TestServing:
    def test_batched_generate(self):
        from repro.serve.decode import batched_generate
        cfg = get_config("gemma2-2b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0,
                                     cfg.vocab_size)
        out = batched_generate(cfg, params, prompts, max_new_tokens=5)
        assert out.shape == (3, 5)
        assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


class TestSmallCNN:
    def test_cnn_trains(self):
        params = small_cnn_init(jax.random.PRNGKey(0))
        pipe = SyntheticCIFAR(batch_size=16, seed=0)
        opt = sgd(0.05, momentum=0.9)
        state = opt.init(params)

        @jax.jit
        def step(params, state, images, labels):
            loss, grads = jax.value_and_grad(small_cnn_loss)(params, images,
                                                             labels)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        losses = []
        for i in range(20):
            b = pipe.batch(i)
            params, state, loss = step(params, state, b["images"], b["labels"])
            losses.append(float(loss))
        assert losses[-1] < losses[0]
