"""`repro.core.buckets`: decision → bucket-plan round-trip coverage.

The distributed trainer trusts the plan blindly (one collective per group),
so the plan must tile the sched layers exactly: every layer in exactly one
forward bucket (ascending pulls) and one backward bucket (descending
pushes), for any decision a scheduler can emit.
"""

import numpy as np
import pytest

from repro.core import plan_from_decision, random_costs, schedule
from repro.core.buckets import flat_layer_order
from repro.core.costmodel import (backward_segments_from_g,
                                  forward_segments_from_p)


def _assert_exact_tiling(plan, L):
    fwd = flat_layer_order(plan.forward)
    bwd = flat_layer_order(plan.backward)
    assert fwd == tuple(range(L)), fwd
    assert bwd == tuple(range(L - 1, -1, -1)), bwd
    assert len(set(fwd)) == L and len(set(bwd)) == L
    assert plan.num_forward_collectives == len(plan.forward)
    assert plan.num_backward_collectives == len(plan.backward)


class TestPlanFromDecision:
    @pytest.mark.parametrize("L", [1, 2, 17])
    @pytest.mark.parametrize("seed", range(5))
    @pytest.mark.parametrize("strategy",
                             ["sequential", "lbl", "ibatch", "dynacomm"])
    def test_scheduled_decisions_round_trip(self, L, seed, strategy):
        costs = random_costs(L, seed=seed)
        f, b = schedule(costs, strategy)
        _assert_exact_tiling(plan_from_decision(f, b, L), L)

    @pytest.mark.parametrize("L", [1, 2, 17])
    def test_random_cut_vectors_round_trip(self, L):
        """Every legal ZOIP cut vector maps to an exact layer tiling."""
        rng = np.random.default_rng(L)
        for _ in range(25):
            p = rng.integers(0, 2, max(L - 1, 0))
            g = rng.integers(0, 2, max(L - 1, 0))
            plan = plan_from_decision(forward_segments_from_p(p),
                                      backward_segments_from_g(g), L)
            _assert_exact_tiling(plan, L)
            # bucket count == number of cuts + 1
            assert len(plan.forward) == int(np.sum(p)) + 1
            assert len(plan.backward) == int(np.sum(g)) + 1

    def test_dp_decision_buckets_match_dynacomm_trainer_contract(self):
        """The invariant ZeroTrainer._validate_plan relies on: backward
        buckets are descending within and across groups."""
        costs = random_costs(17, seed=3)
        f, b = schedule(costs, "dynacomm")
        plan = plan_from_decision(f, b, 17)
        for group in plan.backward:
            assert list(group) == sorted(group, reverse=True)
        for group in plan.forward:
            assert list(group) == sorted(group)
