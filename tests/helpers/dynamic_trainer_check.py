"""Subprocess helper: multi-device checks for the dynamic re-scheduling loop.

Run with 4 forged host devices.  Scenario: a 10 Gbps → 1 Gbps → 10 Gbps
bandwidth drift over three epochs, analytic cost source (deterministic).
Prints one JSON line the parent asserts on:

1. the DP re-plans to a *different* BucketPlan when the bandwidth drops,
   and back to the original plan when it recovers;
2. the compiled-step cache serves the revisited plan without re-tracing
   (traces == #distinct plans, cache_hits == #revisits);
3. per distinct plan, compiled-HLO all-gather / reduce-scatter counts
   equal the plan's bucket counts;
4. the dynamic run's losses are bit-identical to statically running each
   epoch's plan with ``ZeroTrainer.with_plan`` on the same batches;
5. every epoch boundary records a RescheduleEvent whose scheduling time
   fits the Δt + gt¹ idle window (Table I "overhead hidden").
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import (EdgeNetworkModel, NetworkSchedule, costs_from_profiles,
                        plan_from_decision, schedule)
from repro.data.pipeline import SyntheticText
from repro.dist.dynamic import DynamicTrainer
from repro.dist.zero import ZeroTrainer
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw

BW_HIGH, BW_LOW = 10e9, 1e9
FLOPS = 1e10                 # edge-worker compute rate fed to the profiler
STEPS_PER_EPOCH, EPOCHS = 3, 3
B, T = 8, 32


def main():
    cfg = get_config("granite-3-2b").reduced()
    mesh = Mesh(np.array(jax.devices()).reshape(4,), ("data",))
    pipe = SyntheticText(cfg.vocab_size, T, B, seed=0)
    net = NetworkSchedule(knots=(
        (0, EdgeNetworkModel(bandwidth_bps=BW_HIGH)),
        (1, EdgeNetworkModel(bandwidth_bps=BW_LOW)),
        (2, EdgeNetworkModel(bandwidth_bps=BW_HIGH)),
    ))
    num_steps = STEPS_PER_EPOCH * EPOCHS

    dyn = DynamicTrainer(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                         network=net, steps_per_epoch=STEPS_PER_EPOCH,
                         compute_flops_per_s=FLOPS)
    state = dyn.init_state(jax.random.PRNGKey(0))
    state, losses_dyn = dyn.run(state, pipe.batch, num_steps)

    plans = []
    for plan in dyn.plans_seen:
        ag, rs = dyn.hlo_counts(plan)
        plans.append({"fwd": len(plan.forward), "bwd": len(plan.backward),
                      "ag": ag, "rs": rs})

    events = [{"step": e.step, "epoch": e.epoch,
               "fwd": len(e.plan.forward), "bwd": len(e.plan.backward),
               "changed": e.plan_changed, "retraced": e.retraced,
               "hidden": e.overhead_hidden,
               "sched_s": e.scheduling_seconds}
              for e in dyn.events]

    # ---- static reference: same plan sequence, one ZeroTrainer per epoch --
    shape = InputShape("dynamic", T, B, "train")
    profs = layer_profiles(cfg, shape)
    Ls = num_sched_layers(cfg)

    def plan_for(epoch):
        costs = costs_from_profiles(profs, net=net.model_at(epoch),
                                    compute_flops_per_s=FLOPS)
        return plan_from_decision(*schedule(costs, "dynacomm"), Ls)

    base = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan_for(0),
                       optimizer=adamw(1e-3))
    state_s = base.init_state(jax.random.PRNGKey(0))
    losses_static = []
    step_fns = {}
    for epoch in range(EPOCHS):
        plan = plan_for(epoch)
        if plan not in step_fns:
            step_fns[plan] = jax.jit(base.with_plan(plan).build_train_step())
        for i in range(epoch * STEPS_PER_EPOCH,
                       (epoch + 1) * STEPS_PER_EPOCH):
            state_s, loss = step_fns[plan](state_s, pipe.batch(i))
            losses_static.append(float(loss))

    print(json.dumps({
        "losses_dyn": losses_dyn, "losses_static": losses_static,
        "traces": dyn.traces, "cache_hits": dyn.cache_hits,
        "plans": plans, "events": events,
    }))


if __name__ == "__main__":
    main()
