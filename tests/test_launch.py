"""Launch layer: HLO collective parsing, roofline math, mesh builders,
input specs, and a real (subprocess) dry-run smoke."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.hlo_analysis import (Roofline, collective_bytes,
                                       cost_analysis_dict, roofline)
from repro.launch.specs import decode_specs, input_specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HLO_SAMPLE = """
HloModule test
ENTRY %main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %p1 = f32[64]{0} parameter(1)
  %ag = bf16[512,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[64]{0} all-reduce(%p1), to_apply=%add
  %rs = f32[16]{0} reduce-scatter(%p1), dimensions={0}
  %cp = bf16[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %ag2 = bf16[512,256]{1,0} all-gather-start(%p0), dimensions={0}
}
"""


class TestCollectiveBytes:
    def test_parses_operand_bytes(self):
        out = collective_bytes(HLO_SAMPLE)
        p0 = 128 * 256 * 2
        p1 = 64 * 4
        assert out["all-gather"] == 2 * p0      # ag + ag-start
        assert out["all-reduce"] == p1
        assert out["reduce-scatter"] == p1
        assert out["collective-permute"] == p0
        assert out["_counts"]["all-gather"] == 2

    def test_empty(self):
        out = collective_bytes("HloModule empty")
        assert sum(v for k, v in out.items() if not k.startswith("_")) == 0


class TestRoofline:
    def test_terms_and_dominant(self):
        rl = roofline(flops=197e12, hbm_bytes=819e9 / 2,
                      coll={"all-gather": int(50e9 // 4)}, chips=256)
        assert rl.compute_s == pytest.approx(1.0)
        assert rl.memory_s == pytest.approx(0.5)
        assert rl.collective_s == pytest.approx(0.25)
        assert rl.dominant == "compute"
        assert rl.bound_time == pytest.approx(1.0)


class TestSpecs:
    @pytest.mark.parametrize("arch", ["granite-3-2b", "llava-next-34b",
                                      "hubert-xlarge", "xlstm-350m"])
    def test_input_specs_shapes(self, arch):
        cfg = get_config(arch)
        shape = INPUT_SHAPES["train_4k"]
        specs = input_specs(cfg, shape)
        assert "labels" in specs
        if cfg.frontend == "audio":
            assert specs["frames"].shape == (256, 4096, cfg.d_model)
        elif cfg.frontend == "vision":
            nv = min(cfg.num_vision_tokens, 4095)
            assert specs["vision_embeds"].shape == (256, nv, cfg.d_model)
            assert specs["tokens"].shape == (256, 4096 - nv)
        else:
            assert specs["tokens"].shape == (256, 4096)

    def test_decode_specs_cache_sizes(self):
        cfg = get_config("gemma2-2b")
        token, caches = decode_specs(cfg, INPUT_SHAPES["decode_32k"])
        assert token.shape == (128, 1)
        assert len(caches) == cfg.num_layers
        from repro.models.attention import KVCache
        for kind, c in zip(cfg.layer_kinds(), caches):
            assert isinstance(c, KVCache)
            want = cfg.sliding_window if kind == "local_attn" else 32768
            assert c.k.shape == (128, want, cfg.num_kv_heads, cfg.head_dim)

    def test_no_allocation(self):
        """Specs must be ShapeDtypeStructs, never device arrays."""
        cfg = get_config("grok-1-314b")
        specs = input_specs(cfg, INPUT_SHAPES["train_4k"])
        for leaf in jax.tree_util.tree_leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


class TestAnalyticFlopsMatchUnrolledHLO:
    def test_dense_block_flops_within_20pct(self):
        """The §Roofline methodology: analytic per-layer FLOPs track XLA's
        cost analysis on an *unrolled* single-device lowering."""
        import dataclasses
        from repro.models import init_params, train_loss
        from repro.models.profiles import layer_profiles
        from repro.configs.base import InputShape

        cfg = dataclasses.replace(
            get_config("granite-3-2b").reduced(num_layers=2, d_model=256),
            vocab_size=512)
        shape = InputShape("t", 128, 4, "train")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((4, 128), jnp.int32),
                 "labels": jnp.zeros((4, 128), jnp.int32)}
        lowered = jax.jit(
            lambda p, b: train_loss(cfg, p, b)).lower(params, batch)
        cost = cost_analysis_dict(lowered.compile())
        hlo_flops = float(cost.get("flops", 0))
        analytic_fwd = sum(p.flops_fwd for p in layer_profiles(cfg, shape))
        assert hlo_flops > 0
        # Empirically XLA-CPU cost_analysis attributes ≈ the FORWARD dots
        # only (backward fusion flops unreported) — which is why §Roofline
        # uses max(HLO, analytic).  Assert the forward-side agreement.
        ratio = hlo_flops / analytic_fwd
        assert 0.8 < ratio < 1.3, f"analytic fwd model off: ratio {ratio}"


@pytest.mark.slow
class TestDryRunSubprocess:
    def test_one_combo_compiles_with_512_devices(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "granite-moe-1b-a400m", "--shape", "decode_32k"],
            capture_output=True, text=True, env=env, timeout=900, cwd=REPO)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "[ok] granite-moe-1b-a400m x decode_32k" in proc.stdout

    def test_skip_policy_is_reported(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "hubert-xlarge", "--shape", "long_500k"],
            capture_output=True, text=True, env=env, timeout=300, cwd=REPO)
        assert proc.returncode == 0
        assert "[skip] hubert-xlarge x long_500k" in proc.stdout
