"""Training launcher: a thin client of the ``repro.runtime`` registry.

Every regime is one :class:`repro.runtime.RuntimeConfig` built through
:func:`repro.runtime.build_runtime` — the flags below are nothing but an
argparse → config mapping, and ``--config runtime.json`` bypasses them
entirely (``--dump-config`` prints the equivalent JSON for any flag
combination, which is exactly what the smoke configs under
``examples/runtime_configs/`` contain).

Runtimes (``--runtime``, ``--staleness k`` switches the ps variants to
their asynchronous form):

* ``local`` (default) — single-process jit training on whatever devices
  exist; reduced configs runnable on CPU.
* ``zero`` — the DynaComm-bucketed ZeRO trainer over a 1-D data mesh,
  schedule chosen by ``--strategy``; the plan is decided once at startup.
* ``dynamic`` — the run-time loop (paper Section IV-C): re-plan every
  ``--steps-per-epoch`` steps against the active network model, swap
  compiled steps when the decision changes.  ``--bw-shift-gbps`` scripts
  a bandwidth drift; ``--drift-detect`` re-schedules from *observed* step
  times instead.
* ``ps`` — the parameter-server subsystem: ``--ps-servers`` shards behind
  asymmetric ``--down-gbps``/``--up-gbps`` links, consensus-planned.
  With ``--staleness k``: bounded-staleness asynchronous execution
  (``--throttle reject|wait``; ``--aggregate`` commits same-version
  pushes as one BSP step).
* ``dynamic-ps`` — the run-time loop in the PS regime over a
  time-varying topology (``--up-shift-gbps`` degrades every uplink at
  ``--shift-epoch``); with ``--staleness k``, per-worker re-plans swapped
  into the async event loop.
* ``fleet-async`` — elastic membership over the deterministic event
  engine: ``--fleet-schedule events.json`` scripts joins/leaves/failures/
  drift (a JSON list of fleet event dicts), each membership change
  re-plans every surviving worker and re-shards the server.
* ``pipeline`` — stage-partitioned pipeline parallelism: ``--stages S``
  contiguous stages balanced by profiled fc+bc, ``--microbatches M``
  micro-batches per step under ``--pipeline-schedule`` (gpipe | 1f1b),
  with inter-stage activations crossing each boundary as
  DynaComm-scheduled segments (``--transfer-chunks`` splits each
  micro-batch's boundary tensor for finer overlap).

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --reduced --runtime zero --strategy dynacomm --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --runtime dynamic --steps 60 --steps-per-epoch 20 \
        --bw-gbps 10 --bw-shift-gbps 1 --shift-epoch 1
    PYTHONPATH=src python -m repro.launch.train \
        --config examples/runtime_configs/dynamic_ps.json --steps 12
"""

from __future__ import annotations

import argparse
import time

from repro.configs import ARCHITECTURES
from repro.runtime import (CompressionConfig, ExecutionConfig, FleetConfig,
                           MeasureConfig, NetworkConfig, PipelineConfig,
                           RuntimeConfig, ScheduleConfig, TopologyConfig,
                           build_runtime)


def config_from_flags(args) -> RuntimeConfig:
    """The argparse → RuntimeConfig mapping (the whole launcher logic)."""
    name = args.runtime
    if args.staleness is not None and name in ("ps", "dynamic-ps"):
        name += "-async"

    network = topology = None
    if name in ("zero", "dynamic", "pipeline"):
        # pass the shift through even for 'zero': RuntimeConfig owns the
        # "a drift needs the run-time loop" diagnostic
        network = NetworkConfig(
            bandwidth_gbps=args.bw_gbps,
            shift_gbps=args.bw_shift_gbps,
            shift_epoch=args.shift_epoch)
    elif name != "local":
        up_shift = None
        if args.up_shift_gbps is not None:
            if args.up_shift_gbps <= 0:
                raise SystemExit(f"--up-shift-gbps must be positive, got "
                                 f"{args.up_shift_gbps}")
            up_shift = args.up_gbps / args.up_shift_gbps
        topology = TopologyConfig(
            servers=args.ps_servers,
            workers=args.ps_workers if name.endswith("async") else None,
            down_gbps=args.down_gbps, up_gbps=args.up_gbps,
            worker_flops=args.worker_flops,
            up_shift_factor=up_shift, shift_epoch=args.shift_epoch)

    fleet = None
    if args.fleet_schedule is not None and name != "fleet-async":
        raise SystemExit("--fleet-schedule scripts elastic membership; it "
                         "needs --runtime fleet-async")
    if name == "fleet-async":
        events = ()
        if args.fleet_schedule is not None:
            import json
            with open(args.fleet_schedule) as fh:
                events = tuple(json.load(fh))
        fleet = FleetConfig(events=events,
                            workers_per_shard=args.workers_per_shard)

    pipeline = None
    stages = getattr(args, "stages", None)
    microbatches = getattr(args, "microbatches", None)
    if name == "pipeline":
        pipeline = PipelineConfig(
            stages=stages or 2, microbatches=microbatches or 2,
            schedule=getattr(args, "pipeline_schedule", "1f1b"),
            chunks=getattr(args, "transfer_chunks", 1))
    elif stages is not None or microbatches is not None:
        raise SystemExit("--stages/--microbatches configure the pipeline "
                         "runtime; add --runtime pipeline")

    return RuntimeConfig(
        runtime=name, arch=args.arch, reduced=args.reduced,
        fleet=fleet, pipeline=pipeline,
        batch=args.batch, seq=args.seq,
        optimizer=args.optimizer, lr=args.lr,
        schedule=ScheduleConfig(
            strategy=args.strategy,
            reschedule_every=args.steps_per_epoch,
            drift_detect=args.drift_detect,
            async_planning=args.async_planning,
            plan_cache_size=args.plan_cache_size,
            network=network, topology=topology),
        execution=ExecutionConfig(
            staleness=args.staleness, throttle=args.throttle,
            aggregate=args.aggregate),
        measure=MeasureConfig(
            cost_source=args.cost_source,
            compute_flops_per_s=args.worker_flops),
        compression=CompressionConfig(
            scheme=args.compress,
            topk_fraction=(args.topk_fraction
                           if args.compress == "topk" else None),
            error_feedback=not args.no_error_feedback))


def _print_events(rt) -> None:
    for e in rt.events:
        if hasattr(e, "resharded"):          # fleet re-plan
            reshard = f" resharded→{e.num_servers} shards " \
                      f"({e.migrated_bytes / 1e6:.1f} MB moved)" \
                      if e.resharded else ""
            print(f"t={e.sim_time:8.3f} @push {e.at_push:4d}: re-plan "
                  f"({e.reason}, worker {e.worker}) — {e.num_workers} "
                  f"workers, "
                  f"{'re-segmented' if e.plan_changed else 'unchanged'}"
                  f"{reshard}  sched {e.scheduling_seconds * 1e3:.2f} ms "
                  f"hidden={e.overhead_hidden}")
        elif hasattr(e, "fleet_size"):       # fleet membership change
            print(f"t={e.sim_time:8.3f}: {e.kind} worker {e.worker} "
                  f"(fleet size {e.fleet_size})")
        elif hasattr(e, "worker_plans"):     # async per-worker re-plan
            segs = [(len(p.forward), len(p.backward))
                    for p in e.worker_plans]
            print(f"epoch {e.epoch:3d} @push {e.at_push:4d}: per-worker "
                  f"pull/push segments {segs}  "
                  f"{'re-segmented' if e.plan_changed else 'unchanged'}  "
                  f"sched {e.scheduling_seconds * 1e3:.2f} ms "
                  f"hidden={e.overhead_hidden}")
        else:                                # sync RescheduleEvent
            extra = ""
            if hasattr(rt.trainer, "hlo_counts"):
                ag, rs = rt.trainer.hlo_counts(e.plan)
                extra = f" (hlo {ag} ag / {rs} rs)"
            print(f"epoch {e.epoch:3d} step {e.step:4d}: "
                  f"{len(e.plan.forward)} pull / {len(e.plan.backward)} "
                  f"push segments{extra}  "
                  f"{'re-segmented' if e.plan_changed else 'unchanged'}"
                  f"{' [cache hit]' if e.plan_changed and not e.retraced else ''}"
                  f"  sched {e.scheduling_seconds * 1e3:.2f} ms "
                  f"hidden={e.overhead_hidden}")
    tr = getattr(rt, "trainer", None)
    if tr is not None and hasattr(tr, "traces"):
        print(f"[{rt.config.runtime}] traces {tr.traces}, "
              f"cache hits {tr.cache_hits}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None,
                    help="build the runtime from this RuntimeConfig JSON "
                         "file instead of the flags below")
    ap.add_argument("--dump-config", action="store_true",
                    help="print the RuntimeConfig JSON for these flags "
                         "and exit")
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES),
                    default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--runtime",
                    choices=("local", "zero", "dynamic", "ps", "ps-async",
                             "dynamic-ps", "dynamic-ps-async",
                             "fleet-async", "pipeline"),
                    default="local",
                    help="registry name; --staleness k still upgrades "
                         "ps/dynamic-ps to their -async form")
    ap.add_argument("--strategy", default="dynacomm",
                    choices=("sequential", "lbl", "ibatch", "dynacomm"))
    # scheduling knobs (zero + dynamic runtimes)
    ap.add_argument("--steps-per-epoch", type=int, default=20,
                    help="re-scheduling interval of the dynamic runtimes")
    ap.add_argument("--bw-gbps", type=float, default=10.0,
                    help="edge uplink bandwidth (Gbit/s)")
    ap.add_argument("--bw-shift-gbps", type=float, default=None,
                    help="drift the uplink to this bandwidth at --shift-epoch")
    ap.add_argument("--shift-epoch", type=int, default=1)
    ap.add_argument("--async-planning", action="store_true",
                    help="pre-plan epoch e+1's decision during epoch e "
                         "(the paper's gt¹ idle window); decisions stay "
                         "bit-identical, only where they are computed "
                         "moves")
    ap.add_argument("--plan-cache-size", type=int, default=256,
                    help="memoized (strategy, costs) -> decision entries "
                         "kept by the planner (LRU)")
    ap.add_argument("--cost-source", choices=("analytic", "measured"),
                    default="analytic")
    ap.add_argument("--drift-detect", action="store_true",
                    help="dynamic runtime: also re-schedule when observed "
                         "step times drift (EWMA detector)")
    # parameter-server knobs (ps runtimes)
    ap.add_argument("--ps-servers", type=int, default=2,
                    help="number of server shards")
    ap.add_argument("--ps-workers", type=int, default=None,
                    help="async mode only: logical worker count "
                         "(sync mode runs one worker per device)")
    ap.add_argument("--down-gbps", type=float, default=10.0,
                    help="server→worker (pull) bandwidth per link")
    ap.add_argument("--up-gbps", type=float, default=1.0,
                    help="worker→server (push) bandwidth per link")
    ap.add_argument("--staleness", type=int, default=None,
                    help="bounded-staleness k: switch the ps runtimes to "
                         "asynchronous execution")
    ap.add_argument("--throttle", choices=("reject", "wait"),
                    default="reject",
                    help="async ps: evict stale pushes (reject) or SSP "
                         "wait-at-barrier (wait)")
    ap.add_argument("--aggregate", action="store_true",
                    help="async ps wait throttle: commit same-version "
                         "pushes as one BSP step")
    ap.add_argument("--up-shift-gbps", type=float, default=None,
                    help="dynamic-ps: degrade every uplink to this "
                         "bandwidth at --shift-epoch")
    ap.add_argument("--fleet-schedule", default=None,
                    help="fleet-async: JSON file holding a list of fleet "
                         "event dicts (time/kind/worker/...) to script "
                         "membership churn")
    ap.add_argument("--workers-per-shard", type=int, default=0,
                    help="fleet-async: let the shard count track the "
                         "fleet size (0 keeps --ps-servers fixed)")
    ap.add_argument("--worker-flops", type=float, default=1e10,
                    help="edge-worker compute rate fed to the profiler")
    # pipeline knobs (pipeline runtime)
    ap.add_argument("--stages", type=int, default=None,
                    help="pipeline: number of contiguous stages (DP-"
                         "balanced by profiled fc+bc; default 2)")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="pipeline: micro-batches per step (must divide "
                         "--batch; default 2)")
    ap.add_argument("--pipeline-schedule", choices=("gpipe", "1f1b"),
                    default="1f1b",
                    help="pipeline: micro-batch order (GPipe fill/drain "
                         "or PipeDream-flush 1F1B)")
    ap.add_argument("--transfer-chunks", type=int, default=1,
                    help="pipeline: boundary-tensor chunks per micro-batch "
                         "for DynaComm-segmented activation transfers")
    ap.add_argument("--compress", choices=("none", "int8", "topk"),
                    default="none",
                    help="ps runtimes: compress gradient pushes (int8 "
                         "per-tile quantization or top-k sparsification)")
    ap.add_argument("--topk-fraction", type=float, default=0.01,
                    help="fraction of entries kept by --compress topk")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable error-feedback residual accumulation "
                         "on compressed pushes")
    ap.add_argument("--steps", type=int, default=100,
                    help="units of progress to run (must be >= 1)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=("adamw", "sgd"), default="adamw")
    ap.add_argument("--checkpoint", default=None,
                    help="save the runtime state here every "
                         "--checkpoint-every units and after training")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.config is not None:
        config = RuntimeConfig.load(args.config)
    else:
        config = config_from_flags(args)
    if args.dump_config:
        print(config.to_json())
        return
    if args.steps < 1:
        raise SystemExit(f"--steps must be >= 1, got {args.steps}")

    from repro.configs import get_config
    if get_config(config.arch).frontend != "none":
        raise SystemExit("train.py drives text archs; stubbed-modality "
                         "archs are exercised via the dry-run and tests")

    rt = build_runtime(config)
    spec = f"[{config.runtime}] arch {config.arch}" + \
        (" (reduced)" if config.reduced else "") + \
        f", strategy {config.schedule.strategy}"
    if config.regime == "ps-async":
        spec += (f", k={config.execution.staleness or 0} "
                 f"({config.execution.throttle}"
                 f"{'+aggregate' if config.execution.aggregate else ''})")
    if config.runtime == "fleet-async" and config.fleet is not None:
        spec += f", fleet events {len(config.fleet.events)}" \
            if config.fleet.events else \
            f", fleet churn {config.fleet.churn}/s"
    if config.runtime == "pipeline":
        spec += (f", S={config.pipeline.stages} "
                 f"M={config.pipeline.microbatches} "
                 f"({config.pipeline.schedule})")
    print(spec)

    t0 = time.perf_counter()
    losses = []
    # periodic checkpointing now rides inside fit(); the outer loop only
    # chunks by the logging cadence for the wall-clock progress line
    while len(losses) < args.steps:
        chunk = min(args.log_every or args.steps, args.steps - len(losses))
        losses.extend(rt.fit(
            chunk,
            checkpoint_every=(args.checkpoint_every if args.checkpoint
                              else 0),
            checkpoint_path=args.checkpoint))
        if args.log_every:
            dt = (time.perf_counter() - t0) / max(len(losses), 1)
            print(f"step {len(losses):4d}  loss {losses[-1]:.4f}  "
                  f"{dt:.3f}s/step")

    _print_events(rt)
    led = rt.ledger
    print(f"[{config.runtime}] {len(losses)} units, final loss "
          f"{losses[-1]:.4f}; transfers: "
          f"{led['pull_bytes'] / 1e6:.1f} MB down / "
          f"{led['push_bytes'] / 1e6:.1f} MB up "
          f"({led['num_pulls']} pulls, {led['num_pushes']} pushes)")
    if config.compression.enabled:
        print(f"[{config.runtime}] push wire "
              f"{led['push_wire_bytes'] / 1e6:.1f} MB "
              f"({config.compression.scheme}, "
              f"{led['push_compression_ratio']:.2f}x vs fp32)")
    if args.checkpoint:
        rt.save_state(args.checkpoint)
        print(f"saved runtime state to {args.checkpoint}")


if __name__ == "__main__":
    main()
