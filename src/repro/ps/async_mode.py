"""Bounded-staleness asynchronous PS execution.

Synchronous mode (``repro.ps.worker.PSTrainer``) pays the straggler at
every barrier; this module removes the barrier: each worker pulls a
parameter snapshot, computes gradients *against that version*, and pushes
— every *applied* gradient's staleness (head version at commit minus the
version it was computed at) is bounded by ``k``.  Two throttle
disciplines enforce the bound:

* ``throttle="reject"`` — the server-side gate of PR 3: a push staler
  than ``k`` at commit time is evicted and the worker re-pulls the head
  and recomputes.  Simple, but fast workers advance the head while a slow
  worker computes, so a worker ~W× slower than the rest can be rejected
  *every* time at small ``k`` — it never contributes (the starvation
  regression test pins this down).
* ``throttle="wait"`` — Stale Synchronous Parallel wait-at-barrier
  semantics: nobody's gradients are ever dropped; instead the *fast*
  side blocks.  Two gates in the discrete-event loop:

  1. **admission** — a worker may start a new pull+compute only while at
     most ``k`` other computations are in flight (uncommitted), because
     under global versioning every in-flight computation is a future head
     increment: admitting a (k+2)-th concurrent computation would force
     some commit beyond the bound;
  2. **commit barrier** — a completed computation commits only once its
     pinned version is the *minimum* over all in-flight computations;
     fresher completions wait at the barrier until the laggard commits
     (ties drain in completion order, then worker id).

  Together these guarantee every push is accepted with staleness <= k and
  every worker — however slow — eventually contributes; ``k=0``
  degenerates to fully-serialized sequential SGD, exactly as in reject
  mode, but with waiting instead of wasted recomputation.

Execution is a deterministic discrete-event simulation driven by the
topology's per-worker costs: each worker's pull → compute → push latency
comes from its own ``LayerCosts`` under its ``BucketPlan`` (via
``core.simulator``), the :class:`repro.fleet.engine.EventQueue` orders
completions by ``(simulated time, insertion seq, worker id)`` — the
fleet-grade deterministic core — and gradient math runs for real
through one jitted
``value_and_grad`` shared by all workers — so runs are reproducible
bit-for-bit and the staleness trace is machine-checkable, while losses
come from actually training the model (the smoke-CNN convergence test).

Plans may differ per worker (the asynchronous planning mode of
``core.scheduler.schedule_topology``: each worker overlaps its own link
with its own compute, so the optimal decomposition is per-worker); pass a
sequence of ``BucketPlan``s, one per worker, instead of a single shared
plan.  ``set_plans`` swaps plans between (not during) event-loop runs —
the ``repro.ps.dynamic`` driver uses this on topology-epoch boundaries.

The trainer is generic over "a model whose parameters are a list of
per-layer pytrees + a loss function": the smoke CNN
(``repro.models.cnn``) and the text archs (``sched_layer_trees`` +
``train_loss``) both fit.
"""

from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp

from repro.core.buckets import BucketPlan, decision_from_plan
from repro.core.costmodel import TopologyCosts, iteration_time
from repro.dist.collectives import (FlatSpec, flatten_tree, make_flat_spec,
                                    unflatten_tree)
from repro.fleet.engine import EventQueue
from repro.optim import Optimizer
from repro.ps.server import PSServer, PushResult, StaleVersion
from repro.ps.topology import PSTopology

THROTTLES = ("reject", "wait")


@dataclasses.dataclass(frozen=True)
class AsyncPushEvent:
    """One committed (accepted or rejected) push, in commit order."""

    worker: int
    sim_time: float           # simulated seconds at commit
    version: int              # version the gradients were computed at
    result: PushResult
    loss: float
    retries: int              # stale rejections before this commit
    wait_s: float = 0.0       # wait throttle: seconds blocked at the barrier


@dataclasses.dataclass
class AsyncRunLog:
    events: List[AsyncPushEvent] = dataclasses.field(default_factory=list)

    @property
    def accepted(self) -> List[AsyncPushEvent]:
        return [e for e in self.events if e.result.accepted]

    @property
    def losses(self) -> List[float]:
        return [e.loss for e in self.accepted]

    @property
    def max_staleness(self) -> int:
        return max((e.result.staleness for e in self.accepted), default=0)

    @property
    def num_rejected(self) -> int:
        return sum(1 for e in self.events if not e.result.accepted)

    @property
    def makespan(self) -> float:
        return max((e.sim_time for e in self.events), default=0.0)

    @property
    def total_wait_s(self) -> float:
        """Simulated seconds spent blocked at the SSP barrier (0 under the
        reject throttle)."""
        return sum(e.wait_s for e in self.events)

    def accepted_by_worker(self) -> Dict[int, int]:
        """{worker: number of accepted pushes} (workers with none absent)."""
        out: Dict[int, int] = {}
        for e in self.accepted:
            out[e.worker] = out.get(e.worker, 0) + 1
        return out


class AsyncPSTrainer:
    """Event-driven bounded-staleness trainer over a PS topology.

    Parameters
    ----------
    init_layers:
        per-layer parameter pytrees (the model's sched-layer view).
    loss_fn:
        ``loss_fn(layers, batch) -> scalar`` over the *assembled* layer
        list; differentiated once with ``jax.value_and_grad`` and shared
        by every worker.
    plan:
        the shared ``BucketPlan`` — each forward bucket is one pull
        message, each backward bucket one push message — or one plan per
        worker (the per-worker asynchronous planning mode).
    staleness:
        the bound ``k``: an applied push computed at version ``v``
        satisfies ``head − v ≤ k`` at commit.
    throttle:
        ``"reject"`` (server evicts stale pushes, workers recompute) or
        ``"wait"`` (SSP wait-at-barrier: fast workers block, nothing is
        dropped — see the module docstring).
    aggregate:
        wait throttle only: commit all same-version pushes as ONE
        mean-gradient optimizer step once the version group completes —
        k=0 becomes true bulk-synchronous data parallelism (one version
        bump per round of W pushes) instead of serialized commits.
    costs:
        optional per-worker ``TopologyCosts`` driving the simulated
        clock; without it every worker's iteration costs one unit, which
        keeps the event order deterministic but uninformative.
    compressor:
        optional ``repro.compress`` scheme applied to every gradient push
        (per-layer flat buffers compressed before they hit the server;
        pulls stay fp32).  With ``compressor.error_feedback`` each
        (worker, layer) pair carries a residual of its own quantization
        error into its next push.  The ledger accounts wire vs logical
        bytes per worker.
    """

    def __init__(self, *, init_layers: Sequence[Any],
                 loss_fn: Callable[[List[Any], Dict[str, Any]], Any],
                 optimizer: Optimizer, topology: PSTopology,
                 plan: Union[BucketPlan, Sequence[BucketPlan]],
                 staleness: int = 1, throttle: str = "reject",
                 aggregate: bool = False,
                 costs: Optional[TopologyCosts] = None,
                 compressor=None):
        init_layers = list(init_layers)
        if not init_layers:
            raise ValueError("need at least one layer tree")
        if throttle not in THROTTLES:
            raise ValueError(f"throttle must be one of {THROTTLES}, got "
                             f"{throttle!r}")
        if aggregate and throttle != "wait":
            raise ValueError(
                "aggregate=True commits same-version pushes as one "
                "optimizer step at the SSP barrier; it requires "
                f"throttle='wait' (got {throttle!r})")
        if aggregate and staleness != 0:
            raise ValueError(
                f"aggregate=True admits workers in full-fleet cohorts, so "
                f"every commit has staleness 0 and k={staleness} would be "
                f"inert — pass staleness=0 (true BSP), or drop aggregation "
                f"for bounded-staleness overlap")
        self.topology = topology
        self.staleness = staleness
        self.throttle = throttle
        self.aggregate = aggregate
        self.specs: Tuple[FlatSpec, ...] = tuple(
            make_flat_spec(t, 1) for t in init_layers)
        self._plans = self._as_worker_plans(plan)
        flats = [flatten_tree(t, s) for t, s in zip(init_layers, self.specs)]
        if compressor is not None and compressor.scheme == "none":
            compressor = None
        self.compressor = compressor
        self.server = PSServer(self.specs, topology, optimizer, flats,
                               staleness_bound=staleness,
                               compressor=compressor)
        if compressor is None:
            self._compress_fn = None
        elif compressor.error_feedback:
            self._compress_fn = jax.jit(compressor.feedback_roundtrip)
        else:
            self._compress_fn = jax.jit(compressor.roundtrip)
        self._residuals: Dict[Tuple[int, int], jnp.ndarray] = {}
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        if costs is not None and costs.num_workers != topology.num_workers:
            raise ValueError(f"costs for {costs.num_workers} workers, "
                             f"topology has {topology.num_workers}")
        self._costs = costs
        self._durations = self._iteration_durations()
        self._loop: Optional[_LoopState] = None

    # ------------------------------------------------------------------
    # plans (shared or per-worker, swappable between runs)
    # ------------------------------------------------------------------

    @property
    def plan(self) -> BucketPlan:
        """The shared plan; raises if workers run distinct plans."""
        distinct = set(self._plans)
        if len(distinct) != 1:
            raise ValueError("workers run per-worker plans; use plans")
        return self._plans[0]

    @property
    def plans(self) -> Tuple[BucketPlan, ...]:
        """One plan per worker (identical entries under a shared plan)."""
        return self._plans

    def _as_worker_plans(self, plan) -> Tuple[BucketPlan, ...]:
        W = self.topology.num_workers
        if isinstance(plan, BucketPlan):
            worker_plans = (plan,) * W
        else:
            worker_plans = tuple(plan)
            if len(worker_plans) != W:
                raise ValueError(f"{len(worker_plans)} plans for {W} "
                                 f"workers")
        L = len(self.specs)
        for p in dict.fromkeys(worker_plans):
            for direction in ("forward", "backward"):
                covered = sorted(l for b in getattr(p, direction) for l in b)
                if covered != list(range(L)):
                    raise ValueError(f"plan's {direction} buckets cover "
                                     f"layers {covered}, model has "
                                     f"0..{L - 1}")
        return worker_plans

    def set_plans(self, plan: Union[BucketPlan, Sequence[BucketPlan]],
                  costs: Optional[TopologyCosts] = None,
                  topology: Optional[PSTopology] = None) -> None:
        """Swap the active plan(s) — and optionally the simulated-clock
        costs and the topology itself — between event-loop runs (a
        topology-epoch boundary).  In-flight computations keep the
        durations they started with; new admissions use the new plans.
        A new ``topology`` is forwarded to the server (shard routing,
        ledger); its worker count must not change."""
        if topology is not None:
            if topology.num_workers != self.topology.num_workers:
                raise ValueError(
                    f"new topology has {topology.num_workers} workers, "
                    f"trainer was built with {self.topology.num_workers} — "
                    f"workers cannot join or leave mid-run")
            self.topology = topology
            self.server.topology = topology
        self._plans = self._as_worker_plans(plan)
        if costs is not None:
            if costs.num_workers != self.topology.num_workers:
                raise ValueError(f"costs for {costs.num_workers} workers, "
                                 f"topology has {self.topology.num_workers}")
            self._costs = costs
        self._durations = self._iteration_durations()

    def _iteration_durations(self) -> Tuple[float, ...]:
        if self._costs is None:
            # compute-bound default: duration ∝ 1 / worker compute rate,
            # normalized so the fastest worker's iteration is one unit
            flops = self.topology.worker_flops
            fastest = max(flops)
            return tuple(fastest / f for f in flops)
        return tuple(
            iteration_time(c, *decision_from_plan(p))
            for c, p in zip(self._costs.workers, self._plans))

    # ------------------------------------------------------------------
    # one worker attempt: segmented pull → grads → segmented push
    # ------------------------------------------------------------------

    def _pull_layers(self, worker: int) -> Tuple[int, List[Any]]:
        """Pull every forward segment at one pinned version."""
        while True:
            version: Optional[int] = None
            buffers: Dict[int, Any] = {}
            try:
                for bucket in self._plans[worker].forward:
                    v, flats = self.server.pull_bucket(
                        bucket, version=version, worker=worker)
                    version = v
                    buffers.update(flats)
            except StaleVersion:
                continue          # snapshot evicted mid-pull: restart at head
            layers = [unflatten_tree(buffers[l], self.specs[l])
                      for l in range(len(self.specs))]
            return version, layers

    def _compute(self, worker: int, batch) -> Tuple[float, int, List[Any]]:
        """Pull (pinning a version) and compute gradients against it."""
        version, layers = self._pull_layers(worker)
        loss, grads = self._grad_fn(layers, batch)
        return float(loss), version, grads

    def _compress_flat(self, worker: int, layer: int,
                       flat: jnp.ndarray) -> jnp.ndarray:
        """What the server reconstructs from this worker's wire payload;
        under error feedback the residual carries into the next push."""
        if self.compressor is None:
            return flat
        if not self.compressor.error_feedback:
            return self._compress_fn(flat)
        key = (worker, layer)
        residual = self._residuals.get(key)
        if residual is None:
            residual = jnp.zeros_like(flat)
        compressed, self._residuals[key] = self._compress_fn(flat, residual)
        return compressed

    def _push(self, worker: int, version: int,
              grads: List[Any]) -> PushResult:
        """Push every backward segment; the last one commits."""
        result: Optional[PushResult] = None
        for bucket in self._plans[worker].backward:
            flat_grads = {l: self._compress_flat(
                              worker, l, flatten_tree(grads[l], self.specs[l]))
                          for l in bucket}
            result = self.server.push_bucket(worker, version, bucket,
                                             flat_grads)
        assert result is not None, "plan.backward committed no push"
        return result

    # ------------------------------------------------------------------
    # the event loop
    # ------------------------------------------------------------------

    def run(self, num_pushes: int,
            batch_fn: Callable[[int, int], Any], *,
            reset: bool = True) -> AsyncRunLog:
        """Run until ``num_pushes`` gradient pushes were *accepted*.

        Each worker pulls + computes at the *start* of its iteration and
        commits its push one per-worker iteration duration later — other
        workers' commits land in between, which is where staleness comes
        from.  ``batch_fn(worker, attempt_idx) -> batch`` supplies data;
        the attempt index increments per computation (including retries
        after a stale rejection), so every attempt sees fresh data.

        ``reset=False`` continues a previous run's event loop (simulated
        clock, in-flight computations, and attempt counters carry over;
        the returned log is cumulative) — the dynamic-PS driver runs one
        topology epoch per call this way."""
        if num_pushes < 1:
            raise ValueError(f"num_pushes must be >= 1, got {num_pushes}")
        if reset or self._loop is None:
            self._loop = _LoopState(log=AsyncRunLog(),
                                    parked=list(range(
                                        self.topology.num_workers)))
        loop = self._loop
        target = loop.accepted + num_pushes
        if self.throttle == "wait" and self.aggregate:
            self._run_wait_agg(loop, target, batch_fn)
        elif self.throttle == "wait":
            self._run_wait(loop, target, batch_fn)
        else:
            self._run_reject(loop, target, batch_fn)
        return loop.log

    # -- shared helpers -------------------------------------------------

    def _start(self, loop: "_LoopState", worker: int, now: float,
               batch_fn) -> None:
        """Admit ``worker``: pull at the head, compute, schedule commit."""
        loss, version, grads = self._compute(
            worker, batch_fn(worker, loop.attempts[worker]))
        loop.attempts[worker] += 1
        loop.queue.push(now + self._durations[worker], worker,
                        (version, loss, grads))

    # -- reject throttle (PR 3 semantics, unchanged) --------------------

    def _run_reject(self, loop: "_LoopState", target: int,
                    batch_fn) -> None:
        """Server-side eviction: every worker is always in flight; a push
        staler than k is rejected at commit and the worker recomputes."""
        while loop.parked:                      # admission is unconditional
            self._start(loop, loop.parked.pop(0), loop.now, batch_fn)
        while loop.accepted < target:
            ev = loop.queue.pop()
            t, w = ev.time, ev.worker
            version, loss, grads = ev.payload
            loop.now = t
            result = self._push(w, version, grads)
            loop.log.events.append(AsyncPushEvent(
                worker=w, sim_time=t, version=version, result=result,
                loss=loss, retries=loop.retries[w]))
            loop.accepted += int(result.accepted)
            loop.retries[w] = loop.retries[w] + 1 if not result.accepted \
                else 0
            self._start(loop, w, t, batch_fn)

    # -- wait throttle (SSP wait-at-barrier) ----------------------------

    def _run_wait(self, loop: "_LoopState", target: int, batch_fn) -> None:
        """SSP semantics: admission gate + min-version commit barrier (see
        the module docstring).  Every push commits; nothing is dropped."""
        k = self.staleness

        def in_flight() -> int:
            return len(loop.queue) + len(loop.barrier)

        def admit(now: float) -> None:
            while loop.parked and in_flight() <= k:
                self._start(loop, loop.parked.pop(0), now, batch_fn)

        def min_pin() -> int:
            return min([e.payload[0] for e in loop.queue] +
                       [v for v, _, _, _, _ in loop.barrier])

        def drain(now: float) -> None:
            """Commit every barrier entry whose pin is the in-flight
            minimum, in (pin, completion, worker) order."""
            while loop.barrier and loop.accepted < target:
                loop.barrier.sort()
                pin, done_t, w, loss, grads = loop.barrier[0]
                if pin > min_pin():
                    return                     # blocked on a laggard
                loop.barrier.pop(0)
                assert self.server.head_distance(pin) <= k, \
                    "SSP gates must keep every commit within the bound"
                result = self._push(w, pin, grads)
                assert result.accepted, \
                    "a wait-throttled push can never be stale at commit"
                wait_s = now - done_t
                if wait_s > 0:
                    self.server.ledger.waited_pushes += 1
                loop.log.events.append(AsyncPushEvent(
                    worker=w, sim_time=now, version=pin, result=result,
                    loss=loss, retries=0, wait_s=wait_s))
                loop.accepted += 1
                loop.parked.append(w)          # wants its next iteration
                admit(now)                     # a slot just freed up

        # a resumed run may hold entries that became eligible exactly when
        # the previous run hit its push target: commit them at the clock
        # they were eligible, before waiting on any new completion
        drain(loop.now)
        admit(loop.now)
        while loop.accepted < target:
            ev = loop.queue.pop()
            t, w = ev.time, ev.worker
            version, loss, grads = ev.payload
            loop.now = t
            loop.barrier.append((version, t, w, loss, grads))
            drain(t)

    # -- wait throttle with BSP push aggregation ------------------------

    def _push_aggregate(self, group) -> List[PushResult]:
        """Ledger-account each group member's segmented push and commit
        the whole group as one aggregated (mean-gradient) optimizer step
        via :meth:`PSServer.push_aggregated`."""
        pushes = []
        for pin, _done_t, w, _loss, grads in group:
            full: Dict[int, Any] = {}
            for bucket in self._plans[w].backward:
                for l in bucket:
                    full[l] = self._compress_flat(
                        w, l, flatten_tree(grads[l], self.specs[l]))
                self.server.ledger.record_push(
                    w, self.server.segment_bytes(bucket),
                    wire_bytes=self.server.push_wire_bytes(bucket))
            pushes.append((w, pin, full))
        return self.server.push_aggregated(pushes)

    def _run_wait_agg(self, loop: "_LoopState", target: int,
                      batch_fn) -> None:
        """SSP wait with same-version aggregation: a *version group* (all
        completions pinned at the in-flight minimum version) commits as
        ONE mean-gradient optimizer step once its last member completes.

        With every worker admitted at the same head this is exactly
        bulk-synchronous data parallelism — at k=0 the serialized commits
        of plain ``wait`` become true BSP rounds (the ROADMAP item), and
        staleness at commit is 0 for every member.  Groups are atomic: a
        run may overshoot its push target by up to ``W - 1`` accepted
        pushes when the target lands mid-group.
        """
        def admit(now: float) -> None:
            # safety gate mirroring SSP admission; under group-atomic
            # commits every in-flight pin >= head, so this never starves
            while loop.parked:
                pins = [e.payload[0] for e in loop.queue] + \
                       [e[0] for e in loop.barrier]
                floor = min(pins) if pins else self.server.version
                if self.server.version - floor > self.staleness:
                    return
                self._start(loop, loop.parked.pop(0), now, batch_fn)

        def drain(now: float) -> None:
            while loop.barrier and loop.accepted < target:
                loop.barrier.sort()
                pin = loop.barrier[0][0]
                if any(e.payload[0] <= pin for e in loop.queue):
                    return          # the version group is still computing
                group = [e for e in loop.barrier if e[0] == pin]
                del loop.barrier[:len(group)]    # sorted ⇒ group is prefix
                results = self._push_aggregate(group)
                for (v, done_t, w, loss, _grads), res in zip(group,
                                                             results):
                    assert res.accepted, \
                        "a whole-group commit can never be stale"
                    wait_s = now - done_t
                    if wait_s > 0:
                        self.server.ledger.waited_pushes += 1
                    loop.log.events.append(AsyncPushEvent(
                        worker=w, sim_time=now, version=v, result=res,
                        loss=loss, retries=0, wait_s=wait_s))
                    loop.accepted += 1
                    loop.parked.append(w)
                admit(now)

        drain(loop.now)
        admit(loop.now)
        while loop.accepted < target:
            ev = loop.queue.pop()
            t, w = ev.time, ev.worker
            version, loss, grads = ev.payload
            loop.now = t
            loop.barrier.append((version, t, w, loss, grads))
            drain(t)

    # ------------------------------------------------------------------
    # interop
    # ------------------------------------------------------------------

    def reset_loop(self) -> None:
        """Discard the event loop (clock, in-flight computations, log).

        Required after restoring the server from a checkpoint: in-flight
        computations hold gradients pinned at pre-restore versions and
        computed against pre-rollback weights — committing them against
        the restored parameters would silently corrupt the trajectory.
        The next ``run`` starts a fresh loop at simulated time 0.
        Error-feedback residuals are cleared too (they describe pushes of
        the discarded trajectory)."""
        self._loop = None
        self._residuals = {}

    @property
    def log(self) -> Optional[AsyncRunLog]:
        """The (cumulative) log of the current run, if one is active."""
        return self._loop.log if self._loop is not None else None

    def layer_params(self) -> List[Any]:
        """Head-version parameters, unflattened to the layer pytrees."""
        return [unflatten_tree(f, s)
                for f, s in zip(self.server.flats(), self.specs)]


@dataclasses.dataclass
class _LoopState:
    """Resumable discrete-event loop state.

    ``queue`` is the deterministic :class:`~repro.fleet.engine.EventQueue`
    holding in-flight computations; each event's payload is ``(compute
    version, loss, grads)`` and the engine's ``(time, seq, worker)`` key
    orders commits without ever comparing payloads.  ``barrier`` holds
    completed-but-uncommitted computations (wait throttle) as ``(pin
    version, completion time, worker, loss, grads)``; ``parked`` holds
    workers awaiting admission, FIFO.
    """

    log: AsyncRunLog
    parked: List[int]
    queue: EventQueue = dataclasses.field(default_factory=EventQueue)
    barrier: List[Tuple[int, float, int, float, List[Any]]] = \
        dataclasses.field(default_factory=list)
    now: float = 0.0
    accepted: int = 0              # incremental len(log.accepted)
    attempts: Dict[int, int] = None
    retries: Dict[int, int] = None

    def __post_init__(self):
        if self.attempts is None:
            self.attempts = {w: 0 for w in self.parked}
        if self.retries is None:
            self.retries = {w: 0 for w in self.parked}
