"""Baseline scheduling strategies the paper compares against.

* Sequential — the default PS: one transmission covering all L layers
  (decision ``[0, L]`` forward, ``[L+1, 1]`` backward).
* LBL — the layer-by-layer transmission strategy (Poseidon-style): every
  layer is its own mini-procedure.
"""

from __future__ import annotations

from typing import Tuple

from repro.core.costmodel import (Segment, singleton_segments_backward,
                                  singleton_segments_forward)


def sequential_forward(L: int) -> Tuple[Segment, ...]:
    return ((1, L),)


def sequential_backward(L: int) -> Tuple[Segment, ...]:
    return ((1, L),)


def lbl_forward(L: int) -> Tuple[Segment, ...]:
    return singleton_segments_forward(L)


def lbl_backward(L: int) -> Tuple[Segment, ...]:
    return singleton_segments_backward(L)
