"""gemma-7b [arXiv:2403.08295] — GeGLU, head_dim=256."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    citation="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    activation="geglu",
    gated_mlp=True,
)
