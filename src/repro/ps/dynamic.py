"""Run-time re-planning for the parameter-server subsystem.

``repro.dist.dynamic.DynamicTrainer`` closed the paper's run-time loop for
the flat ZeRO cluster; this module closes it for the paper's *actual*
deployment topology.  A :class:`repro.ps.topology.TopologySchedule` makes
the fabric time-varying — per-link bandwidth/RTT and per-worker compute
rates shifting on epoch boundaries — and two drivers re-derive the
layer-wise decomposition whenever the topology shifts:

* :class:`DynamicPSTrainer` (synchronous, compiled): once per topology
  epoch, re-projects the active topology onto per-worker
  ``TopologyCosts``, re-runs the straggler-minimizing
  ``consensus_decision``, and swaps the compiled pull/push step from the
  shared :class:`repro.runtime.replan.PlanStepCache` (one trace per
  distinct plan, revisits are dictionary lookups).  With
  ``cost_source="measured"``, per-layer fc/bc come from *measured*
  wall-clock timings of the jitted applies (re-measured every
  ``remeasure_every`` topology epochs) and are rescaled to each worker's
  compute rate — so the per-worker decompositions track real compute
  drift, not just the analytic model.  The ZeRO/PS state layout (one
  ``FlatSpec`` flat buffer per sched layer) is plan-independent, so
  states carry across swaps and the loss trajectory is bit-identical to
  statically running each epoch's plan (asserted by
  ``tests/test_dynamic.py``).
* :class:`DynamicAsyncPSTrainer` (asynchronous, event-driven): once per
  topology epoch, re-runs per-worker ``schedule_topology`` — each worker
  gets its own decomposition, matched to its own link and compute rate —
  and swaps the plans (and the simulated-clock costs) into the resumable
  :class:`repro.ps.async_mode.AsyncPSTrainer` loop, under either throttle
  discipline (with optional BSP push aggregation).

Every re-plan records a reschedule event carrying the scheduling wall
time and the paper's Table I overhead-hidden check against the topology's
Δt + gt¹ idle window (the minimum over workers — the re-plan must hide
behind *every* worker's last in-flight gradient push); the event
bookkeeping is shared with the ZeRO driver via
:class:`repro.runtime.replan.ReplanMixin`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.core.buckets import BucketPlan, plan_from_decision
from repro.core.costmodel import TopologyCosts
from repro.core.planner import AsyncPlanner, Planner
from repro.core.profiler import LayerProfile, LayerTimingHook
from repro.core.scheduler import TopologyScheduler
from repro.models import model as model_lib
from repro.models.profiles import layer_profiles
from repro.optim import Optimizer
from repro.ps.async_mode import AsyncPSTrainer, AsyncRunLog
from repro.ps.topology import TopologySchedule, as_topology_schedule
from repro.ps.worker import PSTrainer
from repro.runtime.measure import measure_layer_times, measurement_due
from repro.runtime.replan import ReplanMixin

_MOVED = ("PlanStepCache", "RescheduleEvent", "hlo_collective_counts",
          "sequential_plan")


def __getattr__(name: str):
    # deprecation shims mirroring repro.dist.dynamic: the re-planning
    # machinery PR 4 grew here was hoisted to repro.runtime.replan
    if name in _MOVED:
        warnings.warn(
            f"repro.ps.dynamic.{name} moved to repro.runtime.replan; "
            f"this alias will be removed",
            DeprecationWarning, stacklevel=2)
        from repro.runtime import replan
        return getattr(replan, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def profiles_from_specs(specs, *, flops_per_param: float = 4.0
                        ) -> Tuple[LayerProfile, ...]:
    """Synthesize layer workloads from flat-buffer specs (models without
    an analytic profile zoo entry, e.g. the smoke CNN): bytes are the
    exact parameter payloads, FLOPs a uniform multiple of the parameter
    count — enough structure for per-worker *relative* planning."""
    return tuple(LayerProfile(name=f"layer{l}", param_bytes=s.total * 4.0,
                              flops_fwd=flops_per_param * s.total)
                 for l, s in enumerate(specs))


@dataclasses.dataclass
class DynamicPSTrainer(ReplanMixin):
    """Topology-epoch re-planning driver around :class:`PSTrainer` (sync).

    ``topology`` may be a static :class:`PSTopology` or a
    :class:`TopologySchedule`; the schedule's ``num_workers`` must equal
    the mesh's ``axis_name`` size (one synchronous worker per device, and
    workers cannot join or leave mid-run).

    ``cost_source="measured"`` times this host's jitted per-layer applies
    (``repro.runtime.measure``) every ``remeasure_every`` topology epochs
    and projects the timings onto each worker by compute-rate scaling:
    the measured vectors are taken to describe a worker running at
    ``measure_ref_flops`` (default: the fleet's fastest rate), so worker
    *w* sees them scaled by ``measure_ref_flops / worker_flops[w]`` while
    pt/gt/Δt still come from its own links.
    """

    cfg: ArchConfig
    mesh: Any
    optimizer: Optimizer
    topology: Any                  # PSTopology | TopologySchedule
    steps_per_epoch: int
    input_shape: InputShape
    strategy: str = "dynacomm"
    cost_source: str = "analytic"          # "analytic" | "measured"
    measure_iters: int = 3
    measure_warmup: int = 1
    remeasure_every: int = 1      # epochs between fc/bc re-measurements;
                                  # 0 = measure once
    measure_ref_flops: Optional[float] = None
    zero3: bool = False
    axis_name: str = "data"
    aux_weight: float = 0.01
    compressor: Optional[Any] = None
    async_planning: bool = False  # pre-plan epoch e+1 in e's idle window
    plan_cache_size: int = 256    # memoized decisions kept (LRU)

    def __post_init__(self):
        if self.steps_per_epoch < 1:
            raise ValueError(f"steps_per_epoch must be >= 1, got "
                             f"{self.steps_per_epoch}")
        if self.cost_source not in ("analytic", "measured"):
            raise ValueError(f"cost_source must be 'analytic' or 'measured', "
                             f"got {self.cost_source!r}")
        if self.remeasure_every < 0:
            raise ValueError(f"remeasure_every must be >= 0, got "
                             f"{self.remeasure_every}")
        self.topology: TopologySchedule = as_topology_schedule(self.topology)
        planner_cls = AsyncPlanner if self.async_planning else Planner
        self.planner = planner_cls(cache_size=self.plan_cache_size)
        self.scheduler = TopologyScheduler(
            strategy=self.strategy, reschedule_every=self.steps_per_epoch,
            mode="consensus", planner=self.planner)
        self.hook = LayerTimingHook(warmup=self.measure_warmup)
        self._profiles = layer_profiles(self.cfg, self.input_shape)
        Ls = model_lib.num_sched_layers(self.cfg)
        seq = BucketPlan(forward=(tuple(range(Ls)),),
                         backward=(tuple(range(Ls - 1, -1, -1)),))
        self.base = PSTrainer(cfg=self.cfg, mesh=self.mesh, plan=seq,
                              optimizer=self.optimizer,
                              topology=self.topology.topology_at(0),
                              zero3=self.zero3, axis_name=self.axis_name,
                              aux_weight=self.aux_weight,
                              compressor=self.compressor)
        self.compressor = self.base.compressor   # "none" normalized away
        self._init_replan()
        self._step_idx = 0
        self._costs: Optional[TopologyCosts] = None
        self._measured_fc_bc: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._measured_epoch = -1

    # ------------------------------------------------------------------
    # state / introspection
    # ------------------------------------------------------------------

    def init_state(self, key):
        return self.base.init_state(key)

    @property
    def step_index(self) -> int:
        return self._step_idx

    @property
    def epoch(self) -> int:
        return self._step_idx // self.steps_per_epoch

    @property
    def planner_stats(self) -> Dict[str, float]:
        """Memo-cache / async-planning counters (``PlannerStats``)."""
        return self.planner.stats.as_dict()

    def costs_for_epoch(self, epoch: int, state=None, batch=None, *,
                        remeasure: bool = False) -> TopologyCosts:
        """The active topology's per-worker cost projection.

        Analytic by default.  With ``cost_source="measured"``, fc/bc come
        from measured host timings rescaled per worker (see the class
        docstring); ``state``/``batch`` are required whenever a (re-)
        measurement is due — callers that only want the cached projection
        (timeline views, tests) can omit them.
        """
        topo = self.topology.topology_at(epoch)
        if self.cost_source == "analytic":
            return topo.topology_costs(self._profiles,
                                       compressor=self.compressor)
        if measurement_due(self._measured_fc_bc, self._measured_epoch,
                           epoch, self.remeasure_every, force=remeasure):
            if state is None or batch is None:
                # view accessors (timelines, tests) may read the cached
                # projection without re-measuring; only the very first
                # measurement has nothing to serve
                if self._measured_fc_bc is None:
                    raise ValueError(
                        "cost_source='measured' needs state and batch for "
                        "the first measurement")
            else:
                measure_layer_times(self.base._zero, self.hook, state,
                                    batch, iters=self.measure_iters)
                Ls = self.base.num_layers
                self._measured_fc_bc = (self.hook.median("fc", Ls),
                                        self.hook.median("bc", Ls))
                self._measured_epoch = epoch
        fc, bc = self._measured_fc_bc
        return topo.topology_costs_measured(
            self._profiles, fc=fc, bc=bc, ref_flops=self.measure_ref_flops,
            compressor=self.compressor)

    def timeline(self, epoch: Optional[int] = None):
        """Per-worker timeline of the *active* plan against an epoch's
        topology costs (current epoch by default)."""
        from repro.core.buckets import decision_from_plan
        from repro.core.simulator import simulate_ps_iteration
        if self._plan is None:
            raise ValueError("no active plan yet — run at least one step")
        epoch = self.epoch if epoch is None else epoch
        return simulate_ps_iteration(self.costs_for_epoch(epoch),
                                     decision_from_plan(self._plan))

    def replan_timeline(self):
        """Re-planned vs frozen-epoch-0-plan makespans across the epochs
        re-scheduled so far (:func:`core.simulator.simulate_ps_replan`) —
        the stale-plan penalty this driver exists to reclaim."""
        from repro.core.simulator import simulate_ps_replan
        from repro.core.buckets import decision_from_plan
        if not self.events:
            raise ValueError("no reschedule events yet")
        by_epoch = {e.epoch: e.plan for e in self.events}
        epochs = sorted(by_epoch)
        costs = [self.costs_for_epoch(e) for e in epochs]
        decisions = [decision_from_plan(by_epoch[e]) for e in epochs]
        return simulate_ps_replan(costs, decisions)

    # ------------------------------------------------------------------
    # the dynamic loop
    # ------------------------------------------------------------------

    def _maybe_reschedule(self, i: int, state, batch) -> None:
        boundary = i % self.steps_per_epoch == 0
        if boundary:
            epoch = i // self.steps_per_epoch
            self._costs = self.costs_for_epoch(epoch, state, batch)
            # the compiled data path is topology-independent; the base
            # trainer's accounting views (segment owners, transfer bytes,
            # timelines) should reflect the active fabric
            self.base.topology = self.topology.topology_at(epoch)
        decision = self.scheduler.decision_for_iteration(self._costs)
        # (``_step_fn is None`` off-boundary ⇒ loop state was just restored
        # from a checkpoint: recompile the active plan, no scheduling event)
        if not boundary and self._step_fn is not None:
            return
        plan = plan_from_decision(*decision, self.base.num_layers)
        prev, retraced = self._activate_plan(
            plan, lambda: self.base.with_plan(plan).build_train_step(),
            state, batch)
        if boundary:
            self._record_reschedule(
                step=i, epoch=i // self.steps_per_epoch, plan=plan,
                prev=prev, retraced=retraced, scheduler=self.scheduler,
                costs=self._costs)
        if boundary and self.async_planning and \
                self.cost_source == "analytic":
            # Phase one of the async protocol: epoch e+1's analytic
            # topology projection is a pure function of the epoch, so its
            # per-worker DPs can run now in the Δt + gt¹ idle window and
            # be collected at the next boundary.  Measured costs solve
            # inline as before (the planner's sync fallback).
            self.planner.submit_topology(
                self.costs_for_epoch(i // self.steps_per_epoch + 1),
                self.strategy)

    def step(self, state, batch):
        """One training step; re-plans on topology-epoch boundaries.
        Returns ``(new_state, mean_loss)``."""
        self._maybe_reschedule(self._step_idx, state, batch)
        new_state, loss = self._step_fn(state, batch)
        self._step_idx += 1
        return new_state, loss

    def run(self, state, batch_fn: Callable[[int], Any], num_steps: int, *,
            log_every: int = 0):
        """Drive ``num_steps`` steps with ``batch_fn(i) -> batch``.

        Returns ``(state, losses)`` with one float loss per step."""
        losses: List[float] = []
        for i in range(num_steps):
            state, loss = self.step(state, batch_fn(i))
            losses.append(float(loss))
            if log_every and (i + 1) % log_every == 0:
                f, b = (len(self._plan.forward), len(self._plan.backward))
                print(f"step {i + 1:4d}  epoch {self.epoch}  "
                      f"loss {losses[-1]:.4f}  segments {f}/{b}")
        return state, losses

    # ------------------------------------------------------------------
    # loop-state checkpointing — loop_state/save_loop_state come from
    # ReplanMixin unchanged; the restore re-points the base trainer's
    # accounting at the resumed epoch's topology
    # ------------------------------------------------------------------

    def restore_loop_state(self, path: str) -> None:
        self._restore_loop_common(path)
        self.base.topology = self.topology.topology_at(self.epoch)


@dataclasses.dataclass(frozen=True)
class AsyncRescheduleEvent:
    """One per-worker re-planning pass of the asynchronous driver."""

    epoch: int
    at_push: int                  # accepted pushes when the pass ran
    worker_plans: Tuple[BucketPlan, ...]
    plan_changed: bool            # any worker's plan differed from before
    scheduling_seconds: float
    overhead_hidden: bool         # fits the topology's min Δt + gt¹ window


class DynamicAsyncPSTrainer:
    """Topology-epoch re-planning around :class:`AsyncPSTrainer`.

    Asynchronous execution has no shared program to recompile — each
    worker plans for itself — so a topology epoch here is a span of
    ``pushes_per_epoch`` *accepted* pushes (the async loop's notion of
    progress), and a re-plan swaps per-worker plans and simulated-clock
    costs into the resumable event loop between epochs.
    """

    def __init__(self, *, init_layers: Sequence[Any],
                 loss_fn: Callable[[List[Any], Dict[str, Any]], Any],
                 optimizer: Optimizer, topology: Any,
                 pushes_per_epoch: int, staleness: int = 1,
                 throttle: str = "reject", aggregate: bool = False,
                 strategy: str = "dynacomm",
                 profiles: Optional[Sequence[LayerProfile]] = None,
                 compressor: Optional[Any] = None,
                 async_planning: bool = False,
                 plan_cache_size: int = 256):
        if pushes_per_epoch < 1:
            raise ValueError(f"pushes_per_epoch must be >= 1, got "
                             f"{pushes_per_epoch}")
        self.topology: TopologySchedule = as_topology_schedule(topology)
        self.pushes_per_epoch = pushes_per_epoch
        self.strategy = strategy
        self.async_planning = async_planning
        planner_cls = AsyncPlanner if async_planning else Planner
        self.planner = planner_cls(cache_size=plan_cache_size)
        self.scheduler = TopologyScheduler(strategy=strategy,
                                           reschedule_every=1,
                                           mode="per-worker",
                                           planner=self.planner)
        self.events: List[AsyncRescheduleEvent] = []
        self._planned_epoch = 0
        # plan epoch 0 before building the trainer (it needs plans)
        self.trainer = AsyncPSTrainer(
            init_layers=init_layers, loss_fn=loss_fn, optimizer=optimizer,
            topology=self.topology.topology_at(0),
            plan=BucketPlan(
                forward=(tuple(range(len(init_layers))),),
                backward=(tuple(range(len(init_layers) - 1, -1, -1)),)),
            staleness=staleness, throttle=throttle, aggregate=aggregate,
            compressor=compressor)
        self.compressor = self.trainer.compressor   # "none" normalized away
        self._profiles = (tuple(profiles) if profiles is not None
                          else profiles_from_specs(self.trainer.specs))
        self._worker_plans: Optional[Tuple[BucketPlan, ...]] = None
        self._replan(0)

    def _accepted(self) -> int:
        return 0 if self.trainer.log is None \
            else len(self.trainer.log.accepted)

    @property
    def epoch(self) -> int:
        """The current topology epoch — a pure function of *accepted*
        pushes, so progress is identical whether a caller drives one
        ``run_pushes(N)`` or N chunked ``run_pushes(1)`` calls."""
        return self._accepted() // self.pushes_per_epoch

    @property
    def worker_plans(self) -> Tuple[BucketPlan, ...]:
        return self._worker_plans

    @property
    def planner_stats(self) -> Dict[str, float]:
        """Memo-cache / async-planning counters (``PlannerStats``)."""
        return self.planner.stats.as_dict()

    def costs_for_epoch(self, epoch: int) -> TopologyCosts:
        return self.topology.topology_at(epoch).topology_costs(
            self._profiles, compressor=self.compressor)

    def _replan(self, epoch: int) -> None:
        costs = self.costs_for_epoch(epoch)
        L = costs.num_layers
        # reschedule_every=1: every decision_for_iteration call re-plans
        decisions = self.scheduler.decision_for_iteration(costs)
        plans = tuple(plan_from_decision(*d, L) for d in decisions)
        prev = self._worker_plans
        self._worker_plans = plans
        self.trainer.set_plans(plans, costs,
                               topology=self.topology.topology_at(epoch))
        accepted = 0 if self.trainer.log is None \
            else len(self.trainer.log.accepted)
        self.events.append(AsyncRescheduleEvent(
            epoch=epoch, at_push=accepted, worker_plans=plans,
            plan_changed=prev is not None and plans != prev,
            scheduling_seconds=self.scheduler.last_scheduling_seconds,
            overhead_hidden=self.scheduler.scheduling_overhead_hidden(
                costs)))
        if self.async_planning:
            # phase one: the async-PS cost projection is always analytic
            # (a pure function of the epoch), so epoch e+1's per-worker
            # DPs can run in this epoch's idle window
            self.planner.submit_topology(self.costs_for_epoch(epoch + 1),
                                         self.strategy)

    def run(self, num_epochs: int,
            batch_fn: Callable[[int, int], Any]) -> AsyncRunLog:
        """Run ``num_epochs`` topology epochs of ``pushes_per_epoch``
        accepted pushes each, re-planning per-worker on each boundary.
        Returns the cumulative :class:`AsyncRunLog`."""
        if num_epochs < 1:
            raise ValueError(f"num_epochs must be >= 1, got {num_epochs}")
        return self.run_pushes(num_epochs * self.pushes_per_epoch, batch_fn)

    def run_pushes(self, num_pushes: int,
                   batch_fn: Callable[[int, int], Any]) -> AsyncRunLog:
        """Run ``num_pushes`` more accepted pushes: a per-worker re-plan
        whenever the cumulative accepted count crosses a
        ``pushes_per_epoch`` boundary.  Epoch position is derived from
        the accepted count, never from how callers chunk their calls —
        ``run_pushes(1)`` six times re-plans at exactly the same pushes
        as one ``run_pushes(6)``."""
        if num_pushes < 1:
            raise ValueError(f"num_pushes must be >= 1, got {num_pushes}")
        log: Optional[AsyncRunLog] = None
        # account by *accepted* pushes, not requested chunks: under BSP
        # aggregation a run may commit a whole same-version group and
        # overshoot its chunk — re-reading the accepted count keeps the
        # total overshoot bounded by one group (W - 1) for the whole call
        target = self._accepted() + num_pushes
        while (accepted := self._accepted()) < target:
            epoch = accepted // self.pushes_per_epoch
            if epoch != self._planned_epoch:
                self._replan(epoch)
                self._planned_epoch = epoch
            # stop at the next epoch boundary so the re-plan lands there
            chunk = min(target - accepted,
                        self.pushes_per_epoch -
                        accepted % self.pushes_per_epoch)
            log = self.trainer.run(chunk, batch_fn,
                                   reset=self.trainer.log is None)
        return log

    def reset_loop(self) -> None:
        """Discard the event loop (a checkpoint restore rolled the server
        back): progress returns to zero accepted pushes and re-planning
        restarts from topology epoch 0 (recorded as a fresh reschedule
        event)."""
        self.trainer.reset_loop()
        self._planned_epoch = 0
        self._replan(0)
