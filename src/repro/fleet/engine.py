"""Deterministic discrete-event core for fleet-scale simulation.

``repro.ps.async_mode`` started life with an ad-hoc ``heapq`` of
``(commit time, worker, ...)`` tuples — fine for a handful of workers,
fragile at fleet scale: once a queue holds events that are *not*
one-per-worker (membership changes, failure probes, stall checks), time
ties between same-worker entries make tuple comparison reach into
payloads, and iteration order starts depending on heap internals.

``EventQueue`` is the fleet-grade replacement: a binary heap whose
entries are ``(time, seq, worker, payload)`` where ``seq`` is a global
monotone insertion counter.  The three-part key gives

* **total order** — ``seq`` is unique, so two entries never compare
  equal and the payload is never inspected;
* **stable tie-breaking** — events at the same simulated time pop in
  insertion order (then worker id, vacuously), independent of payload
  contents, heap layout, or Python version;
* **bit-reproducibility at scale** — the pop sequence of a
  thousand-worker simulation is a pure function of the push sequence.

The queue is plain data end to end: ``state()`` / ``from_state`` round-
trip it through JSON-able lists (payloads permitting), which is what
makes ``save_loop_state``/``restore_loop_state`` resume bit-identical.
No wall clock, no RNG — the module sits in
``LintConfig.deterministic_modules`` and must stay free of both.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence: ``worker``'s ``payload`` fires at
    simulated ``time``; ``seq`` is the queue-assigned insertion index."""

    time: float
    seq: int
    worker: int
    payload: Any = None

    def key(self) -> Tuple[float, int, int]:
        return (self.time, self.seq, self.worker)


class EventQueue:
    """Heap-ordered event queue with ``(time, seq, worker)`` keys.

    ``push`` assigns the next ``seq`` and returns the :class:`Event` (the
    caller can remember ``seq`` to recognise — or lazily invalidate — the
    event when it pops).  Iteration yields live events in arbitrary
    (heap) order: use it for scans like "minimum pinned version over
    everything in flight", never for anything order-sensitive.
    """

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, int, Any]] = []
        self._next_seq = 0

    # -- core ----------------------------------------------------------

    def push(self, time: float, worker: int, payload: Any = None) -> Event:
        if time < 0:
            raise ValueError(f"event time must be >= 0, got {time}")
        ev = Event(time=float(time), seq=self._next_seq, worker=int(worker),
                   payload=payload)
        self._next_seq += 1
        heapq.heappush(self._heap, (ev.time, ev.seq, ev.worker, ev.payload))
        return ev

    def pop(self) -> Event:
        if not self._heap:
            raise IndexError("pop from an empty EventQueue")
        t, seq, worker, payload = heapq.heappop(self._heap)
        return Event(time=t, seq=seq, worker=worker, payload=payload)

    def peek(self) -> Event:
        if not self._heap:
            raise IndexError("peek at an empty EventQueue")
        t, seq, worker, payload = self._heap[0]
        return Event(time=t, seq=seq, worker=worker, payload=payload)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __iter__(self) -> Iterator[Event]:
        for t, seq, worker, payload in self._heap:
            yield Event(time=t, seq=seq, worker=worker, payload=payload)

    # -- bulk edits ----------------------------------------------------

    def remove_if(self, pred: Callable[[Event], bool]) -> int:
        """Drop every event matching ``pred``; returns how many.

        Deterministic: keys are unique, so the surviving heap's pop order
        does not depend on the removal order."""
        kept = [e for e in self._heap
                if not pred(Event(time=e[0], seq=e[1], worker=e[2],
                                  payload=e[3]))]
        removed = len(self._heap) - len(kept)
        if removed:
            heapq.heapify(kept)
            self._heap = kept
        return removed

    def clear(self) -> None:
        self._heap = []

    # -- serialization -------------------------------------------------

    def state(self) -> dict:
        """Plain-data snapshot (payloads must already be plain data —
        encode array-bearing payloads before calling)."""
        return {
            "next_seq": self._next_seq,
            "entries": [[t, seq, worker, payload]
                        for t, seq, worker, payload in sorted(
                            self._heap, key=lambda e: e[:3])],
        }

    @classmethod
    def from_state(cls, state: dict, *,
                   decode: Optional[Callable[[Any], Any]] = None
                   ) -> "EventQueue":
        q = cls()
        q._next_seq = int(state["next_seq"])
        heap = []
        for t, seq, worker, payload in state["entries"]:
            if decode is not None:
                payload = decode(payload)
            heap.append((float(t), int(seq), int(worker), payload))
        heapq.heapify(heap)
        q._heap = heap
        return q
