"""Frozen, JSON-round-trippable runtime configuration.

One serialized description shared by launchers, examples, benchmarks, and
checkpoints: a :class:`RuntimeConfig` names a registered runtime (see
``repro.runtime.registry``) plus three nested blocks —

* :class:`ScheduleConfig` — what the scheduler re-plans against: the
  strategy, the re-plan interval, drift detection, and either a scalar
  edge :class:`NetworkConfig` (ZeRO regimes) or a :class:`TopologyConfig`
  (PS regimes), both optionally time-varying;
* :class:`ExecutionConfig` — how plans execute: ``zero`` (bucketed ZeRO
  collectives), ``ps-sync`` (consensus plan, one pull + one push per
  segment), or ``ps-async`` (bounded-staleness event loop with a
  ``reject``/``wait`` throttle and optional BSP push aggregation);
* :class:`MeasureConfig` — where fc/bc come from: deterministic analytic
  profiles or measured :class:`~repro.core.profiler.LayerTimingHook`
  wall times, re-measured every ``remeasure_every`` re-plan epochs.

``to_json`` → ``from_json`` is exact (``config == RuntimeConfig.from_json(
config.to_json())``), and every cross-field inconsistency — staleness on a
synchronous runtime, a PS topology on a ZeRO regime, aggregation without
the wait throttle — raises ``ValueError`` at construction, not at step 1.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple, Union

# registry-name → execution regime; the single source of truth for which
# combinations exist (the registry registers exactly these names)
RUNTIME_REGIMES = {
    "local": "local",
    "zero": "zero",
    "dynamic": "zero",
    "ps": "ps-sync",
    "dynamic-ps": "ps-sync",
    "ps-async": "ps-async",
    "dynamic-ps-async": "ps-async",
    "fleet-async": "ps-async",
    "pipeline": "pipeline",
}
DYNAMIC_RUNTIMES = ("dynamic", "dynamic-ps", "dynamic-ps-async",
                    "fleet-async")

_STRATEGIES = ("sequential", "lbl", "ibatch", "dynacomm", "bruteforce")
_THROTTLES = ("reject", "wait")
_COST_SOURCES = ("analytic", "measured")


def _as_tuple(x) -> Optional[Tuple[float, ...]]:
    """Normalize per-worker scalars/sequences so JSON round-trips equal."""
    if x is None or isinstance(x, (int, float)):
        return x
    return tuple(float(v) for v in x)


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Scalar edge network of the ZeRO regimes (one shared uplink)."""

    bandwidth_gbps: float = 10.0
    shift_gbps: Optional[float] = None    # drift target at shift_epoch
    shift_epoch: int = 1

    def __post_init__(self):
        if self.bandwidth_gbps <= 0:
            raise ValueError(f"bandwidth_gbps must be positive, got "
                             f"{self.bandwidth_gbps}")
        if self.shift_gbps is not None and self.shift_gbps <= 0:
            raise ValueError(f"shift_gbps must be positive, got "
                             f"{self.shift_gbps}")

    def build(self):
        """The ``repro.core.netmodel`` object this block describes."""
        from repro.core import EdgeNetworkModel, bandwidth_shift
        if self.shift_gbps is None:
            return EdgeNetworkModel(bandwidth_bps=self.bandwidth_gbps * 1e9)
        return bandwidth_shift(self.bandwidth_gbps * 1e9,
                               self.shift_gbps * 1e9,
                               at_epoch=self.shift_epoch)


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """S server shards × W workers of the PS regimes.

    ``down_gbps`` / ``up_gbps`` / ``worker_flops`` accept a scalar
    (homogeneous fleet) or one value per worker (heterogeneous edges —
    the regime the consensus/straggler machinery exists for).
    ``workers=None`` resolves at build time to one worker per device
    (sync) or per-device logical workers (async).
    """

    servers: int = 2
    workers: Optional[int] = None
    down_gbps: Union[float, Tuple[float, ...]] = 10.0
    up_gbps: Union[float, Tuple[float, ...]] = 1.0
    worker_flops: Union[float, Tuple[float, ...]] = 1e10
    up_shift_factor: Optional[float] = None   # every uplink /= factor ...
    shift_epoch: int = 1                      # ... at this epoch

    def __post_init__(self):
        for name in ("down_gbps", "up_gbps", "worker_flops"):
            object.__setattr__(self, name, _as_tuple(getattr(self, name)))
        if self.servers < 1:
            raise ValueError(f"servers must be >= 1, got {self.servers}")
        if self.workers is not None and self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.up_shift_factor is not None and self.up_shift_factor <= 0:
            raise ValueError(f"up_shift_factor must be positive, got "
                             f"{self.up_shift_factor}")

    def _per_worker(self, value, W: int) -> Tuple[float, ...]:
        if isinstance(value, tuple):
            if len(value) != W:
                raise ValueError(f"{len(value)} per-worker values for "
                                 f"{W} workers")
            return value
        return (float(value),) * W

    def build(self, default_workers: int):
        """The ``PSTopology`` (or ``TopologySchedule`` when drifting)."""
        from repro.ps import PSTopology, asymmetric_link, uplink_degradation
        W = self.workers
        if W is None:
            W = max(len(t) for t in (self.down_gbps, self.up_gbps,
                                     self.worker_flops)
                    if isinstance(t, tuple)) \
                if any(isinstance(t, tuple)
                       for t in (self.down_gbps, self.up_gbps,
                                 self.worker_flops)) else default_workers
        down = self._per_worker(self.down_gbps, W)
        up = self._per_worker(self.up_gbps, W)
        flops = self._per_worker(self.worker_flops, W)
        base = PSTopology(
            num_servers=self.servers,
            links=tuple(asymmetric_link(d * 1e9, u * 1e9)
                        for d, u in zip(down, up)),
            worker_flops=flops)
        if self.up_shift_factor is None:
            return base
        return uplink_degradation(base, factor=self.up_shift_factor,
                                  at_epoch=self.shift_epoch)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Stage-partitioned pipeline execution (``repro.pipeline``).

    ``stages`` contiguous stages balanced by profiled fc + bc, ``schedule``
    micro-batch order (``gpipe`` fill/drain or ``1f1b`` PipeDream-flush),
    and ``chunks`` boundary-tensor chunks per micro-batch for the
    DynaComm-segmented activation transfers (1 ⇒ segment only across
    micro-batches).
    """

    stages: int = 2
    microbatches: int = 2
    schedule: str = "1f1b"
    chunks: int = 1

    def __post_init__(self):
        from repro.pipeline.schedule import SCHEDULES
        if self.stages < 1:
            raise ValueError(f"stages must be >= 1, got {self.stages}")
        if self.microbatches < 1:
            raise ValueError(f"microbatches must be >= 1, got "
                             f"{self.microbatches}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown pipeline schedule {self.schedule!r}; "
                             f"choose from {list(SCHEDULES)}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """What the scheduler plans against, and how often it re-plans."""

    strategy: str = "dynacomm"
    reschedule_every: int = 20       # steps (sync) / pushes (async) per epoch
    drift_detect: bool = False       # dynamic runtime: EWMA step-time drift
    async_planning: bool = False     # pre-plan epoch e+1 in e's idle window
    plan_cache_size: int = 256       # memoized decisions kept (LRU)
    network: Optional[NetworkConfig] = None
    topology: Optional[TopologyConfig] = None

    def __post_init__(self):
        if self.strategy not in _STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; choose "
                             f"from {sorted(_STRATEGIES)}")
        if self.reschedule_every < 1:
            raise ValueError(f"reschedule_every must be >= 1, got "
                             f"{self.reschedule_every}")
        if self.plan_cache_size < 1:
            raise ValueError(f"plan_cache_size must be >= 1, got "
                             f"{self.plan_cache_size}")
        if self.network is not None and self.topology is not None:
            raise ValueError("give either a network (ZeRO regimes) or a "
                             "topology (PS regimes), not both")


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """How decided plans execute."""

    regime: Optional[str] = None     # None ⇒ derived from the runtime name
    staleness: Optional[int] = None  # ps-async bound k
    throttle: str = "reject"         # ps-async: reject | wait
    aggregate: bool = False          # wait throttle: BSP push aggregation
    zero3: bool = False

    def __post_init__(self):
        if self.regime is not None and \
                self.regime not in set(RUNTIME_REGIMES.values()):
            raise ValueError(f"unknown regime {self.regime!r}; choose from "
                             f"{sorted(set(RUNTIME_REGIMES.values()))}")
        if self.throttle not in _THROTTLES:
            raise ValueError(f"throttle must be one of {_THROTTLES}, got "
                             f"{self.throttle!r}")
        if self.staleness is not None and self.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {self.staleness}")
        if self.aggregate and self.throttle != "wait":
            raise ValueError("aggregate=True is the wait throttle's BSP "
                             "mode; it cannot be combined with "
                             f"throttle={self.throttle!r}")
        if self.aggregate and self.staleness not in (None, 0):
            raise ValueError(
                f"aggregate=True admits workers in full-fleet cohorts, so "
                f"staleness={self.staleness} would be inert (every commit "
                f"lands at staleness 0) — set staleness to 0 or drop "
                f"aggregation")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Gradient push compression (``repro.compress``) on the PS regimes.

    ``scheme="int8"`` quantizes each push to int8 with per-tile fp32
    scales; ``"topk"`` keeps the ``topk_fraction`` largest-magnitude
    entries per flat buffer.  ``error_feedback`` carries each push's
    compression error into the next one (per worker, per layer).  Pulls
    always stay fp32 — the paper's asymmetric edge uplink is the
    bottleneck the wire savings target.
    """

    scheme: str = "none"             # none | int8 | topk
    topk_fraction: Optional[float] = None
    error_feedback: bool = True

    def __post_init__(self):
        from repro.compress import SCHEMES
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown compression scheme {self.scheme!r}; "
                             f"choose from {sorted(SCHEMES)}")
        if self.scheme == "topk":
            if self.topk_fraction is None:
                raise ValueError("scheme='topk' needs topk_fraction")
            if not 0.0 < self.topk_fraction <= 1.0:
                raise ValueError(f"topk_fraction must be in (0, 1], got "
                                 f"{self.topk_fraction}")
        elif self.topk_fraction is not None:
            raise ValueError(f"topk_fraction only applies to scheme='topk' "
                             f"(got scheme={self.scheme!r})")

    @property
    def enabled(self) -> bool:
        return self.scheme != "none"

    def build(self):
        """The :class:`repro.compress.Compressor` (``None`` when off)."""
        if not self.enabled:
            return None
        from repro.compress import make_compressor
        return make_compressor(self.scheme,
                               topk_fraction=self.topk_fraction,
                               error_feedback=self.error_feedback)


@dataclasses.dataclass(frozen=True)
class FleetEventConfig:
    """One scripted membership/environment change (``repro.fleet``).

    ``kind="join"`` may carry the joining worker's link/compute spec via
    ``down_gbps``/``up_gbps``/``flops`` (defaults when unset);
    ``kind="fail"`` picks ``mode`` (``crash`` | ``stall``);
    ``kind="drift"`` scales the worker's true iteration time by
    ``factor``.
    """

    time: float = 0.0
    kind: str = "join"
    worker: int = 0
    mode: str = "crash"
    factor: float = 1.0
    down_gbps: Optional[float] = None
    up_gbps: Optional[float] = None
    flops: Optional[float] = None

    def __post_init__(self):
        from repro.fleet.membership import FAIL_MODES, FLEET_EVENT_KINDS
        if self.kind not in FLEET_EVENT_KINDS:
            raise ValueError(f"kind must be one of {FLEET_EVENT_KINDS}, "
                             f"got {self.kind!r}")
        if self.mode not in FAIL_MODES:
            raise ValueError(f"mode must be one of {FAIL_MODES}, got "
                             f"{self.mode!r}")
        if self.time < 0:
            raise ValueError(f"time must be >= 0, got {self.time}")
        if self.factor <= 0:
            raise ValueError(f"factor must be positive, got {self.factor}")
        if self.kind != "join" and (self.down_gbps is not None or
                                    self.up_gbps is not None or
                                    self.flops is not None):
            raise ValueError(f"only join events carry a worker spec "
                             f"(got kind={self.kind!r})")

    def build(self):
        """The :class:`repro.fleet.FleetEvent` this block describes."""
        from repro.fleet.membership import FleetEvent, WorkerSpec
        spec = None
        if self.kind == "join" and (self.down_gbps is not None or
                                    self.up_gbps is not None or
                                    self.flops is not None):
            defaults = WorkerSpec()
            spec = WorkerSpec(
                down_bps=(self.down_gbps * 1e9 if self.down_gbps is not None
                          else defaults.down_bps),
                up_bps=(self.up_gbps * 1e9 if self.up_gbps is not None
                        else defaults.up_bps),
                flops=self.flops if self.flops is not None
                else defaults.flops)
        return FleetEvent(time=self.time, kind=self.kind,
                          worker=self.worker, mode=self.mode,
                          factor=self.factor, spec=spec)


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Elastic-fleet knobs of the ``fleet-async`` runtime.

    The membership script comes from either explicit ``events`` or a
    synthesized churn process (``churn`` events per simulated second up
    to ``horizon``, reproducible per ``churn_seed``) — not both.
    ``workers_per_shard > 0`` lets the server's shard count track the
    fleet (``S = ceil(active / workers_per_shard)``), re-sharding in
    place on membership changes.  The remaining knobs parameterize the
    failure detector and the per-worker drift detector.
    """

    events: Tuple[FleetEventConfig, ...] = ()
    churn: float = 0.0               # synthesized events per simulated second
    horizon: float = 0.0             # synthesized-churn time window
    churn_seed: int = 0
    workers_per_shard: int = 0       # 0 ⇒ shard count fixed by topology
    check_interval: float = 0.0      # 0 ⇒ slowest believed iteration
    stall_factor: float = 4.0
    drift_alpha: float = 0.2
    drift_threshold: float = 0.3
    drift_patience: int = 3
    drift_warmup: int = 2

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            FleetEventConfig(**e) if isinstance(e, dict) else e
            for e in self.events))
        if self.churn < 0:
            raise ValueError(f"churn must be >= 0, got {self.churn}")
        if self.churn > 0 and self.horizon <= 0:
            raise ValueError("synthesized churn needs a positive horizon")
        if self.churn > 0 and self.events:
            raise ValueError("give either explicit events or synthesized "
                             "churn, not both")
        if self.workers_per_shard < 0:
            raise ValueError(f"workers_per_shard must be >= 0, got "
                             f"{self.workers_per_shard}")
        if self.check_interval < 0:
            raise ValueError(f"check_interval must be >= 0, got "
                             f"{self.check_interval}")
        if self.stall_factor <= 1:
            raise ValueError(f"stall_factor must be > 1, got "
                             f"{self.stall_factor}")
        if not 0 < self.drift_alpha <= 1:
            raise ValueError(f"drift_alpha must be in (0, 1], got "
                             f"{self.drift_alpha}")
        if self.drift_threshold <= 0:
            raise ValueError(f"drift_threshold must be positive, got "
                             f"{self.drift_threshold}")
        if self.drift_patience < 1 or self.drift_warmup < 1:
            raise ValueError("drift_patience and drift_warmup must be >= 1")

    def build_schedule(self, initial_workers):
        """The :class:`repro.fleet.FleetSchedule` this block describes."""
        from repro.fleet.membership import FleetSchedule
        if self.churn > 0:
            return FleetSchedule.synthesize(
                initial_workers, churn=self.churn, horizon=self.horizon,
                seed=self.churn_seed)
        return FleetSchedule(tuple(e.build() for e in self.events))

    def build_detector(self):
        """The :class:`repro.fleet.FleetDriftDetector` this describes."""
        from repro.fleet.drift import FleetDriftDetector
        return FleetDriftDetector(alpha=self.drift_alpha,
                                  threshold=self.drift_threshold,
                                  patience=self.drift_patience,
                                  warmup=self.drift_warmup)


@dataclasses.dataclass(frozen=True)
class MeasureConfig:
    """Where fc/bc cost vectors come from."""

    cost_source: str = "analytic"    # analytic | measured
    remeasure_every: int = 1         # re-plan epochs between measurements
    measure_iters: int = 3
    measure_warmup: int = 1
    compute_flops_per_s: float = 1e10   # analytic host rate (ZeRO regimes)

    def __post_init__(self):
        if self.cost_source not in _COST_SOURCES:
            raise ValueError(f"cost_source must be one of {_COST_SOURCES}, "
                             f"got {self.cost_source!r}")
        if self.remeasure_every < 0:
            raise ValueError(f"remeasure_every must be >= 0, got "
                             f"{self.remeasure_every}")
        if self.measure_iters < 1:
            raise ValueError(f"measure_iters must be >= 1, got "
                             f"{self.measure_iters}")
        if self.measure_warmup < 0:
            raise ValueError(f"measure_warmup must be >= 0, got "
                             f"{self.measure_warmup}")
        if self.compute_flops_per_s <= 0:
            raise ValueError(f"compute_flops_per_s must be positive, got "
                             f"{self.compute_flops_per_s}")


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """One complete, serializable description of a training run."""

    runtime: str = "zero"
    arch: str = "granite-3-2b"
    reduced: bool = True
    batch: int = 8
    seq: int = 128
    optimizer: str = "adamw"
    lr: float = 3e-4
    seed: int = 0
    aux_weight: float = 0.01
    schedule: ScheduleConfig = dataclasses.field(default_factory=ScheduleConfig)
    execution: ExecutionConfig = dataclasses.field(
        default_factory=ExecutionConfig)
    measure: MeasureConfig = dataclasses.field(default_factory=MeasureConfig)
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)
    fleet: Optional[FleetConfig] = None
    pipeline: Optional[PipelineConfig] = None

    def __post_init__(self):
        if self.runtime not in RUNTIME_REGIMES:
            raise ValueError(f"unknown runtime {self.runtime!r}; choose "
                             f"from {sorted(RUNTIME_REGIMES)}")
        if self.optimizer not in ("adamw", "sgd"):
            raise ValueError(f"optimizer must be 'adamw' or 'sgd', got "
                             f"{self.optimizer!r}")
        if self.batch < 1 or self.seq < 1:
            raise ValueError(f"batch/seq must be >= 1, got "
                             f"{self.batch}/{self.seq}")
        regime = self.regime
        if self.execution.regime is not None and \
                self.execution.regime != regime:
            raise ValueError(
                f"execution.regime {self.execution.regime!r} contradicts "
                f"runtime {self.runtime!r} (which is {regime!r}); leave "
                f"regime unset to derive it")
        # cross-block consistency: fail at construction, not at step 1
        if regime != "ps-async":
            if self.execution.staleness is not None:
                raise ValueError(
                    f"staleness={self.execution.staleness} is a bounded-"
                    f"staleness (ps-async) knob; runtime {self.runtime!r} "
                    f"is synchronous — use runtime='ps-async' or "
                    f"'dynamic-ps-async'")
            if self.execution.aggregate:
                raise ValueError("aggregate=True is a ps-async knob; "
                                 f"runtime {self.runtime!r} is synchronous")
        if regime in ("zero", "local", "pipeline") and \
                self.schedule.topology is not None:
            raise ValueError(f"runtime {self.runtime!r} plans against a "
                             f"scalar network, not a PS topology — drop "
                             f"schedule.topology or pick a ps-* runtime")
        if regime.startswith("ps") and self.schedule.network is not None:
            raise ValueError(f"runtime {self.runtime!r} plans against a PS "
                             f"topology, not a scalar network — drop "
                             f"schedule.network or pick a zero/dynamic "
                             f"runtime")
        if self.runtime in ("zero", "pipeline") and \
                self.schedule.network is not None \
                and self.schedule.network.shift_gbps is not None:
            raise ValueError("a bandwidth shift needs the run-time loop to "
                             "react to it — use runtime='dynamic' (the "
                             f"{self.runtime!r} runtime plans once at "
                             f"startup)")
        if self.runtime in ("ps", "ps-async") and \
                self.schedule.topology is not None and \
                self.schedule.topology.up_shift_factor is not None:
            raise ValueError("an uplink drift needs the run-time loop to "
                             "react to it — use runtime='dynamic-ps' or "
                             f"'dynamic-ps-async' (the {self.runtime!r} "
                             f"runtime plans once at startup)")
        if self.fleet is not None and self.runtime != "fleet-async":
            raise ValueError(f"the fleet block configures the elastic "
                             f"'fleet-async' runtime (got runtime "
                             f"{self.runtime!r})")
        if self.pipeline is not None and self.runtime != "pipeline":
            raise ValueError(f"the pipeline block configures the "
                             f"'pipeline' runtime (got runtime "
                             f"{self.runtime!r})")
        if self.runtime == "pipeline":
            if self.pipeline is None:
                object.__setattr__(self, "pipeline", PipelineConfig())
            if self.batch % self.pipeline.microbatches:
                raise ValueError(
                    f"batch={self.batch} is not divisible by "
                    f"pipeline.microbatches={self.pipeline.microbatches}")
        if self.runtime == "fleet-async":
            if self.execution.aggregate:
                raise ValueError("aggregate=True needs fixed full-fleet "
                                 "cohorts; the elastic fleet-async runtime "
                                 "cannot aggregate — drop aggregation or "
                                 "use runtime='ps-async'")
            if self.schedule.topology is not None and \
                    self.schedule.topology.up_shift_factor is not None:
                raise ValueError("fleet-async re-plans off measured drift "
                                 "and membership events, not a scripted "
                                 "uplink shift — use a fleet drift event "
                                 "instead of up_shift_factor")
        if self.compression.enabled and not regime.startswith("ps"):
            raise ValueError(
                f"compression rides the PS push path (segmented gradient "
                f"uploads); runtime {self.runtime!r} is a {regime!r} regime "
                f"— pick a ps-* runtime or set compression.scheme='none'")
        if self.schedule.drift_detect and self.runtime != "dynamic":
            raise ValueError("drift_detect re-schedules from observed step "
                             "times, which only the 'dynamic' runtime "
                             f"supports (got runtime {self.runtime!r})")
        if self.measure.cost_source == "measured" and \
                self.runtime not in ("dynamic", "dynamic-ps"):
            raise ValueError("cost_source='measured' times the compiled "
                             "per-layer applies, which the dynamic sync "
                             "runtimes do (runtime 'dynamic' or "
                             f"'dynamic-ps'; got {self.runtime!r})")

    # ------------------------------------------------------------------
    # derived views
    # ------------------------------------------------------------------

    @property
    def regime(self) -> str:
        """The execution regime the runtime name implies."""
        return RUNTIME_REGIMES[self.runtime]

    @property
    def is_dynamic(self) -> bool:
        return self.runtime in DYNAMIC_RUNTIMES

    def build_optimizer(self):
        from repro.optim import adamw, sgd
        return adamw(self.lr) if self.optimizer == "adamw" \
            else sgd(self.lr, 0.9)

    # ------------------------------------------------------------------
    # JSON round-trip
    # ------------------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, obj: dict) -> "RuntimeConfig":
        obj = dict(obj)

        def sub(key, typ):
            val = obj.get(key)
            if isinstance(val, dict):
                obj[key] = typ(**val)

        sched = obj.get("schedule")
        if isinstance(sched, dict):
            sched = dict(sched)
            for key, typ in (("network", NetworkConfig),
                             ("topology", TopologyConfig)):
                if isinstance(sched.get(key), dict):
                    sched[key] = typ(**sched[key])
            obj["schedule"] = ScheduleConfig(**sched)
        sub("execution", ExecutionConfig)
        sub("measure", MeasureConfig)
        sub("compression", CompressionConfig)
        sub("fleet", FleetConfig)    # nested event dicts handled by its
                                     # __post_init__
        sub("pipeline", PipelineConfig)
        unknown = set(obj) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown RuntimeConfig fields "
                             f"{sorted(unknown)}")
        return cls(**obj)

    @classmethod
    def from_json(cls, text: str) -> "RuntimeConfig":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "RuntimeConfig":
        with open(path) as f:
            return cls.from_json(f.read())
