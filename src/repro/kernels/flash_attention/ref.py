"""Pure-jnp oracle: masked softmax attention (causal / window / softcap)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jnp.ndarray:
    """q: (B,H,Tq,hd); k,v: (B,H,Tk,hd) (heads pre-broadcast for GQA)."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    tq, tk = q.shape[2], k.shape[2]
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    ok = jnp.ones((tq, tk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, -np.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
