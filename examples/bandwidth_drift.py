"""Bandwidth-drift demo: watch the schedule re-segment mid-training.

The run-time loop of the paper (Section IV-C), end to end: a ~100M-param
model trains under the DynaComm-bucketed ZeRO trainer while the edge
uplink degrades from 10 Gbps to 1 Gbps at ``--shift-epoch``.  On the epoch
boundary the profiler re-derives pt/gt/Δt from the new network condition,
the DP re-plans, and ``DynamicTrainer`` swaps in the compiled step for the
new bucket plan (cached by plan, so a later recovery to 10 Gbps swaps back
without re-tracing).  The ASCII timelines show *why* the decision moves:
cheaper transmission favours more, smaller segments overlapped with
compute; an expensive link amortizes Δt over fewer, larger ones.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/bandwidth_drift.py --steps 60
"""

import argparse
import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.core import bandwidth_shift
from repro.core.viz import render_timeline
from repro.data.pipeline import SyntheticText
from repro.dist.dynamic import DynamicTrainer
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--steps-per-epoch", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--bw-gbps", type=float, default=10.0)
    ap.add_argument("--shift-gbps", type=float, default=1.0)
    ap.add_argument("--shift-epoch", type=int, default=1)
    ap.add_argument("--worker-flops", type=float, default=1e10)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(num_layers=args.layers,
                                      d_model=args.d_model, vocab=8192),
        name=f"{args.arch}-drift-demo")
    n_dev = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(n_dev,), ("data",))
    net = bandwidth_shift(args.bw_gbps * 1e9, args.shift_gbps * 1e9,
                          at_epoch=args.shift_epoch)
    print(f"devices: {n_dev}  arch: {cfg.name}  layers: {cfg.num_layers}  "
          f"uplink: {args.bw_gbps:g} Gbps → {args.shift_gbps:g} Gbps at "
          f"epoch {args.shift_epoch}")

    dyn = DynamicTrainer(cfg=cfg, mesh=mesh, optimizer=adamw(3e-4),
                         network=net, steps_per_epoch=args.steps_per_epoch,
                         compute_flops_per_s=args.worker_flops)
    state = dyn.init_state(jax.random.PRNGKey(0))
    pipe = SyntheticText(cfg.vocab_size, args.seq, args.batch, seed=0)
    state, _ = dyn.run(state, pipe.batch, args.steps, log_every=10)

    print("\nre-scheduling history:")
    shown = set()
    for e in dyn.events:
        ag, rs = dyn.hlo_counts(e.plan)
        print(f"  epoch {e.epoch:3d}: {len(e.plan.forward)} pull / "
              f"{len(e.plan.backward)} push buckets (hlo {ag} ag / {rs} rs)  "
              f"{'RE-SEGMENTED' if e.plan_changed else 'unchanged'}"
              f"{' via step cache' if e.plan_changed and not e.retraced else ''}"
              f"  sched {e.scheduling_seconds * 1e3:.2f} ms "
              f"hidden={e.overhead_hidden}")
        if e.plan not in shown:
            shown.add(e.plan)
            costs = dyn.costs_for_epoch(e.epoch, state, pipe.batch(e.step))
            # forward buckets back to the paper's 1-indexed segments
            segments = tuple((b[0] + 1, b[-1] + 1) for b in e.plan.forward)
            bw = net.model_at(e.epoch).bandwidth_bps / 1e9
            print(f"  --- forward timeline at {bw:g} Gbps ---")
            for line in render_timeline(costs, segments,
                                        phase="forward").splitlines():
                print(f"  {line}")

    changed = any(e.plan_changed for e in dyn.events)
    print(f"\nplans traced: {dyn.traces}  cache hits: {dyn.cache_hits}")
    print("schedule re-segmented under drift" if changed
          else "WARNING: decision did not change — try --worker-flops 1e9")


if __name__ == "__main__":
    main()
