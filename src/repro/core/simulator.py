"""Discrete-event execution simulator for a scheduled iteration.

Builds the explicit per-mini-procedure timeline implied by a decomposition
decision, enforcing the paper's partial-order constraints (eqs. 1-7), and
derives the stacked-bar decomposition of Figs. 5-8 (non-overlapping
computation / overlapping / non-overlapping communication).

The simulator is deliberately independent of the closed-form ``f_m`` in
``costmodel`` — tests assert both agree, which is the machine-checked version
of the paper's claim that ``f_m`` measures the schedule correctly.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.costmodel import (LayerCosts, PhaseBreakdown, Segment,
                                  TopologyCosts, phase_breakdown,
                                  validate_backward_segments,
                                  validate_forward_segments)


@dataclasses.dataclass(frozen=True)
class Event:
    kind: str            # 'pt' | 'fc' | 'bc' | 'gt'
    layers: Segment      # (lo, hi) covered
    start: float
    end: float


@dataclasses.dataclass(frozen=True)
class IterationTimeline:
    forward_events: Tuple[Event, ...]
    backward_events: Tuple[Event, ...]
    forward_time: float
    backward_time: float

    @property
    def total(self) -> float:
        return self.forward_time + self.backward_time

    def breakdown(self, phase: str) -> PhaseBreakdown:
        events = self.forward_events if phase == "forward" else self.backward_events
        comm = [(e.start, e.end) for e in events if e.kind in ("pt", "gt")]
        comp = [(e.start, e.end) for e in events if e.kind in ("fc", "bc")]
        return phase_breakdown(comm, comp)


def simulate_forward(costs: LayerCosts,
                     segments: Sequence[Segment]) -> Tuple[List[Event], float]:
    validate_forward_segments(segments, costs.num_layers)
    events: List[Event] = []
    link_free = 0.0
    comp_free = 0.0
    for lo, hi in segments:
        # transmission mini-procedure (includes its Δt setup)
        dur = costs.dt + float(np.sum(costs.pt[lo - 1:hi]))
        t0, t1 = link_free, link_free + dur
        events.append(Event("pt", (lo, hi), t0, t1))
        link_free = t1
        # per-layer forward compute mini-procedures within the segment
        for l in range(lo, hi + 1):
            start = max(comp_free, t1)  # eq. (1): needs this segment's params
            end = start + float(costs.fc[l - 1])
            events.append(Event("fc", (l, l), start, end))
            comp_free = end
    return events, comp_free


def simulate_backward(costs: LayerCosts,
                      segments: Sequence[Segment]) -> Tuple[List[Event], float]:
    validate_backward_segments(segments, costs.num_layers)
    events: List[Event] = []
    comp_free = 0.0
    link_free = 0.0
    for lo, hi in segments:
        # per-layer backward compute, layer hi down to lo (eq. 6)
        for l in range(hi, lo - 1, -1):
            end = comp_free + float(costs.bc[l - 1])
            events.append(Event("bc", (l, l), comp_free, end))
            comp_free = end
        # gradient push once the whole segment's grads exist (eq. 2)
        start = max(link_free, comp_free)
        dur = costs.dt_push + float(np.sum(costs.gt[lo - 1:hi]))
        events.append(Event("gt", (lo, hi), start, start + dur))
        link_free = start + dur
    return events, link_free


def simulate_iteration(costs: LayerCosts,
                       fwd_segments: Sequence[Segment],
                       bwd_segments: Sequence[Segment]) -> IterationTimeline:
    f_events, f_t = simulate_forward(costs, fwd_segments)
    b_events, b_t = simulate_backward(costs, bwd_segments)
    return IterationTimeline(tuple(f_events), tuple(b_events), f_t, b_t)


@dataclasses.dataclass(frozen=True)
class PSTimeline:
    """Per-worker timelines of one parameter-server iteration.

    Every worker runs the paper's pull → forward → backward → push pipeline
    against its own link; in synchronous mode the iteration ends at the
    straggler's last gradient push (``makespan``), and ``barrier_waits``
    is each worker's idle time at the barrier — the quantity asynchronous
    bounded-staleness execution reclaims."""

    workers: Tuple[IterationTimeline, ...]

    @property
    def num_workers(self) -> int:
        return len(self.workers)

    @property
    def makespan(self) -> float:
        return max(t.total for t in self.workers)

    @property
    def straggler(self) -> int:
        totals = [t.total for t in self.workers]
        return int(np.argmax(totals))

    @property
    def barrier_waits(self) -> Tuple[float, ...]:
        span = self.makespan
        return tuple(span - t.total for t in self.workers)


def simulate_ps_iteration(topo: TopologyCosts,
                          decisions) -> PSTimeline:
    """Simulate one PS iteration over every worker of a topology.

    ``decisions`` is either one shared ``(fwd, bwd)`` decision (synchronous
    mode) or a sequence of per-worker decisions (one per worker, the
    asynchronous planning mode)."""
    if len(decisions) == 2 and decisions[0] and \
            isinstance(decisions[0][0], tuple) and \
            isinstance(decisions[0][0][0], (int, np.integer)):
        decisions = [decisions] * topo.num_workers
    if len(decisions) != topo.num_workers:
        raise ValueError(f"got {len(decisions)} decisions for "
                         f"{topo.num_workers} workers")
    return PSTimeline(workers=tuple(
        simulate_iteration(costs, f, b)
        for costs, (f, b) in zip(topo.workers, decisions)))


@dataclasses.dataclass(frozen=True)
class PSReplanTimeline:
    """Per-epoch PS timelines of a run over a time-varying topology.

    For each topology epoch, two simulations of one synchronous iteration:
    ``replanned`` uses the decision derived from that epoch's costs (what
    ``repro.ps.dynamic.DynamicPSTrainer`` executes), ``frozen`` keeps the
    epoch-0 decision throughout (the plan-once baseline the paper's
    run-time loop exists to beat).  The gap is the stale-plan penalty."""

    replanned: Tuple[PSTimeline, ...]
    frozen: Tuple[PSTimeline, ...]

    def __post_init__(self):
        if len(self.replanned) != len(self.frozen):
            raise ValueError(f"{len(self.replanned)} replanned epochs vs "
                             f"{len(self.frozen)} frozen")
        if not self.replanned:
            raise ValueError("need at least one epoch")

    @property
    def num_epochs(self) -> int:
        return len(self.replanned)

    @property
    def makespans(self) -> Tuple[float, ...]:
        return tuple(t.makespan for t in self.replanned)

    @property
    def frozen_makespans(self) -> Tuple[float, ...]:
        return tuple(t.makespan for t in self.frozen)

    def stale_plan_penalty(self, epoch: int) -> float:
        """Seconds per iteration lost in ``epoch`` by keeping the epoch-0
        plan instead of re-planning (>= 0 whenever the re-plan is at least
        as good as the stale plan under the new costs)."""
        return self.frozen_makespans[epoch] - self.makespans[epoch]


def simulate_ps_replan(epoch_costs: Sequence[TopologyCosts],
                       epoch_decisions: Sequence,
                       ) -> PSReplanTimeline:
    """Simulate re-planned vs frozen execution over topology epochs.

    ``epoch_costs[e]`` is epoch ``e``'s projected :class:`TopologyCosts`;
    ``epoch_decisions[e]`` the decision derived from it (one shared
    decision or per-worker decisions, as ``simulate_ps_iteration``
    accepts).  The frozen baseline runs ``epoch_decisions[0]`` against
    every epoch's costs."""
    if len(epoch_costs) != len(epoch_decisions):
        raise ValueError(f"{len(epoch_costs)} epoch costs for "
                         f"{len(epoch_decisions)} decisions")
    replanned = tuple(simulate_ps_iteration(c, d)
                      for c, d in zip(epoch_costs, epoch_decisions))
    frozen = tuple(simulate_ps_iteration(c, epoch_decisions[0])
                   for c in epoch_costs)
    return PSReplanTimeline(replanned=replanned, frozen=frozen)


def check_partial_orders(timeline: IterationTimeline, L: int) -> None:
    """Assert the timeline satisfies eqs. (1)-(7).  Raises on violation."""
    eps = 1e-12

    def ends(events, kind):
        out = {}
        for e in events:
            if e.kind == kind:
                for l in range(e.layers[0], e.layers[1] + 1):
                    out[l] = e
        return out

    pt = ends(timeline.forward_events, "pt")
    fc = ends(timeline.forward_events, "fc")
    bc = ends(timeline.backward_events, "bc")
    gt = ends(timeline.backward_events, "gt")

    for l in range(1, L + 1):
        assert pt[l].end <= fc[l].start + eps, f"eq1 violated at layer {l}"
        assert bc[l].end <= gt[l].start + eps, f"eq2 violated at layer {l}"
    for l in range(1, L):
        assert pt[l].end <= pt[l + 1].end + eps, "eq4"
        assert fc[l].end <= fc[l + 1].start + eps, "eq5"
        assert bc[l + 1].end <= bc[l].start + eps, "eq6"
        assert gt[l + 1].end <= gt[l].end + eps, "eq7"
