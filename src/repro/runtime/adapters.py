"""Runtime adapters: the six trainers behind one ``Trainer`` protocol.

Each adapter owns everything a regime needs to run — mesh, optimizer,
model/arch config, trainer, training state, transfer accounting — and
presents the uniform protocol surface (``fit`` / ``step`` / ``events`` /
``timeline`` / ``ledger`` / ``save_state`` / ``restore_state``).  The
underlying trainer stays reachable as ``.trainer`` for regime-specific
introspection (HLO counts, plan caches, async run logs).

Unit of progress: a *training step* for the synchronous regimes, an
*accepted gradient push* for the asynchronous ones — ``fit(n)`` always
returns one loss per unit.  Checkpoints written by ``save_state`` embed
the serialized :class:`RuntimeConfig`, so a restore from a mismatched
runtime fails loudly instead of silently misinterpreting buffers.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.configs.base import ArchConfig, InputShape
from repro.runtime.config import (FleetConfig, NetworkConfig, RuntimeConfig,
                                  TopologyConfig)
from repro.runtime.registry import register_runtime

# per-worker data streams of the async regimes stay disjoint by striding
# the deterministic batch index (the convention every launcher used)
WORKER_STRIDE = 100003


def _data_mesh() -> Mesh:
    devs = jax.devices()
    return Mesh(np.array(devs).reshape(len(devs),), ("data",))


def _plan_ledger(specs, plan, workers: int,
                 compressor: Optional[Any] = None) -> Dict[str, int]:
    """One synchronous iteration's fleet-wide transfer accounting.

    ``push_wire_bytes`` is what the uplink actually carries: compressed
    per-layer payloads plus the per-segment header when a ``compressor``
    is active, the fp32 payload otherwise (pulls always stay fp32)."""
    from repro.dist.collectives import bucket_bytes
    pull = sum(bucket_bytes(specs, b) for b in plan.forward)
    push = sum(bucket_bytes(specs, b) for b in plan.backward)
    if compressor is None:
        push_wire = push
    else:
        push_wire = sum(
            int(round(sum(float(compressor.wire_bytes(specs[l].total * 4))
                          for l in b) + compressor.segment_overhead_bytes))
            for b in plan.backward)
    return {"pull_bytes": pull * workers, "push_bytes": push * workers,
            "pull_wire_bytes": pull * workers,
            "push_wire_bytes": push_wire * workers,
            "num_pulls": len(plan.forward) * workers,
            "num_pushes": len(plan.backward) * workers}


class RuntimeAdapter:
    """Shared bookkeeping of every registered runtime."""

    def __init__(self, config: RuntimeConfig, arch: ArchConfig,
                 batch_fn: Callable[[int], Any]):
        self.config = config
        self.arch = arch
        self._batch_fn = batch_fn
        self._data_idx = 0            # units of progress consumed
        self._eval_events: List[Any] = []
        self.shape = InputShape("runtime", config.seq, config.batch, "train")

    # -- protocol surface ------------------------------------------------

    @property
    def events(self) -> Sequence[Any]:
        return tuple(self._eval_events)

    def timeline(self) -> Optional[Any]:
        return None

    @property
    def ledger(self) -> Dict[str, Any]:
        return {"pull_bytes": 0, "push_bytes": 0,
                "pull_wire_bytes": 0, "push_wire_bytes": 0,
                "num_pulls": 0, "num_pushes": 0}

    @staticmethod
    def _check_eval(eval_fn, eval_every: int) -> None:
        if eval_fn is not None and eval_every < 1:
            raise ValueError(f"eval_fn needs eval_every >= 1, got "
                             f"{eval_every}")

    @staticmethod
    def _check_checkpoint(checkpoint_every: int,
                          checkpoint_path: Optional[str]) -> None:
        if checkpoint_every and checkpoint_path is None:
            raise ValueError("checkpoint_every needs a checkpoint_path")
        if checkpoint_path is not None and checkpoint_every < 1:
            raise ValueError(f"checkpoint_path needs checkpoint_every >= 1, "
                             f"got {checkpoint_every}")

    def _record_eval(self, eval_fn) -> None:
        from repro.runtime.protocol import EvalEvent
        self._eval_events.append(
            EvalEvent(unit=self._data_idx, loss=float(eval_fn())))

    def fit(self, steps: int, *, log_every: int = 0,
            eval_fn: Optional[Callable[[], float]] = None,
            eval_every: int = 0, checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None) -> List[float]:
        """Run ``steps`` units of progress from the configured data,
        printing a one-line progress report every ``log_every`` units.
        With ``eval_fn`` (zero-arg, returns a scalar loss), evaluate every
        ``eval_every`` units and record an ``EvalEvent`` into ``events``.
        With ``checkpoint_every``/``checkpoint_path``, ``save_state`` runs
        at every ``checkpoint_every``-unit boundary — a killed run
        restarts from the last periodic checkpoint."""
        self._check_eval(eval_fn, eval_every)
        self._check_checkpoint(checkpoint_every, checkpoint_path)
        losses = []
        for _ in range(steps):
            losses.append(self.step(self._batch_fn(self._data_idx)))
            if log_every and len(losses) % log_every == 0:
                print(f"step {self._data_idx:4d}  loss {losses[-1]:.4f}")
            if eval_fn is not None and self._data_idx % eval_every == 0:
                self._record_eval(eval_fn)
            if checkpoint_every and \
                    self._data_idx % checkpoint_every == 0:
                self.save_state(checkpoint_path)
        return losses

    def step(self, batch) -> float:
        raise NotImplementedError

    # -- checkpoint plumbing --------------------------------------------

    def _save_tree(self, path: str, tree: Dict[str, Any]) -> None:
        tree = dict(tree)
        tree["config"] = np.asarray(self.config.to_json(indent=None))
        tree["data_idx"] = np.asarray(self._data_idx, np.int64)
        save_checkpoint(path, tree, step=self._data_idx)

    def _load_tree(self, path: str,
                   template: Dict[str, Any]) -> Dict[str, Any]:
        # check the embedded config BEFORE interpreting any buffers: a
        # checkpoint from another regime must fail on provenance, not on
        # whichever template key happens to be missing first
        with np.load(path) as probe:
            if "config" not in probe.files:
                raise ValueError(f"{path} is not a runtime checkpoint "
                                 f"(no embedded config)")
            saved = RuntimeConfig.from_json(str(probe["config"]))
        if saved.runtime != self.config.runtime:
            raise ValueError(
                f"checkpoint {path} was written by runtime "
                f"{saved.runtime!r}; this runtime is "
                f"{self.config.runtime!r} — rebuild from the checkpoint's "
                f"own config")
        template = dict(template)
        template["config"] = np.asarray("")
        template["data_idx"] = np.zeros((), np.int64)
        tree, _ = load_checkpoint(path, template)
        self._data_idx = int(tree["data_idx"])
        return tree

    @staticmethod
    def _replace_like(current, restored):
        """Re-place restored numpy leaves on the current leaves' devices."""
        return jax.tree_util.tree_map(
            lambda cur, new: jax.device_put(
                jnp.asarray(new, cur.dtype), cur.sharding)
            if hasattr(cur, "sharding") else np.asarray(new),
            current, restored)


class _CompiledRuntime(RuntimeAdapter):
    """Base for the mesh-compiled synchronous regimes: holds the training
    state, a jitted step, and per-iteration transfer accounting."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        self._led = {"pull_bytes": 0, "push_bytes": 0,
                     "pull_wire_bytes": 0, "push_wire_bytes": 0,
                     "num_pulls": 0, "num_pushes": 0}
        self._led_by_plan: Dict[Any, Dict[str, int]] = {}

    def _account(self, specs, plan, workers: int,
                 compressor: Optional[Any] = None) -> None:
        if plan not in self._led_by_plan:
            self._led_by_plan[plan] = _plan_ledger(specs, plan, workers,
                                                   compressor)
        for k, v in self._led_by_plan[plan].items():
            self._led[k] += v

    @property
    def ledger(self) -> Dict[str, Any]:
        led = dict(self._led)
        led["push_compression_ratio"] = (
            led["push_bytes"] / led["push_wire_bytes"]
            if led["push_wire_bytes"] else 1.0)
        return led

    def save_state(self, path: str) -> None:
        self._save_tree(path, {"model": self._state})

    def restore_state(self, path: str) -> None:
        tree = self._load_tree(path, {"model": self._state})
        self._state = self._replace_like(self._state, tree["model"])


@register_runtime("local", description="single-process jit training, no "
                                       "distribution layer")
class LocalRuntime(RuntimeAdapter):
    """Plain jit training on whatever devices exist (no collectives)."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.models import init_params
        from repro.train.loop import build_train_step
        self.optimizer = config.build_optimizer()
        self._params = init_params(arch, jax.random.PRNGKey(config.seed))
        self._opt_state = self.optimizer.init(self._params)
        self._step_fn = jax.jit(build_train_step(
            arch, self.optimizer, aux_weight=config.aux_weight))

    def step(self, batch) -> float:
        self._params, self._opt_state, loss = self._step_fn(
            self._params, self._opt_state, batch)
        self._data_idx += 1
        return float(loss)

    def save_state(self, path: str) -> None:
        self._save_tree(path, {"params": self._params,
                               "opt": self._opt_state})

    def restore_state(self, path: str) -> None:
        tree = self._load_tree(path, {"params": self._params,
                                      "opt": self._opt_state})
        self._params = self._replace_like(self._params, tree["params"])
        self._opt_state = self._replace_like(self._opt_state, tree["opt"])


@register_runtime("zero", description="DynaComm-bucketed ZeRO trainer, "
                                      "plan decided once at startup")
class ZeroRuntime(_CompiledRuntime):
    """Profile → schedule → bucketed ZeRO trainer (static plan)."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.core import (DynaCommScheduler, costs_from_profiles,
                                plan_from_decision)
        from repro.dist.zero import ZeroTrainer
        from repro.models import num_sched_layers
        from repro.models.profiles import layer_profiles
        net = (config.schedule.network or NetworkConfig()).build()
        self._costs = costs_from_profiles(
            layer_profiles(arch, self.shape), net=net,
            compute_flops_per_s=config.measure.compute_flops_per_s)
        self.scheduler = DynaCommScheduler(
            strategy=config.schedule.strategy,
            reschedule_every=config.schedule.reschedule_every)
        self._decision = self.scheduler.decision_for_iteration(self._costs)
        plan = plan_from_decision(*self._decision, num_sched_layers(arch))
        self.trainer = ZeroTrainer(
            cfg=arch, mesh=_data_mesh(), plan=plan,
            optimizer=config.build_optimizer(),
            zero3=config.execution.zero3, aux_weight=config.aux_weight)
        self._state = self.trainer.init_state(
            jax.random.PRNGKey(config.seed))
        self._step_fn = jax.jit(self.trainer.build_train_step())

    @property
    def plan(self):
        return self.trainer.plan

    def step(self, batch) -> float:
        self._state, loss = self._step_fn(self._state, batch)
        self._account(self.trainer.specs, self.trainer.plan,
                      self.trainer.axis_size)
        self._data_idx += 1
        return float(loss)

    def timeline(self):
        from repro.core import simulate_iteration
        return simulate_iteration(self._costs, *self._decision)


@register_runtime("dynamic", description="run-time loop: re-profile + "
                                         "re-plan per epoch, swap compiled "
                                         "steps")
class DynamicRuntime(_CompiledRuntime):
    """Epoch-boundary re-scheduling (paper Section IV-C) over ZeRO."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.dist.dynamic import DynamicTrainer
        detector = None
        if config.schedule.drift_detect:
            from repro.core import EwmaDriftDetector
            detector = EwmaDriftDetector()
        net = (config.schedule.network or NetworkConfig()).build()
        self.trainer = DynamicTrainer(
            cfg=arch, mesh=_data_mesh(),
            optimizer=config.build_optimizer(), network=net,
            steps_per_epoch=config.schedule.reschedule_every,
            strategy=config.schedule.strategy, input_shape=self.shape,
            cost_source=config.measure.cost_source,
            compute_flops_per_s=config.measure.compute_flops_per_s,
            measure_iters=config.measure.measure_iters,
            measure_warmup=config.measure.measure_warmup,
            remeasure_every=config.measure.remeasure_every,
            drift_detector=detector, zero3=config.execution.zero3,
            aux_weight=config.aux_weight,
            async_planning=config.schedule.async_planning,
            plan_cache_size=config.schedule.plan_cache_size)
        self._state = self.trainer.init_state(
            jax.random.PRNGKey(config.seed))

    @property
    def events(self):
        return tuple(self.trainer.events) + tuple(self._eval_events)

    @property
    def plan(self):
        return self.trainer.plan

    def step(self, batch) -> float:
        self._state, loss = self.trainer.step(self._state, batch)
        self._account(self.trainer.base.specs, self.trainer.plan,
                      self.trainer.base.axis_size)
        self._data_idx += 1
        return float(loss)

    def timeline(self):
        return self.trainer.timeline()

    def save_state(self, path: str) -> None:
        super().save_state(path)
        self.trainer.save_loop_state(path + ".loop")

    def restore_state(self, path: str) -> None:
        super().restore_state(path)
        self.trainer.restore_loop_state(path + ".loop")


class _PSBase(_CompiledRuntime):
    """Shared topology construction for the synchronous PS regimes."""

    def _build_topology(self):
        topo_cfg = self.config.schedule.topology or TopologyConfig()
        return topo_cfg.build(default_workers=len(jax.devices()))


@register_runtime("ps", description="synchronous parameter-server "
                                    "execution: consensus plan, one pull + "
                                    "one push per segment")
class PSRuntime(_PSBase):
    """Sync PS: segmented pull/push on the mesh (== ZeRO bitwise)."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.ps import PSTrainer
        self.trainer = PSTrainer.from_topology(
            arch, _data_mesh(), self._build_topology(),
            config.build_optimizer(), self.shape,
            strategy=config.schedule.strategy,
            compressor=config.compression.build(),
            zero3=config.execution.zero3, aux_weight=config.aux_weight)
        self._state = self.trainer.init_state(
            jax.random.PRNGKey(config.seed))
        self._step_fn = jax.jit(self.trainer.build_train_step())

    @property
    def plan(self):
        return self.trainer.plan

    def step(self, batch) -> float:
        self._state, loss = self._step_fn(self._state, batch)
        self._account(self.trainer.specs, self.trainer.plan,
                      self.trainer.topology.num_workers,
                      self.trainer.compressor)
        self._data_idx += 1
        return float(loss)

    def timeline(self):
        return self.trainer.timeline(self.shape)


@register_runtime("dynamic-ps", description="run-time loop in the PS "
                                            "regime: consensus re-plan per "
                                            "topology epoch")
class DynamicPSRuntime(_PSBase):
    """Topology-epoch re-planning over the sync PS trainer."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.ps import DynamicPSTrainer
        self.trainer = DynamicPSTrainer(
            cfg=arch, mesh=_data_mesh(),
            optimizer=config.build_optimizer(),
            topology=self._build_topology(),
            steps_per_epoch=config.schedule.reschedule_every,
            input_shape=self.shape, strategy=config.schedule.strategy,
            zero3=config.execution.zero3, aux_weight=config.aux_weight,
            compressor=config.compression.build(),
            cost_source=config.measure.cost_source,
            remeasure_every=config.measure.remeasure_every,
            measure_iters=config.measure.measure_iters,
            measure_warmup=config.measure.measure_warmup,
            async_planning=config.schedule.async_planning,
            plan_cache_size=config.schedule.plan_cache_size)
        self._state = self.trainer.init_state(
            jax.random.PRNGKey(config.seed))

    @property
    def events(self):
        return tuple(self.trainer.events) + tuple(self._eval_events)

    @property
    def plan(self):
        return self.trainer.plan

    def step(self, batch) -> float:
        self._state, loss = self.trainer.step(self._state, batch)
        self._account(self.trainer.base.specs, self.trainer.plan,
                      self.trainer.base.topology.num_workers,
                      self.trainer.compressor)
        self._data_idx += 1
        return float(loss)

    def timeline(self):
        return None if self.trainer.plan is None else self.trainer.timeline()

    def save_state(self, path: str) -> None:
        super().save_state(path)
        self.trainer.save_loop_state(path + ".loop")

    def restore_state(self, path: str) -> None:
        super().restore_state(path)
        self.trainer.restore_loop_state(path + ".loop")


class _AsyncBase(RuntimeAdapter):
    """Shared machinery of the asynchronous (event-loop) regimes.

    A unit of progress is one *accepted* gradient push.  ``fit`` drives
    the per-worker deterministic data streams; ``step(batch)`` feeds the
    given batch to every worker attempt until one more push commits.
    Under BSP aggregation a whole same-version group commits at once;
    ``step`` then returns the group's mean loss (the synchronous-step
    convention) and ``fit`` may return up to ``W - 1`` more losses than
    requested.
    """

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.models import (init_params, params_from_sched_layers,
                                  sched_layer_trees, train_loss)
        self._layers = sched_layer_trees(
            init_params(arch, jax.random.PRNGKey(config.seed)))
        aux = config.aux_weight

        def loss_fn(layer_list, batch):
            return train_loss(arch, params_from_sched_layers(layer_list),
                              batch, aux_weight=aux)

        self._loss_fn = loss_fn
        self._started = False
        self._reported = 0           # accepted events already returned

    # each concrete class provides: _run_pushes(n, wfn) -> AsyncRunLog,
    # and a `_server` property
    def _run_pushes(self, num_pushes, worker_batch_fn):
        raise NotImplementedError

    @property
    def _server(self):
        raise NotImplementedError

    def _worker_batch_fn(self):
        fn = self._batch_fn
        return lambda w, i: fn(w * WORKER_STRIDE + i)

    def _drive(self, pushes: int, wfn) -> List[float]:
        log = self._run_pushes(pushes, wfn)
        self._started = True
        fresh = log.accepted[self._reported:]
        self._reported = len(log.accepted)
        self._data_idx += len(fresh)
        return [e.loss for e in fresh]

    def fit(self, steps: int, *, log_every: int = 0,
            eval_fn: Optional[Callable[[], float]] = None,
            eval_every: int = 0, checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None) -> List[float]:
        # accepted pushes land in chunks (BSP aggregation can commit a
        # whole cohort), so evals and checkpoints trigger on *boundary
        # crossings* of the cumulative push count rather than exact
        # multiples
        self._check_eval(eval_fn, eval_every)
        self._check_checkpoint(checkpoint_every, checkpoint_path)
        losses: List[float] = []
        wfn = self._worker_batch_fn()
        while len(losses) < steps:
            chunk = min(log_every or steps, steps - len(losses))
            if eval_fn is not None:
                chunk = min(chunk, eval_every - self._data_idx % eval_every)
            if checkpoint_every:
                chunk = min(chunk, checkpoint_every -
                            self._data_idx % checkpoint_every)
            before = self._data_idx
            losses.extend(self._drive(chunk, wfn))
            if log_every:
                print(f"push {self._data_idx:4d}  loss {losses[-1]:.4f}")
            if eval_fn is not None and \
                    self._data_idx // eval_every > before // eval_every:
                self._record_eval(eval_fn)
            if checkpoint_every and self._data_idx // checkpoint_every > \
                    before // checkpoint_every:
                self.save_state(checkpoint_path)
        return losses

    def step(self, batch) -> float:
        fresh = self._drive(1, lambda w, i: batch)
        return float(np.mean(fresh))

    @property
    def ledger(self) -> Dict[str, Any]:
        led = self._server.ledger
        return {"pull_bytes": sum(led.pulled_bytes.values()),
                "push_bytes": sum(led.pushed_bytes.values()),
                "pull_wire_bytes": sum(led.pulled_wire_bytes.values()),
                "push_wire_bytes": sum(led.pushed_wire_bytes.values()),
                "push_compression_ratio": led.compression_ratio("push"),
                "num_pulls": led.num_pulls,
                "num_pushes": led.num_pushes,
                "rejected_pushes": led.rejected_pushes,
                "waited_pushes": led.waited_pushes}

    def save_state(self, path: str) -> None:
        """Checkpoint the server's head parameters + optimizer state.

        Event-loop state (in-flight computations) is not serialized; the
        restore discards the loop, so training resumes from the restored
        parameters at simulated time 0."""
        self._save_tree(path, {"server": self._server.state_dict()})

    def restore_state(self, path: str) -> None:
        tree = self._load_tree(path,
                               {"server": self._server.state_dict()})
        self._server.load_state_dict(tree["server"])
        # in-flight gradients were computed against pre-restore weights
        # and pinned at pre-restore versions: committing them against the
        # rolled-back server would corrupt the trajectory
        self._reset_after_restore()
        self._started = False
        self._reported = 0

    def _reset_after_restore(self) -> None:
        self.trainer.reset_loop()


@register_runtime("ps-async", description="bounded-staleness asynchronous "
                                          "PS: reject or SSP-wait "
                                          "throttle, optional BSP "
                                          "aggregation")
class PSAsyncRuntime(_AsyncBase):
    """Event-driven bounded-staleness execution over a static topology."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.core import plan_from_decision
        from repro.core.scheduler import consensus_decision
        from repro.models import num_sched_layers
        from repro.models.profiles import layer_profiles
        from repro.ps import AsyncPSTrainer
        topo_cfg = config.schedule.topology or TopologyConfig()
        topo = topo_cfg.build(default_workers=len(jax.devices()))
        comp = config.compression.build()
        costs = topo.topology_costs(layer_profiles(arch, self.shape),
                                    compressor=comp)
        decision, self.sync_makespan = consensus_decision(
            costs, config.schedule.strategy)
        plan = plan_from_decision(*decision, num_sched_layers(arch))
        self.trainer = AsyncPSTrainer(
            init_layers=self._layers, loss_fn=self._loss_fn,
            optimizer=config.build_optimizer(), topology=topo, plan=plan,
            staleness=config.execution.staleness or 0,
            throttle=config.execution.throttle,
            aggregate=config.execution.aggregate, costs=costs,
            compressor=comp)

    @property
    def _server(self):
        return self.trainer.server

    def _run_pushes(self, num_pushes, wfn):
        return self.trainer.run(num_pushes, wfn, reset=not self._started)

    def timeline(self):
        return self.trainer.log


@register_runtime("dynamic-ps-async",
                  description="per-worker re-planning per topology epoch "
                              "over the bounded-staleness event loop")
class DynamicPSAsyncRuntime(_AsyncBase):
    """Per-worker re-plans swapped into the async loop on epoch bounds."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.models.profiles import layer_profiles
        from repro.ps import DynamicAsyncPSTrainer
        topo_cfg = config.schedule.topology or TopologyConfig()
        topo = topo_cfg.build(default_workers=len(jax.devices()))
        self.trainer = DynamicAsyncPSTrainer(
            init_layers=self._layers, loss_fn=self._loss_fn,
            optimizer=config.build_optimizer(), topology=topo,
            pushes_per_epoch=config.schedule.reschedule_every,
            staleness=config.execution.staleness or 0,
            throttle=config.execution.throttle,
            aggregate=config.execution.aggregate,
            strategy=config.schedule.strategy,
            profiles=layer_profiles(arch, self.shape),
            compressor=config.compression.build(),
            async_planning=config.schedule.async_planning,
            plan_cache_size=config.schedule.plan_cache_size)

    @property
    def events(self):
        return tuple(self.trainer.events) + tuple(self._eval_events)

    @property
    def _server(self):
        return self.trainer.trainer.server

    def _run_pushes(self, num_pushes, wfn):
        return self.trainer.run_pushes(num_pushes, wfn)

    def timeline(self):
        return self.trainer.trainer.log


@register_runtime("fleet-async",
                  description="elastic worker fleet on the deterministic "
                              "event engine: churn-driven re-planning, "
                              "server re-sharding, measured drift "
                              "detection")
class FleetRuntime(_AsyncBase):
    """Elastic membership over the bounded-staleness event loop.

    The initial fleet comes from the topology block (one
    :class:`~repro.fleet.WorkerSpec` per configured link); the fleet
    block scripts or synthesizes membership churn and tunes the stall
    and drift detectors.  Unlike the other async adapters, ``save_state``
    also serializes the *event-loop* state (in-flight work, admission
    queue, simulated clock), so a restored run resumes mid-simulation
    bit-identically instead of restarting the loop at time 0."""

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.fleet import FleetTrainer, WorkerSpec
        from repro.models.profiles import layer_profiles
        topo_cfg = config.schedule.topology or TopologyConfig()
        topo = topo_cfg.build(default_workers=len(jax.devices()))
        specs = {w: WorkerSpec(down_bps=link.down.bandwidth_bps,
                               up_bps=link.up.bandwidth_bps,
                               flops=topo.worker_flops[w])
                 for w, link in enumerate(topo.links)}
        fleet_cfg = config.fleet or FleetConfig()
        self.trainer = FleetTrainer(
            init_layers=self._layers, loss_fn=self._loss_fn,
            optimizer=config.build_optimizer(), workers=specs,
            schedule=fleet_cfg.build_schedule(tuple(specs)),
            num_servers=topo.num_servers,
            workers_per_shard=fleet_cfg.workers_per_shard,
            staleness=config.execution.staleness or 0,
            throttle=config.execution.throttle,
            strategy=config.schedule.strategy,
            profiles=layer_profiles(arch, self.shape),
            compressor=config.compression.build(),
            drift_detector=fleet_cfg.build_detector(),
            stall_factor=fleet_cfg.stall_factor,
            check_interval=fleet_cfg.check_interval,
            async_planning=config.schedule.async_planning,
            plan_cache_size=config.schedule.plan_cache_size)

    @property
    def events(self):
        timed = sorted(tuple(self.trainer.replan_events) +
                       tuple(self.trainer.membership_events),
                       key=lambda e: e.sim_time)
        return tuple(timed) + tuple(self._eval_events)

    @property
    def _server(self):
        return self.trainer.server

    def _run_pushes(self, num_pushes, wfn):
        return self.trainer.run(num_pushes, wfn, reset=not self._started)

    def timeline(self):
        return self.trainer.log

    def save_state(self, path: str) -> None:
        """Checkpoint server state plus the live event loop.

        The loop (engine queue, in-flight gradients, SSP barrier,
        membership roster, detector streams, run log) lands next to the
        parameter checkpoint at ``path + ".loop"``."""
        self._save_tree(path, {"server": self.trainer.server.state_dict()})
        self.trainer.save_loop_state(path + ".loop")

    def restore_state(self, path: str) -> None:
        tree = self._load_tree(path,
                               {"server": self.trainer.server.state_dict()})
        self.trainer.server.load_state_dict(tree["server"])
        self.trainer.restore_loop_state(path + ".loop")
        # the loop resumes mid-simulation: keep driving the restored run
        # instead of resetting to time 0
        self._started = True
        log = self.trainer.log
        self._reported = len(log.accepted) if log is not None else 0


@register_runtime("pipeline",
                  description="stage-partitioned pipeline parallelism with "
                              "DynaComm-scheduled activation transfers")
class PipelineRuntime(_CompiledRuntime):
    """Profile → DP stage partition → micro-batch pipeline execution.

    Stages are balanced by profiled fc + bc via
    :func:`repro.pipeline.partition_profiles`; inter-stage activation
    traffic is planned through the shared edge cost model
    (``dp_forward``/``dp_backward`` over virtual boundary layers) riding a
    :class:`~repro.core.planner.Planner`, so homogeneous boundaries are
    one DP solve plus cache hits.  Losses are bit-identical to the
    single-stage execution of the same decomposition at any stage count.
    """

    def __init__(self, config, arch, batch_fn):
        super().__init__(config, arch, batch_fn)
        from repro.core import costs_from_profiles
        from repro.core.planner import Planner
        from repro.models.profiles import layer_profiles
        from repro.pipeline import PipelineTrainer, partition_profiles
        pcfg = config.pipeline        # materialized by RuntimeConfig
        net = (config.schedule.network or NetworkConfig()).build()
        profiles = layer_profiles(arch, self.shape)
        partition = partition_profiles(
            profiles, pcfg.stages,
            compute_flops_per_s=config.measure.compute_flops_per_s)
        self._costs = costs_from_profiles(
            profiles, net=net,
            compute_flops_per_s=config.measure.compute_flops_per_s)
        self.planner = Planner(cache_size=config.schedule.plan_cache_size)
        self.trainer = PipelineTrainer(
            cfg=arch, optimizer=config.build_optimizer(),
            num_stages=pcfg.stages, num_microbatches=pcfg.microbatches,
            schedule_name=pcfg.schedule, aux_weight=config.aux_weight,
            partition=partition, planner=self.planner,
            transfer_strategy=config.schedule.strategy,
            costs=self._costs, net=net, transfer_chunks=pcfg.chunks)
        self._state = self.trainer.init_state(
            jax.random.PRNGKey(config.seed))

    @property
    def partition(self):
        return self.trainer.partition

    def step(self, batch) -> float:
        self._state, loss = self.trainer.step(self._state, batch)
        self._data_idx += 1
        return float(loss)

    @property
    def ledger(self) -> Dict[str, Any]:
        led = dict(self.trainer.ledger)
        led["push_compression_ratio"] = 1.0   # activations stay fp32
        return led

    def timeline(self):
        return self.trainer.timeline()
