"""Schedule-conformance verification over compiled HLO (layer 1).

DynaComm's structural claim is that the compiled step contains *exactly*
the collectives the DP decision prescribes: one all-gather (parameter
pull) per forward bucket, one reduce-scatter (gradient push) per
backward bucket, each moving exactly the ``FlatSpec`` flat-buffer bytes
— and nothing else crossing replicas.  :func:`verify_schedule` checks a
compiled HLO dump against a :class:`~repro.core.buckets.BucketPlan` and
the trainer's specs; :func:`verify_cache` audits a
:class:`~repro.runtime.replan.PlanStepCache` (one compilation per
distinct plan); :func:`verify_wire_model` and
:func:`verify_push_ledger` prove the compressed wire-byte accounting
exact against an *independent* reimplementation of the compressor byte
formulas.

Expected operand bytes (empirically pinned against XLA's partitioner,
see the golden fixtures):

* all-gather of forward bucket ``b`` operates on the concatenated local
  shards — ``4 * sum(padded_l // axis_size for l in b)`` bytes;
* reduce-scatter of backward bucket ``b`` operates on the stacked
  ``(axis_size, shard)`` gradient — ``4 * sum(padded_l for l in b)``
  bytes (compressed pushes roundtrip to f32 *before* the collective, so
  HLO operands stay f32 — wire compression is verified at the byte-model
  layer instead);
* one scalar all-reduce (the loss ``pmean``) is tolerated below
  ``small_collective_bytes``.

Pure stdlib + :mod:`repro.analysis.hlo`: no jax import, so conformance
over golden fixtures runs without a compile.
"""

from __future__ import annotations

import math
from collections import Counter
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.hlo import ModuleOrText, _as_module, collective_summary

__all__ = [
    "expected_ag_bytes", "expected_rs_bytes", "independent_wire_bytes",
    "segment_wire_bytes", "verify_schedule", "verify_no_collectives",
    "verify_cache", "verify_wire_model", "verify_push_ledger",
    "verify_fleet_membership",
]

# Int8 wire layout: 1 byte/element + one fp32 scale per quantization
# tile.  Deliberately NOT imported from repro.kernels.compress.ops.TILE:
# this module re-derives the wire math independently of the code under
# audit (a test pins the two constants to each other).
INT8_TILE = 512

#: Collectives at or below this operand size are treated as scalar-loss
#: reductions (the ``pmean`` of the per-device loss) and not flagged.
SMALL_COLLECTIVE_BYTES = 1024


# ---------------------------------------------------------------------------
# expected byte math
# ---------------------------------------------------------------------------

def expected_ag_bytes(specs: Sequence[Any], plan: Any, *,
                      zero3: bool = False) -> List[int]:
    """Expected all-gather operand bytes, one entry per gather.

    With ``zero3`` every backward bucket containing a middle layer
    re-pulls its full bucket (one extra gather of the same byte shape as
    a forward gather of that bucket)."""
    def bucket_bytes(bucket):
        return 4 * sum(specs[l].padded // specs[l].axis_size for l in bucket)

    out = [bucket_bytes(b) for b in plan.forward]
    if zero3:
        num_layers = len(specs)
        out += [bucket_bytes(b) for b in plan.backward
                if any(0 < l < num_layers - 1 for l in b)]
    return out


def expected_rs_bytes(specs: Sequence[Any], plan: Any) -> List[int]:
    """Expected reduce-scatter operand bytes, one entry per backward
    bucket (the stacked ``(axis_size, shard)`` gradient)."""
    return [4 * sum(specs[l].padded for l in b) for b in plan.backward]


def independent_wire_bytes(compressor: Optional[Any],
                           logical_bytes: float) -> float:
    """Wire bytes of one fp32 buffer, re-derived from the published
    formulas rather than ``compressor.wire_bytes`` (which is the code
    under audit)."""
    scheme = getattr(compressor, "scheme", "none") if compressor else "none"
    if scheme == "none":
        return float(logical_bytes)
    n = logical_bytes / 4.0
    if scheme == "int8":
        return n + 4.0 * math.ceil(n / INT8_TILE)
    if scheme == "topk":
        return 8.0 * max(1.0, math.ceil(compressor.fraction * n))
    raise ValueError(f"unknown compression scheme {scheme!r}")


def segment_wire_bytes(specs: Sequence[Any], bucket: Sequence[int],
                       compressor: Optional[Any]) -> int:
    """Wire bytes of one push segment under the independent byte model
    (mirrors ``PSServer.push_wire_bytes``: per-layer payloads plus one
    per-segment header, rounded once)."""
    overhead = getattr(compressor, "segment_overhead_bytes", 0.0) \
        if compressor else 0.0
    return int(round(sum(independent_wire_bytes(compressor,
                                                specs[l].total * 4)
                         for l in bucket) + overhead))


# ---------------------------------------------------------------------------
# conformance passes
# ---------------------------------------------------------------------------

def _multiset_diff(expected: Sequence[int], observed: Sequence[int]
                   ) -> Tuple[List[int], List[int]]:
    """(missing-from-observed, unexpected-in-observed)."""
    exp, obs = Counter(expected), Counter(observed)
    missing = sorted((exp - obs).elements())
    extra = sorted((obs - exp).elements())
    return missing, extra


def verify_schedule(hlo: ModuleOrText, plan: Any, specs: Sequence[Any], *,
                    compressor: Optional[Any] = None, zero3: bool = False,
                    small_collective_bytes: int = SMALL_COLLECTIVE_BYTES,
                    context: str = "") -> List[Finding]:
    """Check one compiled step's HLO against its ``BucketPlan``.

    Returns an empty list iff the module contains exactly one all-gather
    per forward bucket (plus zero3 re-gathers) and one reduce-scatter
    per backward bucket, with operand bytes matching the ``FlatSpec``
    byte math as a multiset, and no other cross-replica collective above
    the scalar-loss threshold.  With a single-device ``axis_size`` XLA
    elides the collectives entirely, so only the stray-collective check
    runs.  The wire-byte model (compression exactness) is checked by
    :func:`verify_wire_model`, appended here when a compressor is given.
    """
    findings: List[Finding] = []
    ctx = {"context": context} if context else {}
    summary = collective_summary(hlo)
    axis_size = specs[0].axis_size if len(specs) else 1

    if axis_size > 1:
        exp_ag = expected_ag_bytes(specs, plan, zero3=zero3)
        exp_rs = expected_rs_bytes(specs, plan)
        obs_ag = [b for _, b in summary["all-gather"]]
        obs_rs = [b for _, b in summary["reduce-scatter"]]

        if len(obs_ag) != len(exp_ag):
            findings.append(Finding(
                code="SCHED-AG-COUNT",
                message=f"{len(obs_ag)} all-gathers compiled, plan "
                        f"prescribes {len(exp_ag)} "
                        f"({len(plan.forward)} forward buckets"
                        + (", zero3 re-gathers included)" if zero3 else ")"),
                detail={"expected": len(exp_ag), "observed": len(obs_ag),
                        **ctx}))
        if len(obs_rs) != len(exp_rs):
            findings.append(Finding(
                code="SCHED-RS-COUNT",
                message=f"{len(obs_rs)} reduce-scatters compiled, plan "
                        f"prescribes {len(exp_rs)} backward buckets",
                detail={"expected": len(exp_rs), "observed": len(obs_rs),
                        **ctx}))

        for code, kind, exp, obs in (
                ("SCHED-AG-BYTES", "all-gather", exp_ag, obs_ag),
                ("SCHED-RS-BYTES", "reduce-scatter", exp_rs, obs_rs)):
            missing, extra = _multiset_diff(exp, obs)
            if missing or extra:
                findings.append(Finding(
                    code=code,
                    message=f"{kind} operand bytes do not match the "
                            f"FlatSpec byte math: missing {missing}, "
                            f"unexpected {extra}",
                    detail={"expected": sorted(exp),
                            "observed": sorted(obs), **ctx}))

    # stray cross-replica collectives outside the plan
    for kind in ("all-to-all", "collective-permute"):
        for instr, nbytes in summary[kind]:
            findings.append(Finding(
                code="SCHED-STRAY-COLLECTIVE",
                message=f"stray {kind} ({nbytes} operand bytes, "
                        f"%{instr.name}) — the plan prescribes none",
                detail={"opcode": kind, "name": instr.name,
                        "bytes": nbytes, **ctx}))
    for instr, nbytes in summary["all-reduce"]:
        if nbytes > small_collective_bytes:
            findings.append(Finding(
                code="SCHED-STRAY-COLLECTIVE",
                message=f"all-reduce of {nbytes} operand bytes "
                        f"(%{instr.name}) exceeds the scalar-loss "
                        f"threshold ({small_collective_bytes} B) — "
                        f"gradient traffic must go through the "
                        f"scheduled reduce-scatters",
                detail={"opcode": "all-reduce", "name": instr.name,
                        "bytes": nbytes, **ctx}))

    if compressor is not None:
        findings.extend(verify_wire_model(specs, plan, compressor,
                                          context=context))
    return findings


def verify_no_collectives(hlo: ModuleOrText, *,
                          small_collective_bytes: int =
                          SMALL_COLLECTIVE_BYTES,
                          context: str = "") -> List[Finding]:
    """A module that must contain **no** cross-replica traffic at all
    (the local runtime's step, the async trainers' single-jit gradient
    — their communication is explicit server messages, never
    collectives).  Sub-threshold scalar reductions are tolerated."""
    findings: List[Finding] = []
    ctx = {"context": context} if context else {}
    for kind, entries in collective_summary(hlo).items():
        for instr, nbytes in entries:
            if nbytes <= small_collective_bytes:
                continue
            findings.append(Finding(
                code="SCHED-STRAY-COLLECTIVE",
                message=f"{kind} of {nbytes} operand bytes "
                        f"(%{instr.name}) in a module that must contain "
                        f"no cross-replica collectives",
                detail={"opcode": kind, "name": instr.name,
                        "bytes": nbytes, **ctx}))
    return findings


def verify_wire_model(specs: Sequence[Any], plan: Any, compressor: Any, *,
                      context: str = "") -> List[Finding]:
    """Exactness of the compressed wire-byte accounting.

    Recomputes every backward segment's wire bytes from the published
    int8/top-k formulas (:func:`independent_wire_bytes`) and requires
    the repo's own ``compressor.wire_bytes`` accounting (what
    ``PSServer.push_wire_bytes`` and the ledgers record) to agree to the
    integer."""
    findings: List[Finding] = []
    ctx = {"context": context} if context else {}
    overhead = getattr(compressor, "segment_overhead_bytes", 0.0)
    for i, bucket in enumerate(plan.backward):
        expected = segment_wire_bytes(specs, bucket, compressor)
        actual = int(round(sum(
            float(compressor.wire_bytes(specs[l].total * 4))
            for l in bucket) + overhead))
        if actual != expected:
            findings.append(Finding(
                code="SCHED-WIRE-BYTES",
                message=f"backward segment {i} ({tuple(bucket)}): "
                        f"compressor accounts {actual} wire bytes, "
                        f"independent {compressor.scheme} formula gives "
                        f"{expected}",
                detail={"segment": list(bucket), "expected": expected,
                        "actual": actual, "scheme": compressor.scheme,
                        **ctx}))
    return findings


def verify_cache(cache: Any, *, specs: Optional[Sequence[Any]] = None,
                 zero3: bool = False, context: str = "") -> List[Finding]:
    """Retrace audit of a ``PlanStepCache``: exactly one compilation per
    distinct ``BucketPlan``, and each cached step's collective counts
    match its plan's bucket counts."""
    findings: List[Finding] = []
    ctx = {"context": context} if context else {}
    plans = cache.plans
    if cache.traces != len(plans):
        findings.append(Finding(
            code="SCHED-CACHE-RETRACE",
            message=f"{cache.traces} compilations for {len(plans)} "
                    f"distinct plans — revisited plans must be served "
                    f"from the cache",
            detail={"traces": cache.traces, "plans": len(plans), **ctx}))
    single_device = specs is not None and len(specs) \
        and specs[0].axis_size == 1
    for plan in plans:
        n_ag, n_rs = cache.hlo_counts(plan)
        exp_ag = len(plan.forward)
        if zero3:
            num_layers = max(max(b) for b in plan.forward) + 1
            exp_ag += sum(1 for b in plan.backward
                          if any(0 < l < num_layers - 1 for l in b))
        exp_rs = len(plan.backward)
        # one device: XLA either elides the single-replica collectives
        # or compiles them as degenerate ops — both shapes are conformant
        ok = {(exp_ag, exp_rs), (0, 0)} if single_device \
            else {(exp_ag, exp_rs)}
        if (n_ag, n_rs) not in ok:
            findings.append(Finding(
                code="SCHED-CACHE-COUNTS",
                message=f"cached step for plan {plan} compiled "
                        f"{n_ag} all-gathers / {n_rs} reduce-scatters, "
                        f"expected {exp_ag} / {exp_rs}"
                        + (" (or 0 / 0 elided)" if single_device else ""),
                detail={"expected": [exp_ag, exp_rs],
                        "observed": [n_ag, n_rs], **ctx}))
    return findings


def verify_push_ledger(ledger: Any, plans_by_worker: Dict[int, Any],
                       specs: Sequence[Any], compressor: Optional[Any], *,
                       context: str = "") -> List[Finding]:
    """Per-worker wire-byte audit of a ``TransferLedger``.

    Each worker's recorded ``pushed_bytes`` must decompose exactly into
    its plan's backward segments walked in order (whole iterations plus
    at most one partial), and the wire bytes implied by that
    decomposition under the independent byte model must equal the
    recorded ``pushed_wire_bytes`` to the integer — proving the
    compressed accounting exact for every committed push, including
    int8/top-k payloads.

    Elastic fleets re-plan workers mid-run, so a worker's bytes no
    longer decompose under ONE plan.  For those, ``plans_by_worker``
    maps the worker to its *push history* instead — a sequence of
    ``(plan, full_iterations, extra_segments)`` entries (the
    ``FleetTrainer.push_history`` format, ``extra_segments`` counting a
    trailing partial walk, e.g. a crash mid-push) — and the audit sums
    the exact decomposition those entries pin down.  A departed worker's
    ledger entry closes cleanly iff its history reproduces the recorded
    bytes; a joined worker simply has no entries before its join."""
    findings: List[Finding] = []
    ctx = {"context": context} if context else {}
    total_segments = 0
    for worker, logical_target in sorted(ledger.pushed_bytes.items()):
        plan = plans_by_worker[worker]
        if not hasattr(plan, "backward"):     # elastic: push history
            logical = wire = nseg = 0
            for entry_plan, full, extra in plan:
                seg_logical = [sum(specs[l].total * 4 for l in b)
                               for b in entry_plan.backward]
                seg_wire = [segment_wire_bytes(specs, b, compressor)
                            for b in entry_plan.backward]
                logical += full * sum(seg_logical) + sum(seg_logical[:extra])
                wire += full * sum(seg_wire) + sum(seg_wire[:extra])
                nseg += full * len(seg_logical) + extra
            if logical != logical_target:
                findings.append(Finding(
                    code="SCHED-LEDGER",
                    message=f"worker {worker}: recorded {logical_target} "
                            f"pushed bytes, but its push history "
                            f"decomposes to {logical}",
                    detail={"worker": worker, "recorded": logical_target,
                            "history_bytes": logical, **ctx}))
                continue
            recorded_wire = ledger.pushed_wire_bytes.get(worker, 0)
            if wire != recorded_wire:
                findings.append(Finding(
                    code="SCHED-LEDGER",
                    message=f"worker {worker}: ledger records "
                            f"{recorded_wire} pushed wire bytes, the "
                            f"independent byte model implies {wire} for "
                            f"its push history ({nseg} segments)",
                    detail={"worker": worker, "recorded": recorded_wire,
                            "expected": wire, "segments": nseg, **ctx}))
            total_segments += nseg
            continue
        seg_logical = [sum(specs[l].total * 4 for l in b)
                       for b in plan.backward]
        seg_wire = [segment_wire_bytes(specs, b, compressor)
                    for b in plan.backward]
        cap = 1 + len(seg_logical) * (
            1 + logical_target // max(1, sum(seg_logical)))
        logical = wire = nseg = 0
        while logical < logical_target and nseg < cap:
            logical += seg_logical[nseg % len(seg_logical)]
            wire += seg_wire[nseg % len(seg_wire)]
            nseg += 1
        if logical != logical_target:
            findings.append(Finding(
                code="SCHED-LEDGER",
                message=f"worker {worker}: recorded {logical_target} "
                        f"pushed bytes do not decompose into plan-order "
                        f"backward segments (nearest prefix {logical})",
                detail={"worker": worker, "recorded": logical_target,
                        "nearest_prefix": logical, **ctx}))
            continue
        recorded_wire = ledger.pushed_wire_bytes.get(worker, 0)
        if wire != recorded_wire:
            findings.append(Finding(
                code="SCHED-LEDGER",
                message=f"worker {worker}: ledger records "
                        f"{recorded_wire} pushed wire bytes, the "
                        f"independent byte model implies {wire} for the "
                        f"same {nseg} segments",
                detail={"worker": worker, "recorded": recorded_wire,
                        "expected": wire, "segments": nseg, **ctx}))
        total_segments += nseg
    if ledger.pushed_bytes and ledger.num_pushes != total_segments:
        findings.append(Finding(
            code="SCHED-LEDGER",
            message=f"ledger counts {ledger.num_pushes} push messages, "
                    f"the per-worker byte decomposition implies "
                    f"{total_segments} segments",
            detail={"num_pushes": ledger.num_pushes,
                    "segments": total_segments, **ctx}))
    return findings


def verify_fleet_membership(log: Any, joined_at: Dict[int, Tuple[float, int]],
                            departed: Dict[int, Tuple[float, str]], *,
                            staleness_bound: int,
                            context: str = "") -> List[Finding]:
    """Membership-coherence audit of an elastic-fleet run log.

    Against an ``AsyncRunLog`` and the roster history a
    ``FleetMembership`` records, checks that

    * every accepted push is within the staleness bound ``k`` — churn
      must not let a stale gradient slip past the SSP gate;
    * no worker commits outside its membership window: nothing before
      its join time, nothing after its departure (a departed worker's
      ledger closes cleanly);
    * a joined worker's pushes start at (or after) the server version it
      joined at — it can never have pulled older parameters than the
      join-time head.
    """
    findings: List[Finding] = []
    ctx = {"context": context} if context else {}
    for e in log.accepted:
        if e.result.staleness > staleness_bound:
            findings.append(Finding(
                code="FLEET-STALENESS",
                message=f"worker {e.worker} committed at staleness "
                        f"{e.result.staleness} > bound {staleness_bound} "
                        f"(t={e.sim_time})",
                detail={"worker": e.worker, "staleness": e.result.staleness,
                        "bound": staleness_bound, "time": e.sim_time,
                        **ctx}))
        if e.worker not in joined_at:
            findings.append(Finding(
                code="FLEET-MEMBER",
                message=f"worker {e.worker} committed at t={e.sim_time} "
                        f"but never joined the fleet",
                detail={"worker": e.worker, "time": e.sim_time, **ctx}))
            continue
        join_t, join_v = joined_at[e.worker]
        if e.sim_time < join_t:
            findings.append(Finding(
                code="FLEET-MEMBER",
                message=f"worker {e.worker} committed at t={e.sim_time}, "
                        f"before its join at t={join_t}",
                detail={"worker": e.worker, "time": e.sim_time,
                        "joined": join_t, **ctx}))
        if e.version < join_v:
            findings.append(Finding(
                code="FLEET-MEMBER",
                message=f"worker {e.worker} pushed against version "
                        f"{e.version}, older than the head at its join "
                        f"(version {join_v})",
                detail={"worker": e.worker, "version": e.version,
                        "join_version": join_v, **ctx}))
        if e.worker in departed and e.sim_time > departed[e.worker][0]:
            dep_t, reason = departed[e.worker]
            findings.append(Finding(
                code="FLEET-MEMBER",
                message=f"worker {e.worker} committed at t={e.sim_time}, "
                        f"after its departure ({reason}) at t={dep_t}",
                detail={"worker": e.worker, "time": e.sim_time,
                        "departed": dep_t, "reason": reason, **ctx}))
    return findings
