"""Training-step builders + a small host-side loop.

``build_train_step`` produces the jit-able (params, opt_state, batch) →
(params, opt_state, loss) function used both by the CPU examples and by the
production dry-run (where it is lowered with GSPMD shardings).  Supports
activation rematerialization and microbatched gradient accumulation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as model_lib
from repro.optim import Optimizer


def build_train_step(cfg: ArchConfig, optimizer: Optimizer, *,
                     accum_steps: int = 1, remat: bool = True,
                     aux_weight: float = 0.01) -> Callable:
    def loss_fn(params, batch):
        return model_lib.train_loss(cfg, params, batch,
                                    aux_weight=aux_weight, remat=remat)

    if accum_steps == 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state = optimizer.update(grads, opt_state, params)
            return params, opt_state, loss
        return train_step

    def train_step(params, opt_state, batch):
        def reshape(x):
            return x.reshape((accum_steps, x.shape[0] // accum_steps)
                             + x.shape[1:])
        micro = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            gacc, lacc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (gacc, lacc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss_sum / accum_steps

    return train_step


@dataclasses.dataclass
class TrainLoop:
    """Host loop: data pipeline → jitted step → metrics/checkpoints."""

    cfg: ArchConfig
    optimizer: Optimizer
    accum_steps: int = 1
    remat: bool = False
    log_every: int = 10
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 0

    def run(self, key, batches: Iterable[Dict[str, jnp.ndarray]],
            num_steps: int, params: Any = None):
        from repro.checkpoint import save_checkpoint
        if params is None:
            params = model_lib.init_params(self.cfg, key)
        opt_state = self.optimizer.init(params)
        step_fn = jax.jit(build_train_step(self.cfg, self.optimizer,
                                           accum_steps=self.accum_steps,
                                           remat=self.remat))
        losses = []
        t0 = time.perf_counter()
        for i, batch in enumerate(batches):
            if i >= num_steps:
                break
            params, opt_state, loss = step_fn(params, opt_state, batch)
            losses.append(float(loss))
            if self.log_every and (i + 1) % self.log_every == 0:
                dt = time.perf_counter() - t0
                print(f"step {i + 1:5d}  loss {losses[-1]:.4f}  "
                      f"({dt / (i + 1):.3f}s/step)")
            if (self.checkpoint_path and self.checkpoint_every
                    and (i + 1) % self.checkpoint_every == 0):
                save_checkpoint(self.checkpoint_path,
                                {"params": params, "opt": opt_state},
                                step=i + 1)
        return params, opt_state, losses
