"""Model zoo: assigned architectures + the paper's CNN workload tables."""

from repro.models.model import (cross_entropy, decode_step, forward,
                                init_caches, init_params, num_sched_layers,
                                param_count, params_from_sched_layers,
                                sched_layer_bytes, sched_layer_trees,
                                train_loss, tree_bytes)
from repro.models.profiles import (block_forward_flops, layer_profiles,
                                   model_flops_per_token)
from repro.models.cnn import (PAPER_CNNS, small_cnn_forward, small_cnn_init,
                              small_cnn_loss)

__all__ = [
    "init_params", "forward", "train_loss", "decode_step", "init_caches",
    "cross_entropy", "num_sched_layers", "sched_layer_trees",
    "params_from_sched_layers", "sched_layer_bytes", "tree_bytes",
    "param_count", "layer_profiles", "block_forward_flops",
    "model_flops_per_token", "PAPER_CNNS",
    "small_cnn_init", "small_cnn_forward", "small_cnn_loss",
]
