"""Drive a built runtime through the conformance passes.

``verify_runtime(config)`` is the engine behind
``python -m repro.analysis verify``: it builds the runtime via
:func:`repro.runtime.build_runtime`, lowers/compiles one training step
(or, for the dynamic regimes, runs just past a re-plan boundary so the
``PlanStepCache`` holds real compiled steps), and checks

* the compiled HLO against the active ``BucketPlan`` + ``FlatSpec``
  byte math (:func:`~repro.analysis.conformance.verify_schedule`);
* the compiled-step cache: one compilation per distinct plan
  (:func:`~repro.analysis.conformance.verify_cache`);
* the compressed wire-byte accounting, exact to the integer
  (:func:`~repro.analysis.conformance.verify_wire_model`, and for the
  event-loop regimes the per-worker ledger decomposition of
  :func:`~repro.analysis.conformance.verify_push_ledger`);
* that modules with no scheduled communication (the local step, the
  async trainers' single-jit gradient) compile zero cross-replica
  collectives.

This module imports jax (via ``repro.runtime``); the CLI imports it
lazily so ``lint`` stays jax-free.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.conformance import (segment_wire_bytes, verify_cache,
                                        verify_fleet_membership,
                                        verify_no_collectives,
                                        verify_push_ledger, verify_schedule,
                                        verify_wire_model)
from repro.analysis.findings import Finding

__all__ = ["verify_runtime"]


def verify_runtime(config: Any, *, steps: Optional[int] = None
                   ) -> Tuple[List[Finding], Dict[str, Any]]:
    """Verify one ``RuntimeConfig``; returns ``(findings, info)``.

    ``steps`` overrides how many units of progress to run where running
    is needed (dynamic regimes default to one step past the first
    re-plan boundary; async regimes to a couple of committed pushes).
    """
    from repro.runtime import build_runtime
    rt = build_runtime(config)
    regime = config.runtime
    if regime == "local":
        return _verify_local(rt)
    if regime in ("zero", "ps"):
        return _verify_static(rt, config, steps)
    if regime in ("dynamic", "dynamic-ps"):
        return _verify_dynamic(rt, config, steps)
    if regime in ("ps-async", "dynamic-ps-async"):
        return _verify_async(rt, config, regime, steps)
    if regime == "fleet-async":
        return _verify_fleet(rt, config, steps)
    if regime == "pipeline":
        return _verify_pipeline(rt, config, steps)
    raise ValueError(f"no conformance driver for runtime {regime!r}")


def _info(regime: str, **extra: Any) -> Dict[str, Any]:
    return {"runtime": regime, **extra}


def _plan_obj(plan: Any) -> Dict[str, Any]:
    return {"forward": [list(b) for b in plan.forward],
            "backward": [list(b) for b in plan.backward]}


def _verify_local(rt: Any) -> Tuple[List[Finding], Dict[str, Any]]:
    batch = rt._batch_fn(0)
    hlo = rt._step_fn.lower(rt._params, rt._opt_state,
                            batch).compile().as_text()
    findings = verify_no_collectives(hlo, context="local step")
    return findings, _info("local", checked=["no-collectives"])


def _verify_static(rt: Any, config: Any, steps: Optional[int]
                   ) -> Tuple[List[Finding], Dict[str, Any]]:
    tr = rt.trainer
    batch = rt._batch_fn(0)
    hlo = rt._step_fn.lower(rt._state, batch).compile().as_text()
    compressor = getattr(tr, "compressor", None)
    zero3 = config.execution.zero3
    findings = verify_schedule(hlo, rt.plan, tr.specs,
                               compressor=compressor, zero3=zero3,
                               context=f"{config.runtime} step")
    # ledger audit over a short run: the adapter's fleet-wide push wire
    # accounting must equal steps x workers x the independent per-segment
    # byte model
    n = steps if steps is not None else 1
    rt.fit(n)
    workers = tr.topology.num_workers if hasattr(tr, "topology") \
        else tr.axis_size
    expected_wire = n * workers * sum(
        segment_wire_bytes(tr.specs, b, compressor)
        for b in rt.plan.backward)
    recorded = rt.ledger["push_wire_bytes"]
    if recorded != expected_wire:
        findings.append(Finding(
            code="SCHED-LEDGER",
            message=f"runtime ledger records {recorded} push wire bytes "
                    f"over {n} step(s) x {workers} worker(s); the "
                    f"independent byte model gives {expected_wire}",
            detail={"recorded": recorded, "expected": expected_wire,
                    "steps": n, "workers": workers}))
    return findings, _info(
        config.runtime, plan=_plan_obj(rt.plan), steps_run=n,
        compression=getattr(compressor, "scheme", "none")
        if compressor else "none",
        checked=["schedule", "wire-model", "ledger"])


def _verify_dynamic(rt: Any, config: Any, steps: Optional[int]
                    ) -> Tuple[List[Finding], Dict[str, Any]]:
    # run one step past the first re-plan boundary so the cache holds at
    # least one (usually two) genuinely compiled plans
    n = steps if steps is not None else config.schedule.reschedule_every + 1
    rt.fit(n)
    tr = rt.trainer
    base = tr.base
    compressor = getattr(tr, "compressor", None)
    zero3 = config.execution.zero3
    findings = verify_cache(tr._cache, specs=base.specs, zero3=zero3,
                            context=f"{config.runtime} cache")
    for i, plan in enumerate(tr.plans_seen):
        # verify_schedule handles axis_size == 1 itself (XLA elides the
        # collectives; only stray + wire-model checks run)
        findings.extend(verify_schedule(
            tr._cache.hlo_text(plan), plan, base.specs,
            compressor=compressor, zero3=zero3,
            context=f"{config.runtime} plan {i}"))
    return findings, _info(
        config.runtime, steps_run=n, plans_seen=len(tr.plans_seen),
        traces=tr.traces, cache_hits=tr.cache_hits,
        compression=getattr(compressor, "scheme", "none")
        if compressor else "none",
        checked=["schedule", "cache", "wire-model"])


def _verify_async(rt: Any, config: Any, regime: str, steps: Optional[int]
                  ) -> Tuple[List[Finding], Dict[str, Any]]:
    async_tr = rt.trainer if regime == "ps-async" else rt.trainer.trainer
    # stay inside the first plan epoch so the per-worker ledger
    # decomposition runs against a single plan sequence per worker
    n = steps if steps is not None else 2
    if regime == "dynamic-ps-async":
        n = min(n, config.schedule.reschedule_every)
    rt.fit(n)

    # the async regimes communicate through explicit server messages;
    # their single-jit gradient must compile zero collectives
    batch = rt._batch_fn(0)
    hlo = async_tr._grad_fn.lower(async_tr.layer_params(),
                                  batch).compile().as_text()
    findings = verify_no_collectives(hlo, context=f"{regime} grad")

    specs = async_tr.specs
    compressor = async_tr.compressor
    plans = async_tr.plans
    if compressor is not None:
        for plan in dict.fromkeys(plans):
            findings.extend(verify_wire_model(specs, plan, compressor,
                                              context=f"{regime} plan"))
    findings.extend(verify_push_ledger(
        async_tr.server.ledger, dict(enumerate(plans)), specs, compressor,
        context=f"{regime} ledger"))
    return findings, _info(
        regime, pushes_run=n, workers=len(plans),
        compression=getattr(compressor, "scheme", "none")
        if compressor else "none",
        checked=["no-collectives", "wire-model", "push-ledger"])


def _verify_pipeline(rt: Any, config: Any, steps: Optional[int]
                     ) -> Tuple[List[Finding], Dict[str, Any]]:
    tr = rt.trainer
    n = steps if steps is not None else 1
    rt.fit(n)

    # each per-stage program must be collective-free: inter-stage bytes
    # move only through the explicit boundary buffers the ledger accounts
    findings: List[Finding] = []
    batch = rt._batch_fn(0)
    for s, (fwd_hlo, bwd_hlo) in enumerate(tr.stage_hlo(batch)):
        findings.extend(verify_no_collectives(
            fwd_hlo, context=f"pipeline stage {s} forward"))
        findings.extend(verify_no_collectives(
            bwd_hlo, context=f"pipeline stage {s} backward"))

    # ledger audit: boundary bytes must equal the independent byte model
    # (per step: M activation flats down + M grad flats up per boundary,
    # plus the tied-embedding flat to/from the head stage)
    S, M = tr.num_stages, tr.num_microbatches
    act = tr.activation_bytes()
    embed_bytes = tr.specs[0].total * 4 if S > 1 else 0
    expected_pull = n * (M * sum(act) + embed_bytes)
    expected_push = n * (M * sum(act) + M * embed_bytes)
    led = rt.ledger
    for direction, expected in (("pull", expected_pull),
                                ("push", expected_push)):
        recorded = led[f"{direction}_bytes"]
        if recorded != expected:
            findings.append(Finding(
                code="PIPE-LEDGER",
                message=f"pipeline ledger records {recorded} {direction} "
                        f"bytes over {n} step(s); the boundary byte model "
                        f"gives {expected}",
                detail={"recorded": recorded, "expected": expected,
                        "steps": n, "stages": S, "microbatches": M}))

    # partition sanity + transfer-plan optimality vs the whole-tensor
    # baseline (the DP can never lose to a feasible decision)
    part = tr.partition
    if abs(max(part.loads) - part.bottleneck) > 1e-9 * max(part.bottleneck,
                                                           1.0):
        findings.append(Finding(
            code="PIPE-PARTITION",
            message=f"partition bottleneck {part.bottleneck} is not the "
                    f"max stage load {max(part.loads)}",
            detail=part.as_dict()))
    plans = tr.transfer_plans() or []
    for p in plans:
        if p.fwd_time > p.whole_fwd_time + 1e-12 or \
                p.bwd_time > p.whole_bwd_time + 1e-12:
            findings.append(Finding(
                code="PIPE-TRANSFER",
                message=f"boundary {p.boundary}: segmented transfer "
                        f"({p.fwd_time + p.bwd_time:.6f}s) loses to the "
                        f"whole-tensor baseline "
                        f"({p.whole_fwd_time + p.whole_bwd_time:.6f}s)",
                detail={"boundary": p.boundary,
                        "segmented": p.fwd_time + p.bwd_time,
                        "whole": p.whole_fwd_time + p.whole_bwd_time}))
    timeline = tr.timeline()
    return findings, _info(
        "pipeline", steps_run=n, stages=S, microbatches=M,
        schedule=tr.schedule_name, partition=part.as_dict(),
        boundary_speedups=[p.speedup for p in plans],
        bubble_fraction=(timeline.bubble_fraction
                         if timeline is not None else None),
        checked=["no-collectives", "ledger", "partition", "transfer-plans"])


def _verify_fleet(rt: Any, config: Any, steps: Optional[int]
                  ) -> Tuple[List[Finding], Dict[str, Any]]:
    tr = rt.trainer
    # run far enough to fire the scripted membership events (the ledger
    # and membership audits are only interesting once churn happened)
    n = steps if steps is not None else 4
    rt.fit(n)

    batch = rt._batch_fn(0)
    hlo = tr._grad_fn.lower(tr.layer_params(),
                            batch).compile().as_text()
    findings = verify_no_collectives(hlo, context="fleet-async grad")

    specs = tr.specs
    compressor = tr.compressor
    history = tr.push_history
    if compressor is not None:
        distinct = dict.fromkeys(p for entries in history.values()
                                 for p, _, _ in entries)
        for plan in distinct:
            findings.extend(verify_wire_model(specs, plan, compressor,
                                              context="fleet-async plan"))
    # the elastic form: each worker's ledger entry decomposes under its
    # own plan *history* (departed workers' entries close cleanly)
    findings.extend(verify_push_ledger(
        tr.server.ledger, history, specs, compressor,
        context="fleet-async ledger"))
    findings.extend(verify_fleet_membership(
        tr.log, tr.membership.joined_at, tr.membership.departed,
        staleness_bound=tr.staleness, context="fleet-async membership"))
    return findings, _info(
        "fleet-async", pushes_run=n, workers=tr.membership.num_active,
        replans=len(tr.replan_events),
        membership_events=len(tr.membership_events),
        compression=getattr(compressor, "scheme", "none")
        if compressor else "none",
        checked=["no-collectives", "wire-model", "push-ledger",
                 "fleet-membership"])
