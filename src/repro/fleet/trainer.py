"""``FleetTrainer``: elastic bounded-staleness training over a churning
worker fleet.

This is ``repro.ps.async_mode.AsyncPSTrainer`` grown to fleet scale on
the deterministic :class:`~repro.fleet.engine.EventQueue`.  Three event
kinds share one engine:

* ``("commit",)`` — a worker's pull → compute → push iteration
  completes; the payload-free event is matched against the worker's
  ``in_flight`` record by ``seq``, so events of departed or evicted
  workers invalidate lazily (they pop and are ignored);
* ``("fleet", i)`` — the ``i``-th :class:`FleetEvent` of the schedule
  fires: joins enter the roster parked, leaves and crashes depart (a
  crash loses its connection mid-push — half its backward segments have
  already hit the server and stay in the ledger before the pending set
  is dropped), stalls and drifts are *silent* (nothing re-plans until
  measurement notices);
* ``("check",)`` — the periodic failure-detector probe: any in-flight
  iteration past ``stall_factor ×`` its believed duration is evicted,
  exactly how a real PS times out a silent worker.

**Re-planning.**  Every observable membership change (join, leave,
crash, stall eviction, detected drift) re-plans through the existing
``TopologyScheduler`` machinery in per-worker mode: the live roster is
projected onto a fresh ``PSTopology`` (compute rates scaled by the
*believed* drift factors the detector has learned), the DP re-derives
one plan per worker, and when ``workers_per_shard`` moves the shard
count the server :meth:`~repro.ps.server.PSServer.reshard`\\ s —
versioned state (parameters, snapshots, optimizer moments, version
counter) is carried bit-identically while the migration bytes land in
the ``TransferLedger``.

**Staleness.**  Both throttles of the async core carry over: ``reject``
(server-side eviction of stale pushes) and ``wait`` (SSP admission gate
+ min-pin commit barrier), and the SSP bound holds under churn — the
admission gate counts *every* uncommitted computation, a departed
worker's in-flight work is cancelled (never committed), and the commit
barrier still requires the minimum pin.  A stalled worker keeps holding
its admission slot and its pinned version until the failure detector
evicts it, which is precisely why silent stalls hurt and detection
matters.

**Determinism.**  The loop is a pure function of (model init, schedule,
specs, batch function): no wall clock, no RNG.  The *entire* loop state
— engine entries, in-flight gradients, barrier, roster, detector and
scheduler state, error-feedback residuals, the run log — round-trips
through ``save_loop_state``/``restore_loop_state``, so a resumed run
replays bit-identically to an uninterrupted one.
"""

from __future__ import annotations

import dataclasses
import json
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buckets import BucketPlan, decision_from_plan, \
    plan_from_decision
from repro.core.costmodel import iteration_time
from repro.core.planner import AsyncPlanner, Planner
from repro.core.scheduler import TopologyScheduler
from repro.dist.collectives import FlatSpec, flatten_tree, make_flat_spec, \
    unflatten_tree
from repro.fleet.drift import FleetDriftDetector
from repro.fleet.engine import EventQueue
from repro.fleet.membership import (FleetEvent, FleetMembership,
                                    FleetSchedule, WorkerSpec)
from repro.optim import Optimizer
from repro.ps.async_mode import THROTTLES, AsyncPushEvent, AsyncRunLog
from repro.ps.server import PSServer, PushResult, StaleVersion


@dataclasses.dataclass(frozen=True)
class FleetReplanEvent:
    """One pass through the ``TopologyScheduler`` after a trigger."""

    sim_time: float
    at_push: int                 # accepted pushes when the re-plan ran
    reason: str                  # init|join|leave|crash|stall|drift
    worker: Optional[int]        # the triggering worker (None for init)
    num_workers: int             # fleet size after the trigger
    num_servers: int
    plan_changed: bool
    resharded: bool
    migrated_bytes: int
    scheduling_seconds: float
    overhead_hidden: bool


@dataclasses.dataclass(frozen=True)
class MembershipChange:
    """One applied roster change or silent-failure (non-)observation."""

    sim_time: float
    kind: str          # join|leave|crash|stall|drift|stall-evict|drift-detect
    worker: int
    fleet_size: int    # active workers after the change


@dataclasses.dataclass
class _InFlight:
    """One admitted iteration: its commit event and everything the push
    will need (the engine event itself carries no payload)."""

    seq: int
    started: float
    pin: int
    loss: float
    grads: List[Any]
    plan: BucketPlan


@dataclasses.dataclass
class _FleetLoop:
    """Resumable event-loop state (see ``save_loop_state``)."""

    log: AsyncRunLog
    parked: List[int]
    engine: EventQueue = dataclasses.field(default_factory=EventQueue)
    in_flight: Dict[int, _InFlight] = dataclasses.field(default_factory=dict)
    # (pin, completion time, worker, loss, grads, plan)
    barrier: List[Tuple] = dataclasses.field(default_factory=list)
    now: float = 0.0
    accepted: int = 0
    attempts: Dict[int, int] = dataclasses.field(default_factory=dict)
    retries: Dict[int, int] = dataclasses.field(default_factory=dict)


def _plan_to_lists(plan: BucketPlan) -> list:
    return [[list(b) for b in plan.forward],
            [list(b) for b in plan.backward]]


def _plan_from_lists(data: Sequence) -> BucketPlan:
    return BucketPlan(forward=tuple(tuple(b) for b in data[0]),
                      backward=tuple(tuple(b) for b in data[1]))


class FleetTrainer:
    """Event-driven bounded-staleness trainer over an elastic fleet.

    Parameters
    ----------
    init_layers / loss_fn / optimizer:
        as for ``AsyncPSTrainer`` — per-layer parameter pytrees and a
        ``loss_fn(layers, batch) -> scalar`` differentiated once.
    workers:
        the initial fleet: ``{worker id: WorkerSpec}`` (or an int for
        ``n`` default-spec workers with ids ``0..n-1``).  Ids are
        *global* and never reused; topology position always follows
        ascending active id.
    schedule:
        the :class:`FleetSchedule` of join/leave/fail/drift events.
    num_servers:
        shard count when ``workers_per_shard == 0`` (fixed sharding).
    workers_per_shard:
        when positive, the shard count tracks the fleet:
        ``S = ceil(active / workers_per_shard)`` — membership changes
        that move it re-shard the server in place.
    staleness / throttle / compressor:
        the async core's bound ``k``, ``"reject"`` or ``"wait"``, and
        optional push compression with per-(worker, layer) EF residuals.
    strategy:
        DP strategy for the per-worker ``TopologyScheduler``.
    profiles:
        per-layer :class:`LayerProfile`\\ s for the cost model (default:
        synthesized from the parameter shapes).
    drift_detector:
        a :class:`FleetDriftDetector`; every commit feeds it the
        worker's observed gap, a trigger scales that worker's believed
        compute rate to the measurement and re-plans.
    stall_factor / check_interval:
        failure detection: every ``check_interval`` simulated seconds
        (default: the slowest believed iteration) any in-flight
        iteration older than ``stall_factor × max(believed duration,
        observed EWMA gap)`` is evicted.  Note the timeout trade-off of
        real failure detectors: a worker that silently slows beyond
        ``stall_factor×`` before detection catches up is evicted as
        stalled rather than re-planned.
    """

    def __init__(self, *, init_layers: Sequence[Any],
                 loss_fn: Callable[[List[Any], Dict[str, Any]], Any],
                 optimizer: Optimizer,
                 workers: Union[int, Mapping[int, WorkerSpec]],
                 schedule: Optional[FleetSchedule] = None,
                 num_servers: int = 1, workers_per_shard: int = 0,
                 staleness: int = 1, throttle: str = "wait",
                 strategy: str = "dynacomm",
                 profiles: Optional[Sequence[Any]] = None,
                 compressor=None,
                 drift_detector: Optional[FleetDriftDetector] = None,
                 stall_factor: float = 4.0, check_interval: float = 0.0,
                 async_planning: bool = False, plan_cache_size: int = 256):
        init_layers = list(init_layers)
        if not init_layers:
            raise ValueError("need at least one layer tree")
        if throttle not in THROTTLES:
            raise ValueError(f"throttle must be one of {THROTTLES}, got "
                             f"{throttle!r}")
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        if workers_per_shard < 0:
            raise ValueError(f"workers_per_shard must be >= 0, got "
                             f"{workers_per_shard}")
        if stall_factor <= 1:
            raise ValueError(f"stall_factor must be > 1, got {stall_factor}")
        if isinstance(workers, int):
            workers = {w: WorkerSpec() for w in range(workers)}
        self._init_specs: Dict[int, WorkerSpec] = dict(sorted(workers.items()))
        self.schedule = schedule or FleetSchedule()
        self.schedule.validate_against(tuple(self._init_specs))
        self.staleness = staleness
        self.throttle = throttle
        self.workers_per_shard = workers_per_shard
        self._fixed_servers = num_servers
        self.stall_factor = stall_factor
        self._check_interval = check_interval
        self.specs: Tuple[FlatSpec, ...] = tuple(
            make_flat_spec(t, 1) for t in init_layers)
        if profiles is None:
            from repro.ps.dynamic import profiles_from_specs
            profiles = profiles_from_specs(self.specs)
        self._profiles = tuple(profiles)
        if compressor is not None and compressor.scheme == "none":
            compressor = None
        self.compressor = compressor
        if compressor is None:
            self._compress_fn = None
        elif compressor.error_feedback:
            self._compress_fn = jax.jit(compressor.feedback_roundtrip)
        else:
            self._compress_fn = jax.jit(compressor.roundtrip)
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        self.detector = drift_detector or FleetDriftDetector()
        # The memo cache is what makes fleet-scale re-planning viable: a
        # homogeneous roster's W identical per-worker DPs collapse to one
        # solve + W−1 content-key hits, and re-plans after churn re-use
        # every unchanged worker's decision.  async_planning additionally
        # pre-solves the next scripted membership change's roster in the
        # background (see _speculate_next_replan).
        self.async_planning = async_planning
        planner_cls = AsyncPlanner if async_planning else Planner
        self.planner = planner_cls(cache_size=plan_cache_size)
        self.scheduler = TopologyScheduler(strategy=strategy,
                                           reschedule_every=1,
                                           mode="per-worker",
                                           planner=self.planner)
        self._next_fleet_event = 0       # index into schedule.events
        self.membership = FleetMembership(self._init_specs)
        topo0 = self.membership.topology(
            self._servers_for(self.membership.num_active))
        flats = [flatten_tree(t, s) for t, s in zip(init_layers, self.specs)]
        self.server = PSServer(self.specs, topo0, optimizer, flats,
                               staleness_bound=staleness,
                               compressor=compressor)
        self.topology = topo0
        self._residuals: Dict[Tuple[int, int], jnp.ndarray] = {}
        self._stalled: set = set()
        self._true_factor: Dict[int, float] = {}
        self._believed: Dict[int, float] = {}
        self._plans: Dict[int, BucketPlan] = {}
        self._durations: Dict[int, float] = {}       # believed (planner)
        self._base_durations: Dict[int, float] = {}  # spec rates, no factors
        self._true_durations: Dict[int, float] = {}  # simulation physics
        self._num_servers = topo0.num_servers
        self._push_history: Dict[int, List[list]] = {}
        self.replan_events: List[FleetReplanEvent] = []
        self.membership_events: List[MembershipChange] = []
        self._loop: Optional[_FleetLoop] = None

    # ------------------------------------------------------------------
    # roster → topology → plans
    # ------------------------------------------------------------------

    def _servers_for(self, num_active: int) -> int:
        if self.workers_per_shard > 0:
            return max(1, -(-num_active // self.workers_per_shard))
        return self._fixed_servers

    def _worker_costs(self, factors: Mapping[int, float]):
        topo = self.membership.topology(self._num_servers,
                                        flops_scale=factors)
        return topo, topo.topology_costs(self._profiles,
                                         compressor=self.compressor)

    def _replan(self, loop: _FleetLoop, now: float, *, reason: str,
                worker: Optional[int]) -> None:
        """Project the live roster onto a topology, re-run the DP, and
        re-shard the server if the shard count moved."""
        W = self.membership.num_active
        if W == 0:
            self._plans, self._durations = {}, {}
            self._base_durations, self._true_durations = {}, {}
            return
        S = self._servers_for(W)
        self._num_servers = S
        resharded, migrated = False, 0
        topo, costs = self._worker_costs(self._believed)
        if S != self.server.topology.num_servers:
            migrated = self.server.reshard(topo)["migrated_bytes"]
            resharded = True
        else:
            self.server.topology = topo
        self.topology = topo
        self.scheduler.invalidate()
        decisions = self.scheduler.decision_for_iteration(costs)
        L = len(self.specs)
        active = self.membership.active
        new_plans = {w: plan_from_decision(*d, L)
                     for w, d in zip(active, decisions)}
        plan_changed = any(new_plans[w] != self._plans.get(w)
                           for w in new_plans)
        self._plans = new_plans
        self._durations = {
            w: iteration_time(costs.workers[i],
                              *decision_from_plan(new_plans[w]))
            for i, w in enumerate(active)}
        _, base_costs = self._worker_costs({})
        self._base_durations = {
            w: iteration_time(base_costs.workers[i],
                              *decision_from_plan(new_plans[w]))
            for i, w in enumerate(active)}
        self._recompute_true_durations()
        self.replan_events.append(FleetReplanEvent(
            sim_time=now, at_push=loop.accepted, reason=reason,
            worker=worker, num_workers=W, num_servers=S,
            plan_changed=plan_changed, resharded=resharded,
            migrated_bytes=migrated,
            scheduling_seconds=self.scheduler.last_scheduling_seconds,
            overhead_hidden=self.scheduler.scheduling_overhead_hidden(
                costs)))
        if self.async_planning:
            self._speculate_next_replan()

    def _speculate_next_replan(self) -> None:
        """Phase one of the async protocol: project the roster the *next*
        scripted membership change will leave behind and pre-solve its
        per-worker DPs in the background, so the re-plan at that event is
        a collect instead of an inline O(W·L³) sweep.  Unscripted
        re-plans (stall evictions, drift detections) and mispredictions
        simply fall back to the planner's inline solve — speculation
        never changes a decision, only where it was computed."""
        specs = {w: self.membership.spec(w) for w in self.membership.active}
        for fev in self.schedule.events[self._next_fleet_event:]:
            if fev.kind == "join":
                specs[fev.worker] = fev.spec or WorkerSpec()
            elif fev.kind == "leave" or \
                    (fev.kind == "fail" and fev.mode == "crash"):
                specs.pop(fev.worker, None)
            else:
                continue         # stalls/drifts don't re-plan on arrival
            break
        else:
            return               # no further scripted membership change
        if not specs:
            return
        topo = FleetMembership(specs).topology(
            self._servers_for(len(specs)), flops_scale=self._believed)
        self.planner.submit_topology(
            topo.topology_costs(self._profiles, compressor=self.compressor),
            self.scheduler.strategy)

    def _recompute_true_durations(self) -> None:
        """What an iteration *actually* takes per worker — the believed
        plan timed under the true (possibly drifted) compute rates."""
        _, costs = self._worker_costs(self._true_factor)
        self._true_durations = {
            w: iteration_time(costs.workers[i],
                              *decision_from_plan(self._plans[w]))
            for i, w in enumerate(self.membership.active)}

    @property
    def plans(self) -> Dict[int, BucketPlan]:
        """{active worker: its current plan}."""
        return dict(self._plans)

    @property
    def push_history(self) -> Dict[int, Tuple[Tuple[BucketPlan, int, int],
                                              ...]]:
        """Per worker (ever admitted), the plan-segmented push record:
        ``(plan, completed pushes, trailing partial segments)`` runs in
        order — what ``verify_push_ledger`` decomposes an elastic
        worker's ledger against."""
        return {w: tuple((p, full, extra) for p, full, extra in hist)
                for w, hist in self._push_history.items()}

    # ------------------------------------------------------------------
    # one worker attempt (segmented pull → grads → segmented push)
    # ------------------------------------------------------------------

    def _pull_layers(self, worker: int,
                     plan: BucketPlan) -> Tuple[int, List[Any]]:
        while True:
            version: Optional[int] = None
            buffers: Dict[int, Any] = {}
            try:
                for bucket in plan.forward:
                    v, flats = self.server.pull_bucket(
                        bucket, version=version, worker=worker)
                    version = v
                    buffers.update(flats)
            except StaleVersion:
                continue
            layers = [unflatten_tree(buffers[l], self.specs[l])
                      for l in range(len(self.specs))]
            return version, layers

    def _compress_flat(self, worker: int, layer: int,
                       flat: jnp.ndarray) -> jnp.ndarray:
        if self.compressor is None:
            return flat
        if not self.compressor.error_feedback:
            return self._compress_fn(flat)
        key = (worker, layer)
        residual = self._residuals.get(key)
        if residual is None:
            residual = jnp.zeros_like(flat)
        compressed, self._residuals[key] = self._compress_fn(flat, residual)
        return compressed

    def _note_push(self, worker: int, plan: BucketPlan,
                   partial_segments: int = 0) -> None:
        hist = self._push_history.setdefault(worker, [])
        if not hist or hist[-1][0] != plan or hist[-1][2]:
            hist.append([plan, 0, 0])
        if partial_segments:
            hist[-1][2] += partial_segments
        else:
            hist[-1][1] += 1

    def _push(self, worker: int, version: int, grads: List[Any],
              plan: BucketPlan) -> PushResult:
        result: Optional[PushResult] = None
        for bucket in plan.backward:
            flat_grads = {l: self._compress_flat(
                              worker, l,
                              flatten_tree(grads[l], self.specs[l]))
                          for l in bucket}
            result = self.server.push_bucket(worker, version, bucket,
                                             flat_grads)
        assert result is not None, "plan.backward committed no push"
        self._note_push(worker, plan)
        return result

    # ------------------------------------------------------------------
    # the event loop
    # ------------------------------------------------------------------

    def run(self, num_pushes: int, batch_fn: Callable[[int, int], Any], *,
            reset: bool = True) -> AsyncRunLog:
        """Run until ``num_pushes`` more pushes were *accepted*.

        ``batch_fn(worker, attempt_idx) -> batch`` supplies data per
        global worker id.  ``reset=False`` resumes the previous loop
        (clock, in-flight work, roster, pending fleet events)."""
        if num_pushes < 1:
            raise ValueError(f"num_pushes must be >= 1, got {num_pushes}")
        if reset or self._loop is None:
            self._init_loop()
        loop = self._loop
        target = loop.accepted + num_pushes
        self._drain(loop, loop.now, target, batch_fn)
        self._admit(loop, loop.now, batch_fn)
        while loop.accepted < target:
            if not loop.engine:
                raise RuntimeError(
                    f"fleet drained at t={loop.now}: no events left with "
                    f"{target - loop.accepted} pushes to go")
            if self.membership.num_active == 0 and not loop.in_flight \
                    and not loop.barrier:
                raise RuntimeError(
                    f"fleet empty at t={loop.now}: every worker departed "
                    f"with {target - loop.accepted} pushes to go")
            ev = loop.engine.pop()
            loop.now = ev.time
            kind = ev.payload[0]
            if kind == "commit":
                self._on_commit(loop, ev, target, batch_fn)
            elif kind == "fleet":
                # bookmark for the speculative pre-solve: the next
                # scripted event after this one is what a re-plan here
                # should pre-compute for
                self._next_fleet_event = ev.payload[1] + 1
                self._apply_fleet_event(
                    loop, self.schedule.events[ev.payload[1]], ev.time,
                    target, batch_fn)
            else:
                self._on_check(loop, ev.time, target, batch_fn)
        return loop.log

    def _init_loop(self) -> None:
        self.membership = FleetMembership(self._init_specs)
        self.detector = FleetDriftDetector(
            alpha=self.detector.alpha, threshold=self.detector.threshold,
            patience=self.detector.patience, warmup=self.detector.warmup)
        self._residuals = {}
        self._stalled = set()
        self._true_factor, self._believed = {}, {}
        self._push_history = {}
        self.replan_events, self.membership_events = [], []
        self._next_fleet_event = 0
        loop = _FleetLoop(log=AsyncRunLog(),
                          parked=list(self.membership.active))
        loop.attempts = {w: 0 for w in loop.parked}
        loop.retries = {w: 0 for w in loop.parked}
        self._loop = loop
        self._replan(loop, 0.0, reason="init", worker=None)
        for i, e in enumerate(self.schedule.events):
            loop.engine.push(e.time, e.worker, ("fleet", i))
        loop.engine.push(self._check_every(), -1, ("check",))

    def _check_every(self) -> float:
        if self._check_interval > 0:
            return self._check_interval
        return max(self._durations.values(), default=1.0) or 1.0

    def _admit(self, loop: _FleetLoop, now: float, batch_fn) -> None:
        if self.throttle == "reject":
            while loop.parked:
                self._start(loop, loop.parked.pop(0), now, batch_fn)
            return
        k = self.staleness
        while loop.parked and \
                len(loop.in_flight) + len(loop.barrier) <= k:
            self._start(loop, loop.parked.pop(0), now, batch_fn)

    def _start(self, loop: _FleetLoop, worker: int, now: float,
               batch_fn) -> None:
        plan = self._plans[worker]
        version, layers = self._pull_layers(worker, plan)
        loss, grads = self._grad_fn(layers, batch_fn(
            worker, loop.attempts[worker]))
        loop.attempts[worker] += 1
        ev = loop.engine.push(now + self._true_durations[worker], worker,
                              ("commit",))
        loop.in_flight[worker] = _InFlight(
            seq=ev.seq, started=now, pin=version, loss=float(loss),
            grads=grads, plan=plan)

    def _min_pin(self, loop: _FleetLoop) -> int:
        return min([e.pin for e in loop.in_flight.values()] +
                   [b[0] for b in loop.barrier])

    def _on_commit(self, loop: _FleetLoop, ev, target: int,
                   batch_fn) -> None:
        w = ev.worker
        entry = loop.in_flight.get(w)
        if entry is None or entry.seq != ev.seq:
            return                       # cancelled: departed or evicted
        if w in self._stalled:
            return                       # silent stall: commit never lands
        del loop.in_flight[w]
        if self.detector.observe(w, ev.time - entry.started):
            self._on_drift_detected(loop, w, ev.time)
        if self.throttle == "wait":
            loop.barrier.append((entry.pin, ev.time, w, entry.loss,
                                 entry.grads, entry.plan))
            self._drain(loop, ev.time, target, batch_fn)
            return
        result = self._push(w, entry.pin, entry.grads, entry.plan)
        loop.log.events.append(AsyncPushEvent(
            worker=w, sim_time=ev.time, version=entry.pin, result=result,
            loss=entry.loss, retries=loop.retries[w]))
        loop.accepted += int(result.accepted)
        loop.retries[w] = 0 if result.accepted else loop.retries[w] + 1
        if self.membership.is_active(w):
            self._start(loop, w, ev.time, batch_fn)

    def _drain(self, loop: _FleetLoop, now: float, target: int,
               batch_fn) -> None:
        """Wait throttle: commit every barrier entry whose pin is the
        in-flight minimum, in (pin, completion, worker) order."""
        if self.throttle != "wait":
            return
        k = self.staleness
        while loop.barrier and loop.accepted < target:
            loop.barrier.sort(key=lambda e: (e[0], e[1], e[2]))
            pin, done_t, w, loss, grads, plan = loop.barrier[0]
            if pin > self._min_pin(loop):
                return                   # blocked on a laggard
            loop.barrier.pop(0)
            assert self.server.head_distance(pin) <= k, \
                "SSP gates must keep every commit within the bound"
            result = self._push(w, pin, grads, plan)
            assert result.accepted, \
                "a wait-throttled push can never be stale at commit"
            wait_s = now - done_t
            if wait_s > 0:
                self.server.ledger.waited_pushes += 1
            loop.log.events.append(AsyncPushEvent(
                worker=w, sim_time=now, version=pin, result=result,
                loss=loss, retries=0, wait_s=wait_s))
            loop.accepted += 1
            if self.membership.is_active(w):
                loop.parked.append(w)
            self._admit(loop, now, batch_fn)

    # ------------------------------------------------------------------
    # fleet events, failure detection, drift
    # ------------------------------------------------------------------

    def _record_membership(self, now: float, kind: str,
                           worker: int) -> None:
        self.membership_events.append(MembershipChange(
            sim_time=now, kind=kind, worker=worker,
            fleet_size=self.membership.num_active))

    def _apply_fleet_event(self, loop: _FleetLoop, fev: FleetEvent,
                           now: float, target: int, batch_fn) -> None:
        w = fev.worker
        if fev.kind == "join":
            self.membership.join(w, fev.spec or WorkerSpec(), time=now,
                                 version=self.server.version)
            loop.attempts.setdefault(w, 0)
            loop.retries.setdefault(w, 0)
            loop.parked.append(w)
            self._record_membership(now, "join", w)
            self._replan(loop, now, reason="join", worker=w)
        elif fev.kind == "leave":
            self._remove_worker(loop, w, now, reason="leave", crash=False)
            self._record_membership(now, "leave", w)
            self._replan(loop, now, reason="leave", worker=w)
        elif fev.kind == "fail" and fev.mode == "crash":
            self._remove_worker(loop, w, now, reason="crash", crash=True)
            self._record_membership(now, "crash", w)
            self._replan(loop, now, reason="crash", worker=w)
        elif fev.kind == "fail":         # silent stall: no replan yet
            self._stalled.add(w)
            self._record_membership(now, "stall", w)
        else:                            # silent drift: physics change only
            self._true_factor[w] = fev.factor
            self._recompute_true_durations()
            self._record_membership(now, "drift", w)
        self._drain(loop, now, target, batch_fn)
        self._admit(loop, now, batch_fn)

    def _remove_worker(self, loop: _FleetLoop, w: int, now: float, *,
                       reason: str, crash: bool) -> None:
        entry = loop.in_flight.pop(w, None)
        if entry is not None and crash:
            # the connection dies mid-push: the first half of the backward
            # segments already reached the server (and its ledger); the
            # incomplete pending set is dropped, never committed
            partial = len(entry.plan.backward) // 2
            for bucket in entry.plan.backward[:partial]:
                flat = {l: self._compress_flat(
                            w, l, flatten_tree(entry.grads[l],
                                               self.specs[l]))
                        for l in bucket}
                self.server.push_bucket(w, entry.pin, bucket, flat)
            if partial:
                self._note_push(w, entry.plan, partial_segments=partial)
            self.server.drop_pending(w)
        loop.barrier = [b for b in loop.barrier if b[2] != w]
        if w in loop.parked:
            loop.parked.remove(w)
        self._stalled.discard(w)
        self.membership.depart(w, time=now, reason=reason)
        self.detector.forget(w)
        for key in [k for k in self._residuals if k[0] == w]:
            del self._residuals[key]

    def _on_check(self, loop: _FleetLoop, now: float, target: int,
                  batch_fn) -> None:
        evicted = []
        for w in sorted(loop.in_flight):
            entry = loop.in_flight[w]
            believed = max(self._durations.get(w, 0.0),
                           self.detector.observed_gap(w) or 0.0)
            if now > entry.started + self.stall_factor * believed + 1e-9:
                evicted.append(w)
        for w in evicted:
            self._remove_worker(loop, w, now, reason="stall", crash=False)
            self._record_membership(now, "stall-evict", w)
            self._replan(loop, now, reason="stall", worker=w)
        loop.engine.push(now + self._check_every(), -1, ("check",))
        if evicted:
            self._drain(loop, now, target, batch_fn)
            self._admit(loop, now, batch_fn)

    def _on_drift_detected(self, loop: _FleetLoop, w: int,
                           now: float) -> None:
        base = self._base_durations.get(w)
        observed = self.detector.observed_gap(w)
        if base and observed:
            self._believed[w] = max(observed / base, 1e-6)
        self._record_membership(now, "drift-detect", w)
        self._replan(loop, now, reason="drift", worker=w)

    # ------------------------------------------------------------------
    # interop
    # ------------------------------------------------------------------

    @property
    def log(self) -> Optional[AsyncRunLog]:
        return self._loop.log if self._loop is not None else None

    @property
    def planner_stats(self) -> Dict[str, float]:
        """Memo-cache / async-planning counters (``PlannerStats``)."""
        return self.planner.stats.as_dict()

    def layer_params(self) -> List[Any]:
        """Head-version parameters, unflattened to the layer pytrees."""
        return [unflatten_tree(f, s)
                for f, s in zip(self.server.flats(), self.specs)]

    def reset_loop(self) -> None:
        """Discard the loop (clock, in-flight work, roster evolution);
        the next ``run`` restarts from the initial fleet at t=0."""
        self._loop = None
        self._residuals = {}

    # ------------------------------------------------------------------
    # loop checkpointing (bit-identical resume)
    # ------------------------------------------------------------------

    def save_loop_state(self, path: str) -> None:
        """Serialize the *entire* loop — engine, in-flight gradients,
        barrier, roster, detector/scheduler state, EF residuals, ledger,
        and the run log — so a restore resumes bit-identically."""
        if self._loop is None:
            raise ValueError("no active loop to save; run() first")
        loop = self._loop
        led = self.server.ledger
        meta = {
            "now": loop.now, "accepted": loop.accepted,
            "parked": list(loop.parked),
            "attempts": {str(w): n for w, n in loop.attempts.items()},
            "retries": {str(w): n for w, n in loop.retries.items()},
            "stalled": sorted(self._stalled),
            "true_factor": {str(w): f
                            for w, f in self._true_factor.items()},
            "believed": {str(w): f for w, f in self._believed.items()},
            "membership": self.membership.state_dict(),
            "detector": self.detector.state_dict(),
            "scheduler": self.scheduler.state_dict(),
            "planner": self.planner.state_dict(),
            "next_fleet_event": self._next_fleet_event,
            "num_servers": self._num_servers,
            "plans": {str(w): _plan_to_lists(p)
                      for w, p in self._plans.items()},
            "durations": {str(w): d for w, d in self._durations.items()},
            "base_durations": {str(w): d
                               for w, d in self._base_durations.items()},
            "true_durations": {str(w): d
                               for w, d in self._true_durations.items()},
            "push_history": {str(w): [[_plan_to_lists(p), full, extra]
                                      for p, full, extra in hist]
                             for w, hist in self._push_history.items()},
            "engine": loop.engine.state(),
            "in_flight": [[w, e.seq, e.started, e.pin, e.loss,
                           _plan_to_lists(e.plan)]
                          for w, e in sorted(loop.in_flight.items())],
            "barrier": [[pin, done_t, w, loss, _plan_to_lists(plan)]
                        for pin, done_t, w, loss, _g, plan
                        in loop.barrier],
            "log": [[e.worker, e.sim_time, e.version, e.loss, e.retries,
                     e.wait_s, e.result.worker, e.result.accepted,
                     e.result.staleness, e.result.version]
                    for e in loop.log.events],
            "replans": [dataclasses.asdict(e) for e in self.replan_events],
            "membership_events": [dataclasses.asdict(e)
                                  for e in self.membership_events],
            "residual_keys": sorted([w, l] for w, l in self._residuals),
            "ledger": {
                "pulled_bytes": {str(w): b
                                 for w, b in led.pulled_bytes.items()},
                "pushed_bytes": {str(w): b
                                 for w, b in led.pushed_bytes.items()},
                "pulled_wire_bytes": {
                    str(w): b for w, b in led.pulled_wire_bytes.items()},
                "pushed_wire_bytes": {
                    str(w): b for w, b in led.pushed_wire_bytes.items()},
                "num_pulls": led.num_pulls, "num_pushes": led.num_pushes,
                "rejected_pushes": led.rejected_pushes,
                "waited_pushes": led.waited_pushes,
                "migrated_bytes": led.migrated_bytes,
                "num_reshards": led.num_reshards,
            },
        }
        tree: Dict[str, Any] = {"meta": np.asarray(json.dumps(meta))}
        for w, e in loop.in_flight.items():
            for l, g in enumerate(e.grads):
                tree[f"infl/{w}/{l}"] = flatten_tree(g, self.specs[l])
        for i, (_pin, _t, _w, _loss, grads, _plan) in \
                enumerate(loop.barrier):
            for l, g in enumerate(grads):
                tree[f"bar/{i}/{l}"] = flatten_tree(g, self.specs[l])
        for (w, l), r in self._residuals.items():
            tree[f"res/{w}/{l}"] = r
        from repro.checkpoint import save_checkpoint
        save_checkpoint(path, tree)

    def restore_loop_state(self, path: str) -> None:
        """Inverse of :meth:`save_loop_state`.  Restore the server's
        ``state_dict`` first — the loop's pinned versions reference it."""
        data = np.load(path)
        meta = json.loads(str(data["meta"]))
        self.membership = FleetMembership.from_state(meta["membership"])
        self.detector.load_state_dict(meta["detector"])
        self.scheduler.load_state_dict(meta["scheduler"])
        if meta.get("planner") is not None:
            self.planner.load_state_dict(meta["planner"])
        self._stalled = set(meta["stalled"])
        self._true_factor = {int(w): f
                             for w, f in meta["true_factor"].items()}
        self._believed = {int(w): f for w, f in meta["believed"].items()}
        self._next_fleet_event = int(meta.get("next_fleet_event", 0))
        self._num_servers = int(meta["num_servers"])
        self._plans = {int(w): _plan_from_lists(p)
                       for w, p in meta["plans"].items()}
        self._durations = {int(w): d
                           for w, d in meta["durations"].items()}
        self._base_durations = {int(w): d
                                for w, d in meta["base_durations"].items()}
        self._true_durations = {int(w): d
                                for w, d in meta["true_durations"].items()}
        self._push_history = {
            int(w): [[_plan_from_lists(p), full, extra]
                     for p, full, extra in hist]
            for w, hist in meta["push_history"].items()}
        self.replan_events = [FleetReplanEvent(**e)
                              for e in meta["replans"]]
        self.membership_events = [MembershipChange(**e)
                                  for e in meta["membership_events"]]
        self._residuals = {
            (w, l): jnp.asarray(data[f"res/{w}/{l}"])
            for w, l in meta["residual_keys"]}
        led = self.server.ledger
        lm = meta["ledger"]
        led.pulled_bytes = {int(w): b
                            for w, b in lm["pulled_bytes"].items()}
        led.pushed_bytes = {int(w): b
                            for w, b in lm["pushed_bytes"].items()}
        led.pulled_wire_bytes = {
            int(w): b for w, b in lm["pulled_wire_bytes"].items()}
        led.pushed_wire_bytes = {
            int(w): b for w, b in lm["pushed_wire_bytes"].items()}
        led.num_pulls, led.num_pushes = lm["num_pulls"], lm["num_pushes"]
        led.rejected_pushes = lm["rejected_pushes"]
        led.waited_pushes = lm["waited_pushes"]
        led.migrated_bytes = lm["migrated_bytes"]
        led.num_reshards = lm["num_reshards"]
        topo = self.membership.topology(self._num_servers,
                                        flops_scale=self._believed)
        self.server.topology = topo
        self.topology = topo
        loop = _FleetLoop(
            log=AsyncRunLog(events=[
                AsyncPushEvent(
                    worker=w, sim_time=t, version=v, loss=loss,
                    retries=r, wait_s=ws,
                    result=PushResult(worker=rw, accepted=bool(acc),
                                      staleness=st, version=rv))
                for w, t, v, loss, r, ws, rw, acc, st, rv
                in meta["log"]]),
            parked=[int(w) for w in meta["parked"]],
            engine=EventQueue.from_state(meta["engine"],
                                         decode=lambda p: tuple(p)),
            now=float(meta["now"]), accepted=int(meta["accepted"]),
            attempts={int(w): n for w, n in meta["attempts"].items()},
            retries={int(w): n for w, n in meta["retries"].items()})
        for w, seq, started, pin, loss, plan in meta["in_flight"]:
            grads = [unflatten_tree(jnp.asarray(data[f"infl/{w}/{l}"]),
                                    self.specs[l])
                     for l in range(len(self.specs))]
            loop.in_flight[int(w)] = _InFlight(
                seq=int(seq), started=float(started), pin=int(pin),
                loss=float(loss), grads=grads,
                plan=_plan_from_lists(plan))
        for i, (pin, done_t, w, loss, plan) in enumerate(meta["barrier"]):
            grads = [unflatten_tree(jnp.asarray(data[f"bar/{i}/{l}"]),
                                    self.specs[l])
                     for l in range(len(self.specs))]
            loop.barrier.append((int(pin), float(done_t), int(w),
                                 float(loss), grads,
                                 _plan_from_lists(plan)))
        self._loop = loop
