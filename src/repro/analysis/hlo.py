"""Structured HLO-text parser (layer 1 of ``repro.analysis``).

Promotes the regex scraping that used to live in
``repro.launch.hlo_analysis`` (and was copy-pasted across the slow-test
helpers) into a typed walk: every instruction definition becomes an
:class:`HloInstruction` with opcode, result type, and operand edges, and
the module knows how to resolve a bare operand name back to its
definition so operand-byte accounting works for both printer styles XLA
uses (bare ``%name`` operands vs inline-typed
``f32[2,128]{1,0} %name``).

Collective accounting rules fixed here (previously subtly wrong):

* async ``-start`` / ``-done`` pairs count **once** — the ``-start``
  carries the operand, the ``-done`` only consumes the start's tuple and
  is skipped entirely;
* tuple-typed operands (and tuple-typed defs a bare operand resolves to)
  sum **all** leaves.

Pure stdlib on purpose: parsing an HLO dump must not import jax, so the
lint/verify CLI and the golden-fixture tests stay import-light.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple, Union

__all__ = [
    "COLLECTIVES", "DTYPE_BYTES", "HloInstruction", "HloModule",
    "parse_hlo", "type_bytes", "collective_counts", "collective_summary",
]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# "  %name = dtype[dims]{layout} opcode(operands...), attrs" — tuple-typed
# results allowed; ROOT prefix optional.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\(.*?\)|[\w\[\]{},:#\d]+)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<rest>.*)$")
_LEAF_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^%?([\w.\-]+)$")


def type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string; tuple types sum **all** leaves.

    E.g. ``'bf16[8,128]{1,0}'`` → 2048, ``'(f32[4], f32[8])'`` → 48.
    Unknown dtypes (and token/opaque leaves) contribute zero.
    """
    total = 0
    for dtype, dims in _LEAF_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _split_top_level(s: str) -> List[str]:
    """Split a comma-separated list at depth 0 of ``()[]{}`` nesting."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _operand_region(rest: str) -> str:
    """The operand list of ``opcode(<rest>`` up to its matching ')'
    (everything after it is attributes like ``replica_groups={...}``)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i]
    return rest


@dataclasses.dataclass(frozen=True)
class HloInstruction:
    """One instruction definition line of an HLO module dump."""

    name: str
    result_type: str
    opcode: str
    operands: Tuple[str, ...]      # raw operand tokens, attrs stripped
    line: int                      # 1-based line number in the dump
    is_root: bool = False

    @property
    def base_opcode(self) -> str:
        """Opcode with any async ``-start``/``-done`` suffix stripped."""
        for suffix in ("-start", "-done"):
            if self.opcode.endswith(suffix):
                return self.opcode[:-len(suffix)]
        return self.opcode

    @property
    def is_async_done(self) -> bool:
        return self.opcode.endswith("-done")

    @property
    def is_collective(self) -> bool:
        """True for the collective op itself; ``-done`` halves are not
        (they only consume the ``-start`` tuple — counting both would
        double-count the pair)."""
        return self.base_opcode in COLLECTIVES and not self.is_async_done

    def operand_names(self) -> Tuple[str, ...]:
        """Bare instruction names referenced by the operand tokens."""
        names = []
        for tok in self.operands:
            m = _NAME_RE.match(tok.split()[-1]) if tok else None
            if m:
                names.append(m.group(1))
        return tuple(names)


@dataclasses.dataclass(frozen=True)
class HloModule:
    """All instruction definitions of an HLO dump, with name resolution."""

    instructions: Tuple[HloInstruction, ...]
    by_name: Dict[str, HloInstruction]

    def find(self, opcode: str) -> Tuple[HloInstruction, ...]:
        """Instructions whose *base* opcode matches (``-done`` included)."""
        return tuple(i for i in self.instructions if i.base_opcode == opcode)

    def collectives(self) -> Tuple[HloInstruction, ...]:
        """Collective ops, each async pair counted once (via its -start)."""
        return tuple(i for i in self.instructions if i.is_collective)

    def operand_bytes(self, instr: HloInstruction) -> int:
        """Total bytes of an instruction's operands.

        Inline-typed operand tokens are read directly; bare ``%name``
        tokens resolve against the definition map (tuple-typed defs sum
        all leaves).  Unresolvable tokens (literals, parameters of
        called computations) contribute zero.
        """
        total = 0
        for tok in instr.operands:
            b = type_bytes(tok)
            if b == 0:
                m = _NAME_RE.match(tok)
                if m and m.group(1) in self.by_name:
                    b = type_bytes(self.by_name[m.group(1)].result_type)
            total += b
        return total


def parse_hlo(text: str) -> HloModule:
    """Parse an HLO module dump (``compiled.as_text()``) line-by-line."""
    instructions: List[HloInstruction] = []
    by_name: Dict[str, HloInstruction] = {}
    for lineno, line in enumerate(text.splitlines(), start=1):
        m = _INSTR_RE.match(line)
        if not m:
            continue
        operands = tuple(_split_top_level(_operand_region(m.group("rest"))))
        instr = HloInstruction(
            name=m.group("name"), result_type=m.group("type"),
            opcode=m.group("opcode"), operands=operands, line=lineno,
            is_root=line.lstrip().startswith("ROOT"))
        instructions.append(instr)
        by_name[instr.name] = instr
    return HloModule(instructions=tuple(instructions), by_name=by_name)


ModuleOrText = Union[HloModule, str]


def _as_module(m: ModuleOrText) -> HloModule:
    return m if isinstance(m, HloModule) else parse_hlo(m)


def collective_counts(module_or_text: ModuleOrText) -> Dict[str, int]:
    """Per-kind collective counts (all kinds present, zeros included);
    async pairs count once."""
    module = _as_module(module_or_text)
    counts = {k: 0 for k in COLLECTIVES}
    for instr in module.collectives():
        counts[instr.base_opcode] += 1
    return counts


def collective_summary(module_or_text: ModuleOrText
                       ) -> Dict[str, List[Tuple[HloInstruction, int]]]:
    """Per-kind list of ``(instruction, operand_bytes)`` for every
    collective (async pairs once, via the ``-start``)."""
    module = _as_module(module_or_text)
    out: Dict[str, List[Tuple[HloInstruction, int]]] = \
        {k: [] for k in COLLECTIVES}
    for instr in module.collectives():
        out[instr.base_opcode].append((instr, module.operand_bytes(instr)))
    return out
