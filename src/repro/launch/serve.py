"""Serving launcher: prefill + batched KV-cache decode for ``--arch <id>``.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --requests 4 --prompt-len 32 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHITECTURES, get_config
from repro.models import init_params
from repro.serve.decode import batched_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--greedy", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step "
                         "(see DESIGN.md skip policy)")
    if cfg.frontend != "none":
        raise SystemExit("serve.py drives text archs")

    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.requests, args.prompt_len), 0,
        cfg.vocab_size)
    t0 = time.perf_counter()
    out = batched_generate(cfg, params, prompts, max_new_tokens=args.tokens,
                           greedy=args.greedy,
                           key=None if args.greedy else jax.random.PRNGKey(2))
    dt = time.perf_counter() - t0
    n = args.requests * args.tokens
    print(f"{cfg.name}: {n} tokens in {dt:.2f}s = {n / dt:.1f} tok/s")
    print("first request continuation:", out[0].tolist())


if __name__ == "__main__":
    main()
