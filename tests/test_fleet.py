"""Elastic-fleet subsystem tests (``repro.fleet``).

The deterministic event-queue engine, membership schedules (scripted +
synthesized churn), the per-worker drift detector, server re-sharding
with bit-exact versioned-state migration, and the ``FleetTrainer``
acceptance properties: staleness bound under churn, one re-plan per
membership event, ledger/membership conformance at zero findings, crash
partial-push accounting, silent-stall eviction, measured-drift
re-planning, and bit-identical determinism at a 512-worker fleet —
across two independent runs and across a
``save_loop_state``/``restore_loop_state`` resume.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.conformance import (verify_fleet_membership,
                                        verify_push_ledger)
from repro.fleet import (EventQueue, FleetDriftDetector, FleetEvent,
                         FleetMembership, FleetSchedule, FleetTrainer,
                         WorkerSpec)
from repro.optim import adamw, sgd

LAYERS, WIDTH = 3, 8


def _toy_layers(seed=0):
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.standard_normal(WIDTH), jnp.float32)}
            for _ in range(LAYERS)]


def _toy_loss(layer_list, batch):
    err = sum(jnp.sum((layer["w"] - batch["target"]) ** 2)
              for layer in layer_list)
    return err / len(layer_list)


def _batch(worker, idx):
    del worker, idx
    return {"target": jnp.zeros((WIDTH,), jnp.float32)}


def _make(workers, **kw):
    kw.setdefault("optimizer", sgd(1e-2, 0.0))
    return FleetTrainer(init_layers=_toy_layers(), loss_fn=_toy_loss,
                        workers=workers, throttle="wait", **kw)


def _log_key(log):
    """The full run log as a comparable value (bit-identity check)."""
    return [(e.worker, e.sim_time, e.version, e.loss, e.retries, e.wait_s,
             e.result.worker, e.result.accepted, e.result.staleness,
             e.result.version)
            for e in log.events]


# ---------------------------------------------------------------------------
# event-queue engine
# ---------------------------------------------------------------------------


class TestEventQueue:
    def test_pop_orders_by_time_then_seq(self):
        q = EventQueue()
        q.push(2.0, 7)
        q.push(1.0, 9, payload="late-insert")
        q.push(1.0, 3)
        order = [(e.time, e.worker) for e in (q.pop(), q.pop(), q.pop())]
        # equal times break by insertion seq, NOT by worker id
        assert order == [(1.0, 9), (1.0, 3), (2.0, 7)]

    def test_events_carry_payload_and_seq(self):
        q = EventQueue()
        a = q.push(0.0, 1, payload=("commit",))
        b = q.push(0.0, 1, payload=("check",))
        assert a.seq < b.seq
        assert q.pop().payload == ("commit",)
        assert q.pop().payload == ("check",)
        with pytest.raises(IndexError):
            q.pop()

    def test_validation_and_len(self):
        q = EventQueue()
        with pytest.raises(ValueError, match=">= 0"):
            q.push(-1.0, 0)
        assert len(q) == 0 and not q
        q.push(1.0, 0)
        assert len(q) == 1 and bool(q)
        assert q.peek().time == 1.0 and len(q) == 1

    def test_remove_if(self):
        q = EventQueue()
        for w in range(6):
            q.push(float(w), w)
        removed = q.remove_if(lambda e: e.worker % 2 == 0)
        assert removed == 3
        assert [e.worker for e in (q.pop(), q.pop(), q.pop())] == [1, 3, 5]

    def test_state_round_trip(self):
        q = EventQueue()
        q.push(3.0, 1, payload=("commit",))
        q.push(1.0, 2, payload=("fleet", 0))
        q.pop()
        q.push(2.0, 3)
        restored = EventQueue.from_state(q.state(),
                                         decode=lambda p: tuple(p) if p
                                         else p)
        # iteration is heap order — compare as sorted-by-key sets
        key = lambda e: (e.time, e.seq, e.worker, e.payload)
        assert sorted(map(key, restored)) == sorted(map(key, q))
        # seq counter survives: new pushes never collide with old ones
        old = max(e.seq for e in q)
        assert restored.push(9.9, 0).seq > old


# ---------------------------------------------------------------------------
# membership
# ---------------------------------------------------------------------------


class TestFleetSchedule:
    def test_validate_against(self):
        sched = FleetSchedule((
            FleetEvent(time=1.0, kind="join", worker=4),
            FleetEvent(time=2.0, kind="leave", worker=4),
        ))
        sched.validate_against([0, 1, 2, 3])
        with pytest.raises(ValueError, match="already used"):
            FleetSchedule((FleetEvent(time=1.0, kind="join", worker=2),)) \
                .validate_against([0, 1, 2, 3])
        with pytest.raises(ValueError, match="not active"):
            FleetSchedule((FleetEvent(time=1.0, kind="fail", worker=9),)) \
                .validate_against([0, 1])
        with pytest.raises(ValueError, match="ordered by time"):
            FleetSchedule((FleetEvent(time=2.0, kind="leave", worker=0),
                           FleetEvent(time=1.0, kind="leave", worker=1)))

    def test_event_validation(self):
        with pytest.raises(ValueError, match="kind"):
            FleetEvent(time=0.0, kind="nope", worker=0)
        with pytest.raises(ValueError, match="fail mode"):
            FleetEvent(time=0.0, kind="fail", worker=0, mode="explode")
        with pytest.raises(ValueError, match="only join"):
            FleetEvent(time=0.0, kind="leave", worker=0, spec=WorkerSpec())

    def test_round_trip(self):
        e = FleetEvent(time=1.5, kind="join", worker=7,
                       spec=WorkerSpec(down_bps=5e9))
        assert FleetEvent.from_dict(e.to_dict()) == e
        f = FleetEvent(time=2.0, kind="fail", worker=7, mode="stall")
        assert FleetEvent.from_dict(f.to_dict()) == f

    def test_synthesize_deterministic_and_coherent(self):
        a = FleetSchedule.synthesize(range(16), churn=2.0, horizon=5.0,
                                     seed=11)
        b = FleetSchedule.synthesize(range(16), churn=2.0, horizon=5.0,
                                     seed=11)
        assert a == b and len(a) > 0
        a.validate_against(range(16))
        c = FleetSchedule.synthesize(range(16), churn=2.0, horizon=5.0,
                                     seed=12)
        assert a != c

    def test_synthesize_respects_fleet_floor(self):
        sched = FleetSchedule.synthesize(range(4), churn=20.0, horizon=5.0,
                                         seed=0, min_fleet=2)
        active = set(range(4))
        for e in sched.events:
            if e.kind == "join":
                active.add(e.worker)
            else:
                active.discard(e.worker)
            assert len(active) >= 2


class TestFleetMembership:
    def test_roster_and_topology_projection(self):
        m = FleetMembership({0: WorkerSpec(), 2: WorkerSpec(up_bps=2e9)})
        assert m.active == (0, 2) and m.index_of(2) == 1
        m.join(5, WorkerSpec(flops=5e9), time=1.0, version=3)
        assert m.joined_at[5] == (1.0, 3)
        topo = m.topology(2)
        assert topo.num_workers == 3
        assert topo.links[1].up.bandwidth_bps == 2e9
        assert topo.worker_flops[2] == 5e9
        # believed slowdown divides the projected compute rate
        slowed = m.topology(2, flops_scale={5: 2.0})
        assert slowed.worker_flops[2] == pytest.approx(2.5e9)

    def test_departed_ids_never_reused(self):
        m = FleetMembership({0: WorkerSpec(), 1: WorkerSpec()})
        m.depart(1, time=2.0, reason="crash")
        assert m.departed[1] == (2.0, "crash")
        with pytest.raises(ValueError, match="already used"):
            m.join(1, WorkerSpec(), time=3.0, version=0)

    def test_state_round_trip(self):
        m = FleetMembership({0: WorkerSpec(), 1: WorkerSpec()})
        m.join(4, WorkerSpec(up_bps=3e9), time=1.0, version=2)
        m.depart(0, time=2.0, reason="leave")
        r = FleetMembership.from_state(m.state_dict())
        assert r.active == m.active
        assert r.joined_at == m.joined_at and r.departed == m.departed
        assert r.spec(4) == m.spec(4)


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


class TestFleetDriftDetector:
    def test_per_worker_streams_are_independent(self):
        det = FleetDriftDetector(threshold=0.3, patience=2, warmup=2)
        for _ in range(4):
            assert not det.observe(0, 1.0)
            assert not det.observe(1, 5.0)   # different baseline, no drift
        fired = [det.observe(0, 4.0) for _ in range(8)]
        assert any(fired)
        # worker 1's stream is untouched by worker 0's drift
        assert not det.observe(1, 5.0)

    def test_baseline_reseeds_after_trigger(self):
        det = FleetDriftDetector(threshold=0.3, patience=1, warmup=1)
        det.observe(0, 1.0)
        det.observe(0, 1.0)
        assert det.observe(0, 10.0)          # drift fires
        # new regime becomes the baseline: staying there is not a drift
        assert not det.observe(0, det.observed_gap(0))

    def test_forget_and_state_round_trip(self):
        det = FleetDriftDetector()
        det.observe(0, 1.0)
        det.observe(1, 2.0)
        det.forget(0)
        assert det.observed_gap(0) is None
        r = FleetDriftDetector()
        r.load_state_dict(det.state_dict())
        assert r.observed_gap(1) == det.observed_gap(1)
        assert r.state_dict() == det.state_dict()

    def test_validation(self):
        with pytest.raises(ValueError, match="alpha"):
            FleetDriftDetector(alpha=0.0)
        det = FleetDriftDetector()
        with pytest.raises(ValueError, match="positive"):
            det.observe(0, 0.0)


# ---------------------------------------------------------------------------
# server re-sharding
# ---------------------------------------------------------------------------


class TestReshard:
    def _trained(self, optimizer):
        tr = _make(6, num_servers=2, staleness=2, optimizer=optimizer)
        tr.run(12, _batch)
        return tr

    def test_reshard_preserves_versioned_state_bit_exactly(self):
        tr = self._trained(adamw(1e-3))
        server = tr.server
        pre_flats = [np.asarray(f).copy() for f in server.flats()]
        pre_version = server.version
        pre_mu = [np.asarray(m).copy() for m in server._opt_state.mu]
        pre_nu = [np.asarray(m).copy() for m in server._opt_state.nu]
        info = server.reshard(tr.membership.topology(3))
        assert info["num_servers"] == 3
        assert server.version == pre_version
        for a, b in zip(pre_flats, server.flats()):
            assert np.array_equal(a, np.asarray(b))
        for pre_m, post_m in zip(pre_mu, server._opt_state.mu):
            assert np.array_equal(pre_m, np.asarray(post_m))
        for pre_m, post_m in zip(pre_nu, server._opt_state.nu):
            assert np.array_equal(pre_m, np.asarray(post_m))

    def test_migration_bytes_formula(self):
        for optimizer, slots in ((sgd(1e-2, 0.0), 0), (adamw(1e-3), 2)):
            tr = self._trained(optimizer)
            server = tr.server
            old = server.topology
            new = tr.membership.topology(3)
            L = server.num_layers
            moved = [l for l in range(L)
                     if old.shard_of_layer(l, L) != new.shard_of_layer(l, L)]
            expected = sum(server.specs[l].total * 4
                           for l in moved) * (1 + slots)
            info = server.reshard(new)
            assert info["moved_layers"] == len(moved)
            assert info["migrated_bytes"] == expected
            assert server.ledger.migrated_bytes == expected
            assert server.ledger.num_reshards == 1

    def test_pull_after_reshard_matches_pre_snapshot(self):
        tr = self._trained(adamw(1e-3))
        server = tr.server
        pin = server.version
        bucket = tuple(range(server.num_layers))
        pre = {l: np.asarray(f).copy() for l, f in
               server.pull_bucket(bucket, version=pin)[1].items()}
        server.reshard(tr.membership.topology(3))
        post = server.pull_bucket(bucket, version=pin)[1]
        for l in bucket:
            assert np.array_equal(pre[l], np.asarray(post[l]))


# ---------------------------------------------------------------------------
# elastic training: churn acceptance
# ---------------------------------------------------------------------------


class TestFleetChurn:
    K = 2

    def _churn_trainer(self):
        schedule = FleetSchedule((
            FleetEvent(time=0.05, kind="drift", worker=4, factor=2.0),
            FleetEvent(time=0.10, kind="join", worker=64,
                       spec=WorkerSpec(up_bps=0.5e9)),
            FleetEvent(time=0.20, kind="leave", worker=1),
            FleetEvent(time=0.30, kind="fail", worker=2, mode="crash"),
            FleetEvent(time=0.35, kind="fail", worker=3, mode="stall"),
        ))
        return _make(64, schedule=schedule, num_servers=2,
                     workers_per_shard=16, staleness=self.K)

    def test_w64_churn_run(self):
        tr = self._churn_trainer()
        log = tr.run(160, _batch)

        # the acceptance criteria of the subsystem, in one run: bound
        # holds, every membership event re-planned, roster is coherent
        assert len(log.accepted) == 160
        assert log.max_staleness <= self.K
        kinds = [e.kind for e in tr.membership_events]
        assert {"join", "leave", "crash", "stall"} <= set(kinds)
        reasons = [e.reason for e in tr.replan_events]
        assert reasons[0] == "init"
        for reason in ("join", "leave", "crash"):
            assert reason in reasons
        # replans fire AT the membership events' simulated times
        by_reason = {e.reason: e for e in tr.replan_events}
        assert by_reason["join"].sim_time == pytest.approx(0.10)
        assert by_reason["leave"].sim_time == pytest.approx(0.20)
        # the joined worker re-planned in, the departed ones out
        assert by_reason["join"].num_workers == 65
        assert 64 in tr.plans and 1 not in tr.plans and 2 not in tr.plans

        # shard count follows the fleet: 64 workers / 16 per shard = 4
        assert tr.server.topology.num_servers == 4
        assert any(e.resharded for e in tr.replan_events)
        assert tr.server.ledger.num_reshards > 0

        # roster history: the join version anchors the new worker's
        # pushes; departures record their reason
        join_t, join_v = tr.membership.joined_at[64]
        assert join_t == pytest.approx(0.10)
        assert tr.membership.departed[1][1] == "leave"
        assert tr.membership.departed[2][1] == "crash"

        # conformance: ledger decomposes under per-worker plan histories
        # (including the crashed worker's partial push), membership audit
        # at zero findings
        assert verify_push_ledger(tr.server.ledger, tr.push_history,
                                  tr.specs, None) == []
        assert verify_fleet_membership(
            log, tr.membership.joined_at, tr.membership.departed,
            staleness_bound=self.K) == []

    def test_stall_is_detected_and_evicted(self):
        schedule = FleetSchedule((
            FleetEvent(time=0.05, kind="fail", worker=0, mode="stall"),
        ))
        tr = _make(4, schedule=schedule, num_servers=1, staleness=1,
                   stall_factor=2.0)
        log = tr.run(40, _batch)
        kinds = [e.kind for e in tr.membership_events]
        assert "stall" in kinds and "stall-evict" in kinds
        assert not tr.membership.is_active(0)
        assert tr.membership.departed[0][1] == "stall"
        assert "stall" in [e.reason for e in tr.replan_events]
        assert log.max_staleness <= 1

    def test_crash_mid_push_closes_ledger_cleanly(self):
        schedule = FleetSchedule((
            FleetEvent(time=0.06, kind="fail", worker=0, mode="crash"),
        ))
        tr = _make(2, schedule=schedule, num_servers=1, staleness=1)
        tr.run(30, _batch)
        assert not tr.membership.is_active(0)
        # the crashed worker's wire bytes decompose under its history —
        # whole iterations plus the partial walk the crash cut short
        assert verify_push_ledger(tr.server.ledger, tr.push_history,
                                  tr.specs, None) == []
        # and the server holds no half-accumulated segments from it
        assert all(k[0] != 0 for k in tr.server._pending)

    def test_measured_drift_triggers_replan(self):
        # compute-dominated profiles: a 3x compute drift moves the
        # commit gap enough for the EWMA detector to breach
        from repro.dist.collectives import make_flat_spec
        from repro.ps.dynamic import profiles_from_specs
        flat_specs = [make_flat_spec(t, 1) for t in _toy_layers()]
        profiles = profiles_from_specs(flat_specs, flops_per_param=1e4)
        specs = {w: WorkerSpec(down_bps=100e9, up_bps=100e9, flops=1e7)
                 for w in range(3)}
        schedule = FleetSchedule((
            FleetEvent(time=0.2, kind="drift", worker=0, factor=3.0),
        ))
        tr = _make(specs, schedule=schedule, num_servers=1, staleness=2,
                   profiles=profiles,
                   drift_detector=FleetDriftDetector(threshold=0.3,
                                                     patience=2, warmup=2))
        tr.run(80, _batch)
        kinds = [e.kind for e in tr.membership_events]
        assert "drift-detect" in kinds
        drift_replans = [e for e in tr.replan_events if e.reason == "drift"]
        assert drift_replans and drift_replans[0].worker == 0
        # the planner's believed slowdown tracks the measured one:
        # compute is most (not all) of the gap, so the learned factor
        # sits between 1 and the injected 3x
        assert 1.3 <= tr._believed[0] <= 3.5

    def test_fleet_exhaustion_raises(self):
        schedule = FleetSchedule((
            FleetEvent(time=0.01, kind="leave", worker=0),
            FleetEvent(time=0.02, kind="leave", worker=1),
        ))
        tr = _make(2, schedule=schedule, num_servers=1, staleness=1)
        with pytest.raises(RuntimeError, match="fleet"):
            tr.run(500, _batch)


# ---------------------------------------------------------------------------
# determinism at scale
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestFleetDeterminism512:
    W, PUSHES, K = 512, 240, 8

    def _fresh(self):
        schedule = FleetSchedule.synthesize(
            range(self.W), churn=6.0, horizon=0.8, seed=7)
        return _make(self.W, schedule=schedule, num_servers=4,
                     workers_per_shard=128, staleness=self.K)

    def test_two_runs_bit_identical(self):
        a, b = self._fresh(), self._fresh()
        log_a = a.run(self.PUSHES, _batch)
        log_b = b.run(self.PUSHES, _batch)
        assert _log_key(log_a) == _log_key(log_b)
        assert log_a.max_staleness <= self.K
        assert a.membership_events == b.membership_events

        # replan streams match up to wall-clock scheduling telemetry
        def stripped(tr):
            return [(e.sim_time, e.at_push, e.reason, e.worker,
                     e.num_workers, e.num_servers, e.plan_changed,
                     e.resharded, e.migrated_bytes)
                    for e in tr.replan_events]
        assert stripped(a) == stripped(b)

    def test_resume_bit_identical(self, tmp_path):
        half = self.PUSHES // 2
        full = self._fresh()
        log_full = full.run(self.PUSHES, _batch)

        first = self._fresh()
        first.run(half, _batch)
        ck = str(tmp_path / "loop.npz")
        server_state = first.server.state_dict()
        first.save_loop_state(ck)
        log_first = first.run(self.PUSHES - half, _batch, reset=False)

        resumed = self._fresh()
        resumed.server.load_state_dict(server_state)
        resumed.restore_loop_state(ck)
        log_resumed = resumed.run(self.PUSHES - half, _batch, reset=False)

        assert _log_key(log_resumed) == _log_key(log_first)
        assert _log_key(log_resumed) == _log_key(log_full)
        assert resumed.membership_events == first.membership_events


class TestFleetValidation:
    def test_ctor_rejects_bad_args(self):
        with pytest.raises(ValueError, match="throttle"):
            FleetTrainer(init_layers=_toy_layers(), loss_fn=_toy_loss,
                         optimizer=sgd(1e-2, 0.0), workers=2,
                         throttle="nope")
        with pytest.raises(ValueError, match="stall_factor"):
            _make(2, stall_factor=1.0)
        with pytest.raises(ValueError, match="not active"):
            _make(2, schedule=FleetSchedule(
                (FleetEvent(time=0.1, kind="leave", worker=9),)))
