"""Per-layer block: init / apply dispatch over the config's layer kinds.

Every block is addressable individually — DynaComm schedules transmissions
layer-by-layer, so the model deliberately exposes `init_block` / `apply_block`
instead of a fused scan-only stack.  (A `lax.scan` fast path exists in
model.py for homogeneous stacks.)
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerKind
from repro.models import attention, ssm
from repro.models.layers import apply_mlp, init_mlp, rms_norm
from repro.models.moe import apply_moe, init_moe_params


def init_block(key, cfg: ArchConfig, kind: LayerKind, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p: dict = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if kind in ("global_attn", "local_attn"):
        p["attn"] = attention.init_attn_params(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = ssm.init_mlstm_params(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = ssm.init_slstm_params(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rglru"] = ssm.init_rglru_params(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)

    if cfg.d_ff > 0:
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        if cfg.is_moe:
            p["moe"] = init_moe_params(ks[1], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def init_block_cache(cfg: ArchConfig, kind: LayerKind, batch: int,
                     max_len: int, dtype=jnp.float32):
    if kind == "global_attn":
        return attention.init_cache(cfg, batch, max_len, local=False, dtype=dtype)
    if kind == "local_attn":
        return attention.init_cache(cfg, batch, max_len, local=True, dtype=dtype)
    if kind == "mlstm":
        return ssm.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return ssm.init_slstm_state(cfg, batch)
    if kind == "rglru":
        return ssm.init_rglru_state(cfg, batch, dtype=dtype)
    raise ValueError(kind)


def apply_block(params, x: jnp.ndarray, cfg: ArchConfig, kind: LayerKind, *,
                mode: str, cache: Any = None
                ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind in ("global_attn", "local_attn"):
        out, new_cache = attention.attention(
            params["attn"], h, cfg, local=(kind == "local_attn"),
            mode=mode, cache=cache)
    elif kind == "mlstm":
        out, new_cache = ssm.apply_mlstm(params["mlstm"], h, cfg, mode=mode,
                                         state=cache)
    elif kind == "slstm":
        out, new_cache = ssm.apply_slstm(params["slstm"], h, cfg, mode=mode,
                                         state=cache)
    elif kind == "rglru":
        out, new_cache = ssm.apply_rglru(params["rglru"], h, cfg, mode=mode,
                                         state=cache)
    else:
        raise ValueError(kind)
    x = x + out

    if cfg.d_ff > 0:
        h2 = rms_norm(x, params["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            out2, aux = apply_moe(params["moe"], h2, cfg)
        else:
            out2 = apply_mlp(params["mlp"], h2, cfg.activation)
        x = x + out2
    return x, new_cache, aux
