"""GQA attention with rope, sliding window, logit softcap and a KV cache.

Three modes share one code path:

* ``train`` / ``prefill`` — full-sequence attention, causal or bidirectional
  (encoder).  Prefill additionally returns the populated cache.
* ``decode`` — one new token against a preallocated cache.  Global layers
  cache the whole sequence (the cache's sequence axis may be sharded over the
  ``data`` mesh axis for long-context decode — GSPMD handles the partial
  softmax); local layers keep a rotating window-sized cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense, init_dense, softcap


class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, S, n_kv, head_dim)
    v: jnp.ndarray       # (B, S, n_kv, head_dim)
    pos: jnp.ndarray     # () int32 — number of tokens already cached


def init_attn_params(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], cfg.d_model, cfg.q_dim, dtype),
        "wk": init_dense(ks[1], cfg.d_model, cfg.kv_dim, dtype),
        "wv": init_dense(ks[2], cfg.d_model, cfg.kv_dim, dtype),
        "wo": init_dense(ks[3], cfg.q_dim, cfg.d_model, dtype),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, local: bool,
               dtype=jnp.float32) -> KVCache:
    s = min(max_len, cfg.sliding_window) if local and cfg.sliding_window else max_len
    shape = (batch, s, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((), jnp.int32))


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int, dtype):
    """(Tq, Tk) additive bias; window>0 limits lookback (sliding window)."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, np.float32(-1e30)).astype(dtype)


def _sdpa(q, k, v, bias, n_rep: int, cap: float):
    """q: (B,Tq,Hq,hd); k,v: (B,Tk,Hkv,hd); bias: (Tq,Tk)."""
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    qg = q.reshape(b, tq, hkv, n_rep, hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k) / np.sqrt(hd).astype(np.float32)
    logits = softcap(logits.astype(jnp.float32), cap)
    logits = logits + bias.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)
    return out.reshape(b, tq, hq, hd)


# Sequences longer than this use the blockwise online-softmax path in
# train/prefill (the full T×T score matrix would blow HBM; this is the
# XLA-level analogue of the Pallas flash_attention kernel).
FULL_ATTN_MAX = 1024


def _block_bias(q_pos, k_pos, *, causal, window):
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, np.float32(-1e30))


def _sdpa_chunked(q, k, v, *, n_rep: int, cap: float, causal: bool,
                  window: int, chunk: int | None = None):
    """Blockwise attention with online softmax (flash pattern in pure XLA).

    Memory O(Tq·chunk) instead of O(Tq·Tk); causal/windowed query blocks
    skip key blocks that are entirely masked, so FLOPs follow the mask.
    """
    b, tq, hq, hd = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    if chunk is None:
        chunk = min(tk, max(1024, tk // 16))
    while tk % chunk:
        chunk //= 2
    n_kv = tk // chunk
    n_q = tq // chunk if tq % chunk == 0 else 1
    qc = tq // n_q

    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, tq, hkv, n_rep, hd)
    outs = []
    for qi in range(n_q):
        q_lo, q_hi = qi * qc, (qi + 1) * qc            # python ints: static
        q_pos = jnp.arange(q_lo, q_hi)
        qq = jax.lax.dynamic_slice_in_dim(qg, q_lo, qc, axis=1)
        m = jnp.full((b, hkv, n_rep, qc), -np.inf, jnp.float32)
        l = jnp.zeros((b, hkv, n_rep, qc), jnp.float32)
        acc = jnp.zeros((b, hkv, n_rep, qc, hd), jnp.float32)
        for ki in range(n_kv):
            k_lo, k_hi = ki * chunk, (ki + 1) * chunk
            if causal and k_lo > q_hi - 1:
                continue                       # entirely in the future
            if window > 0 and k_hi - 1 <= q_lo - window:
                continue                       # entirely out of the window
            k_pos = jnp.arange(k_lo, k_hi)
            kk = jax.lax.dynamic_slice_in_dim(k, k_lo, chunk, axis=1)
            vv = jax.lax.dynamic_slice_in_dim(v, k_lo, chunk, axis=1)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qq, kk).astype(jnp.float32)
            s = softcap(s * scale, cap)
            s = s + _block_bias(q_pos, k_pos, causal=causal, window=window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] \
                + jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(q.dtype), vv
                             ).astype(jnp.float32)
            m = m_new
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(out.transpose(0, 3, 1, 2, 4).reshape(b, qc, hq, hd)
                    .astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def attention(params, x: jnp.ndarray, cfg: ArchConfig, *,
              local: bool, mode: str,
              cache: Optional[KVCache] = None,
              positions: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Returns (output (B,T,d_model), updated cache or None)."""
    b, t, _ = x.shape
    n_rep = cfg.num_heads // cfg.num_kv_heads
    window = cfg.sliding_window if local else 0

    q = dense(x, params["wq"]).reshape(b, t, cfg.num_heads, cfg.head_dim)
    k = dense(x, params["wk"]).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = dense(x, params["wv"]).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)

    if mode in ("train", "prefill"):
        pos = jnp.arange(t) if positions is None else positions
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        if t > FULL_ATTN_MAX:
            out = _sdpa_chunked(q, k, v, n_rep=n_rep,
                                cap=cfg.attn_logit_softcap,
                                causal=cfg.causal, window=window)
        else:
            bias = _mask_bias(pos, pos, causal=cfg.causal, window=window,
                              dtype=jnp.float32)
            out = _sdpa(q, k, v, bias, n_rep, cfg.attn_logit_softcap)
        out = out.reshape(b, t, cfg.q_dim)
        new_cache = None
        if mode == "prefill":
            if window and t > window:
                # rotating buffer invariant: absolute position p sits at
                # slot p % window
                ck = jnp.roll(k[:, -window:], shift=(t - window) % window, axis=1)
                cv = jnp.roll(v[:, -window:], shift=(t - window) % window, axis=1)
            elif window and t < window:
                padw = window - t
                ck = jnp.pad(k, ((0, 0), (0, padw), (0, 0), (0, 0)))
                cv = jnp.pad(v, ((0, 0), (0, padw), (0, 0), (0, 0)))
            else:
                ck, cv = k, v
            new_cache = KVCache(k=ck, v=cv, pos=jnp.asarray(t, jnp.int32))
        return dense(out, params["wo"]), new_cache

    # ----- decode: t == 1 new token against the cache -----
    assert cache is not None and t == 1
    pos = cache.pos  # scalar: index of the new token
    q = apply_rope(q, pos[None][None, :], cfg.rope_theta)
    k = apply_rope(k, pos[None][None, :], cfg.rope_theta)

    s = cache.k.shape[1]
    if window and window < 10**9:
        slot = jnp.mod(pos, s)
    else:
        slot = pos
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))

    # key positions: rotating buffer slots hold absolute position
    slots = jnp.arange(s)
    if window:
        # slot i holds absolute pos: the latest p <= pos with p % s == i
        kpos = pos - jnp.mod(pos - slots, s)
    else:
        kpos = slots
    valid = (kpos <= pos) & (kpos >= 0)
    bias = jnp.where(valid, 0.0, np.float32(-1e30))[None, :].astype(jnp.float32)

    out = _sdpa(q, ck, cv, bias, n_rep, cfg.attn_logit_softcap)
    out = out.reshape(b, t, cfg.q_dim)
    new_cache = KVCache(k=ck, v=cv, pos=pos + 1)
    return dense(out, params["wo"]), new_cache
