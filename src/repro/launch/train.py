"""Training launcher: ``--arch <id>`` + input shape + strategy.

Four runtimes:

* ``--runtime local`` (default) — single-process jit training on whatever
  devices exist; reduced configs runnable on CPU.
* ``--runtime zero`` — the DynaComm-bucketed ZeRO trainer over a 1-D data
  mesh (all local devices), schedule chosen by ``--strategy``; the plan is
  decided once at startup.
* ``--runtime dynamic`` — the run-time loop (paper Section IV-C): the
  scheduler re-plans every ``--steps-per-epoch`` steps against the active
  network model and swaps compiled steps when the decision changes.  Pair
  with ``--bw-shift-gbps`` to script a bandwidth drift and watch the
  schedule re-segment mid-training; ``--drift-detect`` re-schedules from
  *observed* step times instead.
* ``--runtime ps`` — the parameter-server subsystem (the paper's actual
  topology): ``--ps-servers`` shards × one worker per device behind
  asymmetric ``--down-gbps``/``--up-gbps`` links, consensus-planned via
  the per-topology cost model.  Synchronous by default;
  ``--staleness k`` switches to bounded-staleness asynchronous execution
  (host-level event loop, one logical worker per ``--ps-workers``),
  with ``--throttle reject`` (stale pushes evicted) or ``--throttle
  wait`` (SSP wait-at-barrier: nothing dropped, fast workers block).
* ``--runtime dynamic-ps`` — the run-time loop in the PS regime: the
  consensus plan is re-derived every ``--steps-per-epoch`` steps against
  a *time-varying topology* (``--up-shift-gbps`` degrades every worker's
  uplink at ``--shift-epoch``) and compiled steps are swapped from the
  plan-keyed cache.  With ``--staleness k`` the loop goes asynchronous:
  per-worker re-plans swapped into the bounded-staleness event loop
  (``--throttle`` selects rejection or SSP wait), one topology epoch per
  ``--steps-per-epoch`` accepted pushes.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --reduced --runtime zero --strategy dynacomm --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --runtime dynamic --steps 60 --steps-per-epoch 20 \
        --bw-gbps 10 --bw-shift-gbps 1 --shift-epoch 1
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --runtime ps --ps-servers 2 --down-gbps 10 --up-gbps 1 \
        --steps 30
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import ARCHITECTURES, get_config
from repro.configs.base import InputShape
from repro.core import (EdgeNetworkModel, costs_from_profiles,
                        DynaCommScheduler, plan_from_decision)
from repro.data.pipeline import SyntheticText
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw, sgd
from repro.train.loop import TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--runtime",
                    choices=("local", "zero", "dynamic", "ps", "dynamic-ps"),
                    default="local")
    ap.add_argument("--strategy", default="dynacomm",
                    choices=("sequential", "lbl", "ibatch", "dynacomm"))
    # scheduling knobs (zero + dynamic runtimes)
    ap.add_argument("--steps-per-epoch", type=int, default=20,
                    help="re-scheduling interval of the dynamic runtime")
    ap.add_argument("--bw-gbps", type=float, default=10.0,
                    help="edge uplink bandwidth (Gbit/s)")
    ap.add_argument("--bw-shift-gbps", type=float, default=None,
                    help="drift the uplink to this bandwidth at --shift-epoch")
    ap.add_argument("--shift-epoch", type=int, default=1)
    ap.add_argument("--cost-source", choices=("analytic", "measured"),
                    default="analytic")
    ap.add_argument("--drift-detect", action="store_true",
                    help="dynamic runtime: also re-schedule when observed "
                         "step times drift (EWMA detector)")
    # parameter-server knobs (ps runtime)
    ap.add_argument("--ps-servers", type=int, default=2,
                    help="number of server shards")
    ap.add_argument("--ps-workers", type=int, default=None,
                    help="async mode only: logical worker count "
                         "(sync mode runs one worker per device)")
    ap.add_argument("--down-gbps", type=float, default=10.0,
                    help="server→worker (pull) bandwidth per link")
    ap.add_argument("--up-gbps", type=float, default=1.0,
                    help="worker→server (push) bandwidth per link")
    ap.add_argument("--staleness", type=int, default=None,
                    help="bounded-staleness k: switch the ps runtime to "
                         "asynchronous execution")
    ap.add_argument("--throttle", choices=("reject", "wait"),
                    default="reject",
                    help="async ps: evict stale pushes (reject) or SSP "
                         "wait-at-barrier (wait — slow workers always "
                         "contribute)")
    ap.add_argument("--up-shift-gbps", type=float, default=None,
                    help="dynamic-ps: degrade every uplink to this "
                         "bandwidth at --shift-epoch")
    ap.add_argument("--worker-flops", type=float, default=1e10,
                    help="edge-worker compute rate fed to the profiler")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=("adamw", "sgd"), default="adamw")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend != "none":
        raise SystemExit("train.py drives text archs; stubbed-modality "
                         "archs are exercised via the dry-run and tests")

    opt = adamw(args.lr) if args.optimizer == "adamw" else sgd(args.lr, 0.9)
    pipe = SyntheticText(cfg.vocab_size, args.seq, args.batch, seed=0)

    if args.runtime == "local":
        loop = TrainLoop(cfg=cfg, optimizer=opt, log_every=10,
                         checkpoint_path=args.checkpoint,
                         checkpoint_every=50 if args.checkpoint else 0)
        loop.run(jax.random.PRNGKey(0), iter(pipe), num_steps=args.steps)
        return

    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(len(devs),), ("data",))
    shape = InputShape("cli", args.seq, args.batch, "train")

    if args.runtime == "ps":
        _run_ps(args, cfg, mesh, opt, pipe, shape)
        return

    if args.runtime == "dynamic-ps":
        _run_dynamic_ps(args, cfg, mesh, opt, pipe, shape)
        return

    if args.runtime == "dynamic":
        # run-time loop: re-profile + re-plan every epoch, swap compiled
        # steps when the decision changes
        from repro.core import bandwidth_shift
        from repro.dist.dynamic import DynamicTrainer
        if args.bw_shift_gbps is not None:
            net = bandwidth_shift(args.bw_gbps * 1e9,
                                  args.bw_shift_gbps * 1e9,
                                  at_epoch=args.shift_epoch)
        else:
            net = EdgeNetworkModel(bandwidth_bps=args.bw_gbps * 1e9)
        detector = None
        if args.drift_detect:
            from repro.core import EwmaDriftDetector
            detector = EwmaDriftDetector()
            if args.cost_source == "analytic":
                print("[dynamic] note: --drift-detect re-schedules from "
                      "re-derived costs; with --cost-source analytic those "
                      "only change with the scripted network schedule — "
                      "pair with --cost-source measured to react to real "
                      "compute drift")
        dyn = DynamicTrainer(cfg=cfg, mesh=mesh, optimizer=opt, network=net,
                             steps_per_epoch=args.steps_per_epoch,
                             strategy=args.strategy, input_shape=shape,
                             cost_source=args.cost_source,
                             compute_flops_per_s=args.worker_flops,
                             drift_detector=detector)
        print(f"[dynamic] {len(devs)} devices; strategy {args.strategy}, "
              f"re-plan every {args.steps_per_epoch} steps")
        state = dyn.init_state(jax.random.PRNGKey(0))
        dyn.run(state, pipe.batch, args.steps, log_every=10)
        for e in dyn.events:
            ag, rs = dyn.hlo_counts(e.plan)
            print(f"epoch {e.epoch:3d} step {e.step:4d}: "
                  f"{len(e.plan.forward)} pull / {len(e.plan.backward)} push "
                  f"buckets (hlo {ag} ag / {rs} rs)  "
                  f"{'re-segmented' if e.plan_changed else 'unchanged'}"
                  f"{' [cache hit]' if e.plan_changed and not e.retraced else ''}"
                  f"  sched {e.scheduling_seconds * 1e3:.2f} ms "
                  f"hidden={e.overhead_hidden}")
        print(f"[dynamic] traces {dyn.traces}, cache hits {dyn.cache_hits}")
        return

    # zero runtime: profile → schedule → bucketed trainer
    from repro.dist.zero import ZeroTrainer
    costs = costs_from_profiles(
        layer_profiles(cfg, shape),
        net=EdgeNetworkModel(bandwidth_bps=args.bw_gbps * 1e9),
        compute_flops_per_s=args.worker_flops)
    sched = DynaCommScheduler(strategy=args.strategy)
    decision = sched.decision_for_iteration(costs)
    plan = plan_from_decision(*decision, num_sched_layers(cfg))
    print(f"[zero] {len(devs)} devices; {args.strategy}: "
          f"{len(plan.forward)} pull / {len(plan.backward)} push buckets")
    trainer = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=opt)
    state = trainer.init_state(jax.random.PRNGKey(0))
    step = jax.jit(trainer.build_train_step())
    t0 = time.perf_counter()
    for i in range(args.steps):
        state, loss = step(state, pipe.batch(i))
        if (i + 1) % 10 == 0:
            print(f"step {i + 1:4d}  loss {float(loss):.4f}  "
                  f"{(time.perf_counter() - t0) / (i + 1):.3f}s/step")


def _run_dynamic_ps(args, cfg, mesh, opt, pipe, shape) -> None:
    """The run-time loop over a time-varying PS topology: once per
    topology epoch, a consensus re-plan + compiled-step swap (sync), or a
    per-worker re-plan swapped into the async event loop when
    ``--staleness`` is given."""
    from repro.ps import (DynamicPSTrainer, PSTopology, uplink_degradation)

    n_dev = len(jax.devices())
    W = (args.ps_workers or n_dev) if args.staleness is not None else n_dev
    base = PSTopology.uniform(args.ps_servers, W,
                              down_bps=args.down_gbps * 1e9,
                              up_bps=args.up_gbps * 1e9,
                              flops=args.worker_flops)
    if args.up_shift_gbps is not None:
        if args.up_shift_gbps <= 0:
            raise SystemExit(f"--up-shift-gbps must be positive, got "
                             f"{args.up_shift_gbps}")
        factor = args.up_gbps / args.up_shift_gbps
        topo = uplink_degradation(base, factor=factor,
                                  at_epoch=args.shift_epoch)
        drift = (f"uplinks {args.up_gbps} -> {args.up_shift_gbps} Gbps at "
                 f"epoch {args.shift_epoch}")
    else:
        topo, drift = base, "static topology"
    if args.staleness is not None:
        _run_dynamic_ps_async(args, cfg, topo, opt, pipe, shape, drift)
        return
    dyn = DynamicPSTrainer(cfg=cfg, mesh=mesh, optimizer=opt, topology=topo,
                           steps_per_epoch=args.steps_per_epoch,
                           input_shape=shape, strategy=args.strategy)
    print(f"[dynamic-ps] {args.ps_servers} shards x {n_dev} workers; "
          f"{drift}; {args.strategy}, re-plan every "
          f"{args.steps_per_epoch} steps")
    state = dyn.init_state(jax.random.PRNGKey(0))
    state, _ = dyn.run(state, pipe.batch, args.steps, log_every=10)
    for e in dyn.events:
        ag, rs = dyn.hlo_counts(e.plan)
        print(f"epoch {e.epoch:3d} step {e.step:4d}: "
              f"{len(e.plan.forward)} pull / {len(e.plan.backward)} push "
              f"segments (hlo {ag} ag / {rs} rs)  "
              f"{'re-segmented' if e.plan_changed else 'unchanged'}"
              f"{' [cache hit]' if e.plan_changed and not e.retraced else ''}"
              f"  sched {e.scheduling_seconds * 1e3:.2f} ms "
              f"hidden={e.overhead_hidden}")
    print(f"[dynamic-ps] traces {dyn.traces}, cache hits {dyn.cache_hits}")


def _run_dynamic_ps_async(args, cfg, topo, opt, pipe, shape, drift) -> None:
    """Asynchronous dynamic-PS: per-worker re-plan per topology epoch,
    bounded staleness k with the selected throttle; one epoch spans
    ``--steps-per-epoch`` accepted pushes, ``--steps`` pushes total."""
    from repro.models import (init_params, params_from_sched_layers,
                              sched_layer_trees, train_loss)
    from repro.models.profiles import layer_profiles
    from repro.ps import DynamicAsyncPSTrainer

    layers = sched_layer_trees(init_params(cfg, jax.random.PRNGKey(0)))

    def loss_fn(layer_list, batch):
        return train_loss(cfg, params_from_sched_layers(layer_list), batch,
                          aux_weight=0.01)

    dyn = DynamicAsyncPSTrainer(
        init_layers=layers, loss_fn=loss_fn, optimizer=opt, topology=topo,
        pushes_per_epoch=args.steps_per_epoch, staleness=args.staleness,
        throttle=args.throttle, strategy=args.strategy,
        profiles=layer_profiles(cfg, shape))
    print(f"[dynamic-ps] async: {dyn.topology.topology_at(0).num_servers} "
          f"shards x {dyn.topology.num_workers} logical workers; {drift}; "
          f"k={args.staleness} ({args.throttle} throttle), "
          f"{args.strategy}, re-plan every {args.steps_per_epoch} of "
          f"{args.steps} pushes")
    log = dyn.run_pushes(args.steps, lambda w, i: pipe.batch(w * 100003 + i))
    for e in dyn.events:
        segs = [(len(p.forward), len(p.backward)) for p in e.worker_plans]
        print(f"epoch {e.epoch:3d} @push {e.at_push:4d}: per-worker "
              f"pull/push segments {segs}  "
              f"{'re-segmented' if e.plan_changed else 'unchanged'}  "
              f"sched {e.scheduling_seconds * 1e3:.2f} ms "
              f"hidden={e.overhead_hidden}")
    print(f"[dynamic-ps] {len(log.accepted)} pushes accepted, "
          f"{log.num_rejected} rejected, {log.total_wait_s:.4f}s waited "
          f"at the SSP barrier, max staleness {log.max_staleness} <= k, "
          f"simulated makespan {log.makespan:.4f}s")


def _run_ps(args, cfg, mesh, opt, pipe, shape) -> None:
    """The parameter-server runtime: sync on the mesh, or async with a
    bounded staleness k (host-level event loop over logical workers)."""
    from repro.core import decision_from_plan
    from repro.core.viz import render_ps_timeline
    from repro.ps import AsyncPSTrainer, PSTopology, PSTrainer

    n_dev = len(jax.devices())
    if args.staleness is None:
        topo = PSTopology.uniform(args.ps_servers, n_dev,
                                  down_bps=args.down_gbps * 1e9,
                                  up_bps=args.up_gbps * 1e9,
                                  flops=args.worker_flops)
        tr = PSTrainer.from_topology(cfg, mesh, topo, opt, shape,
                                     strategy=args.strategy)
        pulls, pushes = tr.expected_transfers
        tb = tr.transfer_bytes()
        print(f"[ps] sync: {topo.num_servers} shards x {topo.num_workers} "
              f"workers; {args.strategy}: {pulls} pull / {pushes} push "
              f"segments ({tb['pull'] / 1e6:.1f} MB down, "
              f"{tb['push'] / 1e6:.1f} MB up per iter)")
        print(render_ps_timeline(tr.topology_costs(shape),
                                 decision_from_plan(tr.plan)))
        state = tr.init_state(jax.random.PRNGKey(0))
        step = jax.jit(tr.build_train_step())
        t0 = time.perf_counter()
        for i in range(args.steps):
            state, loss = step(state, pipe.batch(i))
            if (i + 1) % 10 == 0:
                print(f"step {i + 1:4d}  loss {float(loss):.4f}  "
                      f"{(time.perf_counter() - t0) / (i + 1):.3f}s/step")
        return

    # async: logical workers against the versioned server
    from repro.core import plan_from_decision, schedule
    from repro.models import (init_params, num_sched_layers,
                              params_from_sched_layers, sched_layer_trees,
                              train_loss)
    W = args.ps_workers or n_dev
    topo = PSTopology.uniform(args.ps_servers, W,
                              down_bps=args.down_gbps * 1e9,
                              up_bps=args.up_gbps * 1e9,
                              flops=args.worker_flops)
    from repro.models.profiles import layer_profiles
    costs = topo.topology_costs(layer_profiles(cfg, shape))
    from repro.core.scheduler import consensus_decision
    decision, makespan = consensus_decision(costs, args.strategy)
    plan = plan_from_decision(*decision, num_sched_layers(cfg))
    layers = sched_layer_trees(init_params(cfg, jax.random.PRNGKey(0)))

    def loss_fn(layer_list, batch):
        return train_loss(cfg, params_from_sched_layers(layer_list), batch,
                          aux_weight=0.01)

    tr = AsyncPSTrainer(init_layers=layers, loss_fn=loss_fn, optimizer=opt,
                        topology=topo, plan=plan,
                        staleness=args.staleness, throttle=args.throttle,
                        costs=costs)
    print(f"[ps] async: {topo.num_servers} shards x {W} logical workers, "
          f"staleness bound k={args.staleness} ({args.throttle} throttle); "
          f"{args.strategy}: "
          f"{len(plan.forward)} pull / {len(plan.backward)} push segments "
          f"(sync makespan would be {makespan:.4f}s)")
    log = tr.run(args.steps, lambda w, i: pipe.batch(w * 100003 + i))
    acc = log.accepted
    print(f"[ps] {len(acc)} pushes accepted, {log.num_rejected} rejected "
          f"(stale), {log.total_wait_s:.4f}s waited at the SSP barrier, "
          f"max staleness {log.max_staleness} <= k, simulated "
          f"makespan {log.makespan:.4f}s")
    for e in acc[:: max(1, len(acc) // 10)]:
        print(f"  t={e.sim_time:8.4f}s worker {e.worker} v{e.version:3d} "
              f"staleness {e.result.staleness}  loss {e.loss:.4f}")


if __name__ == "__main__":
    main()
