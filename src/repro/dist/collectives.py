"""Flat-buffer collectives: one ring collective per DynaComm segment.

A *sched layer*'s parameter pytree is packed into a single padded 1-D
float32 buffer (``FlatSpec`` records the layout), so that a DynaComm
transmission segment — a contiguous group of sched layers — becomes exactly
one ``all-gather`` (the paper's parameter *pull*) or one ``reduce-scatter``
(the gradient *push*) on the data axis, no matter how many tensors the
segment contains.

Layout convention: every per-layer buffer is padded to a multiple of the
data-axis size, stored sharded as ``(padded // axis,)`` per device.  To pull
a bucket, the per-layer shards are concatenated and all-gathered once; row
``i`` of the gathered ``(axis, S)`` result is device ``i``'s slice, so each
layer's full buffer is recovered by slicing columns and flattening rows.
The push is the exact transpose: per-layer full gradients are reshaped to
``(axis, padded // axis)``, concatenated along columns, and reduce-scattered
once along rows.

``gather_bucket`` / ``reduce_scatter_bucket`` must run inside ``shard_map``
(they issue ``jax.lax`` collectives over a named axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

FLAT_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Layout of one sched layer's pytree inside its padded flat buffer."""

    treedef: Any                              # pytree structure
    shapes: Tuple[Tuple[int, ...], ...]       # per-leaf shapes
    dtypes: Tuple[Any, ...]                   # per-leaf dtypes (restored)
    offsets: Tuple[int, ...]                  # per-leaf start offset
    sizes: Tuple[int, ...]                    # per-leaf element count
    total: int                                # sum of sizes
    padded: int                               # total rounded up to axis_size
    axis_size: int

    @property
    def num_leaves(self) -> int:
        return len(self.sizes)

    @property
    def shard_size(self) -> int:
        return self.padded // self.axis_size


def make_flat_spec(tree: Any, axis_size: int) -> FlatSpec:
    """Compute the flat layout for ``tree`` sharded ``axis_size`` ways.

    Works on concrete arrays and on ``ShapeDtypeStruct`` trees (only
    ``.shape`` / ``.dtype`` are read).
    """
    if axis_size < 1:
        raise ValueError(f"axis_size must be >= 1, got {axis_size}")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot build a FlatSpec for an empty pytree")
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        n = 1
        for d in leaf.shape:
            n *= int(d)
        shapes.append(tuple(int(d) for d in leaf.shape))
        dtypes.append(jnp.dtype(leaf.dtype))
        offsets.append(off)
        sizes.append(n)
        off += n
    padded = max(-(-off // axis_size), 1) * axis_size
    return FlatSpec(treedef=treedef, shapes=tuple(shapes), dtypes=tuple(dtypes),
                    offsets=tuple(offsets), sizes=tuple(sizes), total=off,
                    padded=padded, axis_size=axis_size)


def flatten_tree(tree: Any, spec: FlatSpec) -> jnp.ndarray:
    """Pack ``tree`` into its ``(spec.padded,)`` float32 buffer (zero pad)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if len(leaves) != spec.num_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{spec.num_leaves}")
    parts: List[jnp.ndarray] = [
        jnp.ravel(x).astype(FLAT_DTYPE) for x in leaves]
    pad = spec.padded - spec.total
    if pad:
        parts.append(jnp.zeros((pad,), FLAT_DTYPE))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def unflatten_tree(flat: jnp.ndarray, spec: FlatSpec) -> Any:
    """Inverse of :func:`flatten_tree` — restores leaf shapes *and dtypes*."""
    if flat.shape != (spec.padded,):
        raise ValueError(f"flat buffer shape {flat.shape} != ({spec.padded},)")
    leaves = [
        flat[o:o + n].reshape(shape).astype(dtype)
        for o, n, shape, dtype in zip(spec.offsets, spec.sizes, spec.shapes,
                                      spec.dtypes)
    ]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def bucket_bytes(specs: Sequence[FlatSpec], bucket: Sequence[int]) -> int:
    """Unpadded payload bytes of one segment's transmission (f32 flats)."""
    return sum(specs[l].total * jnp.dtype(FLAT_DTYPE).itemsize
               for l in bucket)


# ---------------------------------------------------------------------------
# Bucket collectives (shard_map-internal)
# ---------------------------------------------------------------------------


def _check_bucket(specs: Sequence[FlatSpec], bucket: Sequence[int],
                  op: str) -> None:
    """A bucket must be non-empty, name known layers, and share one
    ``axis_size`` across its specs (one collective ⇒ one shard layout)."""
    if not bucket:
        raise ValueError(f"{op}: empty bucket (a DynaComm segment contains "
                         f"at least one layer)")
    bad = [l for l in bucket if not 0 <= l < len(specs)]
    if bad:
        raise ValueError(f"{op}: bucket {tuple(bucket)} names unknown layers "
                         f"{bad} (have specs for 0..{len(specs) - 1})")
    sizes = {specs[l].axis_size for l in bucket}
    if len(sizes) != 1:
        raise ValueError(f"{op}: bucket {tuple(bucket)} mixes axis sizes "
                         f"{sorted(sizes)}; all specs in a bucket must be "
                         f"sharded over the same axis")


def gather_bucket(shards: Sequence[jnp.ndarray], specs: Sequence[FlatSpec],
                  bucket: Sequence[int], axis_name: str) -> Dict[int, Any]:
    """Pull one bucket with a single ``all-gather``.

    ``shards[l]`` is layer ``l``'s local ``(padded_l // axis,)`` slice.
    Returns ``{layer_id: full parameter pytree}`` for every layer in
    ``bucket``.
    """
    _check_bucket(specs, bucket, "gather_bucket")
    cols = [shards[l] for l in bucket]
    concat = cols[0] if len(cols) == 1 else jnp.concatenate(cols)
    gathered = jax.lax.all_gather(concat, axis_name)      # (axis, sum shards)
    out: Dict[int, Any] = {}
    off = 0
    for l in bucket:
        w = specs[l].shard_size
        full = gathered[:, off:off + w].reshape(-1)        # (padded_l,)
        out[l] = unflatten_tree(full, specs[l])
        off += w
    return out


def reduce_scatter_bucket(grads: Dict[int, Any], specs: Sequence[FlatSpec],
                          bucket: Sequence[int], axis_name: str
                          ) -> Dict[int, jnp.ndarray]:
    """Push one bucket with a single ``reduce-scatter``.

    ``grads[l]`` is the *full* (per-device) gradient pytree of layer ``l``;
    the result maps each layer to this device's summed ``(padded_l // axis,)``
    gradient shard (caller divides by the axis size for the mean).
    """
    _check_bucket(specs, bucket, "reduce_scatter_bucket")
    axis_size = specs[bucket[0]].axis_size
    rows = [flatten_tree(grads[l], specs[l]).reshape(axis_size, -1)
            for l in bucket]
    concat = rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=1)
    summed = jax.lax.psum_scatter(concat, axis_name, scatter_dimension=0,
                                  tiled=True)              # (1, sum shards)
    flat = summed.reshape(-1)
    out: Dict[int, jnp.ndarray] = {}
    off = 0
    for l in bucket:
        w = specs[l].shard_size
        out[l] = flat[off:off + w]
        off += w
    return out


def compressed_reduce_scatter_bucket(
        grads: Dict[int, Any], specs: Sequence[FlatSpec],
        bucket: Sequence[int], axis_name: str, compressor: Any,
        residuals: Dict[int, jnp.ndarray] | None = None,
        ) -> Tuple[Dict[int, jnp.ndarray], Dict[int, jnp.ndarray] | None]:
    """Push one bucket with each device's contribution compressed first.

    Models the PS wire: every worker quantizes/sparsifies its *own* flat
    gradient before pushing, the server sums the decompressed payloads —
    so the reduce-scatter operand is ``compressor.roundtrip`` of each
    local flat buffer.  With ``residuals`` (per-layer ``(padded_l,)``
    local buffers), the compression error of this push is carried into
    the next one (error feedback); returns ``(shards, new_residuals)``
    where ``new_residuals`` is ``None`` iff no residuals were given.
    """
    _check_bucket(specs, bucket, "compressed_reduce_scatter_bucket")
    axis_size = specs[bucket[0]].axis_size
    rows, new_residuals = [], None if residuals is None else {}
    for l in bucket:
        flat = flatten_tree(grads[l], specs[l])
        if residuals is None:
            flat = compressor.roundtrip(flat)
        else:
            flat, new_residuals[l] = compressor.feedback_roundtrip(
                flat, residuals[l])
        rows.append(flat.reshape(axis_size, -1))
    concat = rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=1)
    summed = jax.lax.psum_scatter(concat, axis_name, scatter_dimension=0,
                                  tiled=True)
    flat = summed.reshape(-1)
    out: Dict[int, jnp.ndarray] = {}
    off = 0
    for l in bucket:
        w = specs[l].shard_size
        out[l] = flat[off:off + w]
        off += w
    return out, new_residuals
