"""Run-time re-planning machinery shared by every dynamic trainer.

PR 2 grew a compiled-step cache and reschedule-event bookkeeping inside
``repro.dist.dynamic``; PR 4 duplicated the pattern for the PS regime in
``repro.ps.dynamic``.  This module is the single home for that machinery:

* :class:`PlanStepCache` — ``BucketPlan``-keyed AOT compiled-step cache:
  each distinct plan is traced and compiled exactly once
  (``.lower().compile()``), revisits are dictionary lookups, and per-plan
  HLO collective counts are kept for the structural assertions;
* :class:`RescheduleEvent` — one scheduling pass (paper Table I
  bookkeeping: scheduling wall time + the overhead-hidden check against
  the Δt + gt¹ idle window);
* :class:`ReplanMixin` — the swap-and-record loop body both drivers
  share: activate a plan (compiling on a miss, counting cache hits only
  for genuine plan swaps), and record the ``RescheduleEvent`` for a
  scheduling pass, including the Table I idle-window check delegated to
  the scheduler;
* plan/event (de)serialization helpers used by the loop-state
  checkpointing of both drivers.

``repro.dist.dynamic`` keeps deprecation shims for the old import paths.
"""

from __future__ import annotations

import dataclasses
import json
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.hlo import collective_counts as _collective_counts
from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.core.buckets import BucketPlan


def hlo_collective_counts(hlo_text: str) -> Tuple[int, int]:
    """(#all-gathers, #reduce-scatters) in a compiled HLO dump."""
    counts = _collective_counts(hlo_text)
    return counts["all-gather"], counts["reduce-scatter"]


def sequential_plan(num_layers: int) -> BucketPlan:
    """The whole model as one pull and one push bucket (always valid)."""
    return BucketPlan(forward=(tuple(range(num_layers)),),
                      backward=(tuple(range(num_layers - 1, -1, -1)),))


@dataclasses.dataclass(frozen=True)
class RescheduleEvent:
    """One scheduling pass (paper Table I bookkeeping)."""

    step: int                     # global step index at the epoch boundary
    epoch: int
    plan: BucketPlan              # plan active after this pass
    plan_changed: bool            # decision differed from the previous epoch
    retraced: bool                # False ⇒ compiled-step cache hit (or no swap)
    scheduling_seconds: float     # wall time of the DP re-plan
    overhead_hidden: bool         # fits in the Δt + gt¹ idle window (Table I)
    trigger: str = "epoch"        # "epoch" boundary | "drift" detector


#: compiled HLO dumps retained per cache — comfortably above the plan
#: count any smoke config or ``repro.analysis verify`` pass touches, so
#: every live plan stays auditable, while fleet churn (per-worker plans
#: multiplying across membership changes) can no longer grow text dumps
#: without bound.  Compiled steps and collective *counts* are small and
#: stay unbounded: evicting a step would force a retrace.
DEFAULT_HLO_RETENTION = 16


class PlanStepCache:
    """``BucketPlan``-keyed AOT compiled-step cache (see module docstring).

    ``hlo_retention`` bounds how many full HLO text dumps are kept
    (keep-last-N by compile order); ``hlo_evictions`` counts dumps
    dropped over the bound."""

    def __init__(self, *, hlo_retention: int = DEFAULT_HLO_RETENTION):
        if hlo_retention < 1:
            raise ValueError(
                f"hlo_retention must be >= 1, got {hlo_retention}")
        self._steps: Dict[BucketPlan, Callable] = {}
        self._hlo: Dict[BucketPlan, Tuple[int, int]] = {}
        self._hlo_text: "OrderedDict[BucketPlan, str]" = OrderedDict()
        self.hlo_retention = hlo_retention
        self.hlo_evictions = 0         # HLO dumps dropped over the bound
        self.traces = 0                # compile-cache misses
        self.hits = 0                  # plan *swaps* served from the cache

    @property
    def plans(self) -> Tuple[BucketPlan, ...]:
        return tuple(self._steps)

    def hlo_counts(self, plan: BucketPlan) -> Tuple[int, int]:
        """(#all-gathers, #reduce-scatters) of a cached plan's step."""
        if plan not in self._hlo:
            raise KeyError(f"plan {plan} has no compiled step yet")
        return self._hlo[plan]

    def hlo_text(self, plan: BucketPlan) -> str:
        """The compiled HLO dump of a cached plan's step (kept so the
        conformance pass can audit every plan without recompiling;
        only the last ``hlo_retention`` compiles are retained)."""
        if plan not in self._hlo_text:
            raise KeyError(f"plan {plan} has no retained HLO dump "
                           f"(never compiled, or evicted past the "
                           f"keep-last-{self.hlo_retention} bound)")
        return self._hlo_text[plan]

    def step_for(self, plan: BucketPlan, build_step: Callable[[], Callable],
                 state, batch, *, count_hit: bool) -> Tuple[Callable, bool]:
        """The compiled step for ``plan``, compiling via ``build_step()``
        on a miss.  Returns ``(step_fn, retraced)``; ``count_hit`` tells
        whether a cache hit is an actual plan swap (a post-restore
        recompile of the unchanged plan is not)."""
        if plan in self._steps:
            if count_hit:
                self.hits += 1
            return self._steps[plan], False
        self.traces += 1
        compiled = jax.jit(build_step()).lower(state, batch).compile()
        text = compiled.as_text()
        self._hlo[plan] = hlo_collective_counts(text)
        self._hlo_text[plan] = text
        while len(self._hlo_text) > self.hlo_retention:
            self._hlo_text.popitem(last=False)
            self.hlo_evictions += 1
        self._steps[plan] = compiled
        return compiled, True


class ReplanMixin:
    """Shared plan-swap + event-record body of the dynamic drivers.

    A driver calls :meth:`_init_replan` from its ``__post_init__``, then
    per scheduling pass :meth:`_activate_plan` (compile-or-lookup, swap)
    and :meth:`_record_reschedule` (``RescheduleEvent`` with the paper's
    Table I ``scheduling_overhead_hidden`` check — the scheduler compares
    its last DP wall time against the costs' Δt + gt¹ idle window).
    """

    def _init_replan(self, *, hlo_retention: int = DEFAULT_HLO_RETENTION
                     ) -> None:
        self.events: List[RescheduleEvent] = []
        self._cache = PlanStepCache(hlo_retention=hlo_retention)
        self._plan: Optional[BucketPlan] = None
        self._step_fn: Optional[Callable] = None

    # -- introspection (uniform across drivers) -------------------------

    @property
    def plan(self) -> Optional[BucketPlan]:
        """The currently active bucket plan (None before the first step)."""
        return self._plan

    @property
    def plans_seen(self) -> Tuple[BucketPlan, ...]:
        return self._cache.plans

    @property
    def traces(self) -> int:
        """Compiled-step cache misses (one trace per distinct plan)."""
        return self._cache.traces

    @property
    def cache_hits(self) -> int:
        """Plan swaps served from the compiled-step cache."""
        return self._cache.hits

    @property
    def hlo_evictions(self) -> int:
        """HLO text dumps dropped past the keep-last-N retention bound."""
        return self._cache.hlo_evictions

    def hlo_counts(self, plan: Optional[BucketPlan] = None) -> Tuple[int, int]:
        """(#all-gathers, #reduce-scatters) of a cached plan's compiled
        step."""
        return self._cache.hlo_counts(self._plan if plan is None else plan)

    # -- the shared loop body -------------------------------------------

    def _activate_plan(self, plan: BucketPlan,
                       build_step: Callable[[], Callable],
                       state, batch) -> Tuple[Optional[BucketPlan], bool]:
        """Make ``plan`` the active compiled step if it differs from the
        current one (or none is compiled yet).  Returns
        ``(previous_plan, retraced)``."""
        prev = self._plan
        retraced = False
        if plan != prev or self._step_fn is None:
            self._step_fn, retraced = self._cache.step_for(
                plan, build_step, state, batch, count_hit=plan != prev)
            self._plan = plan
        return prev, retraced

    def _record_reschedule(self, *, step: int, epoch: int, plan: BucketPlan,
                           prev: Optional[BucketPlan], retraced: bool,
                           scheduler, costs, trigger: str = "epoch") -> None:
        """Append the ``RescheduleEvent`` for one scheduling pass."""
        self.events.append(RescheduleEvent(
            step=step, epoch=epoch, plan=plan,
            plan_changed=prev is not None and plan != prev,
            retraced=retraced,
            scheduling_seconds=scheduler.last_scheduling_seconds,
            overhead_hidden=scheduler.scheduling_overhead_hidden(costs),
            trigger=trigger))

    # -- (de)serialization for loop-state checkpointing -----------------

    @staticmethod
    def _plan_to_obj(plan: Optional[BucketPlan]):
        if plan is None:
            return None
        return {"forward": [list(b) for b in plan.forward],
                "backward": [list(b) for b in plan.backward]}

    @staticmethod
    def _plan_from_obj(obj) -> Optional[BucketPlan]:
        if obj is None:
            return None
        return BucketPlan(
            forward=tuple(tuple(b) for b in obj["forward"]),
            backward=tuple(tuple(b) for b in obj["backward"]))

    @classmethod
    def _events_to_obj(cls, events) -> List[Dict[str, Any]]:
        return [{
            "step": e.step, "epoch": e.epoch,
            "plan": cls._plan_to_obj(e.plan),
            "plan_changed": e.plan_changed, "retraced": e.retraced,
            "scheduling_seconds": e.scheduling_seconds,
            "overhead_hidden": e.overhead_hidden, "trigger": e.trigger,
        } for e in events]

    @classmethod
    def _events_from_obj(cls, obj) -> List[RescheduleEvent]:
        return [RescheduleEvent(
            step=e["step"], epoch=e["epoch"],
            plan=cls._plan_from_obj(e["plan"]),
            plan_changed=e["plan_changed"], retraced=e["retraced"],
            scheduling_seconds=e["scheduling_seconds"],
            overhead_hidden=e["overhead_hidden"],
            trigger=e.get("trigger", "epoch")) for e in obj]

    # -- loop-state checkpointing (shared by both dynamic drivers) ------
    #
    # The *model* state is an ordinary pytree checkpointed separately;
    # this captures the re-planning bookkeeping — step/scheduler
    # counters, active plan, event history, measurement cache — so a
    # resumed run replays the same plan sequence.  Compiled steps are not
    # serializable: the restored plan recompiles lazily on the first
    # post-restore step (no scheduling event is recorded).  Drivers
    # expect the shared attribute set (scheduler, _step_idx, cost_source,
    # _measured_fc_bc, _measured_epoch, base.num_layers) and add their
    # extras through ``extra_meta`` / the returned meta dict.

    def loop_state(self, *, extra_meta: Optional[Dict[str, Any]] = None
                   ) -> Dict[str, np.ndarray]:
        """The re-planning loop bookkeeping as a checkpointable pytree."""
        meta = {
            "scheduler": self.scheduler.state_dict(),
            "plan": self._plan_to_obj(self._plan),
            "events": self._events_to_obj(self.events),
            "measured_epoch": self._measured_epoch,
        }
        planner = getattr(self, "planner", None)
        if planner is not None:
            meta["planner"] = planner.state_dict()
        if extra_meta:
            meta.update(extra_meta)
        state = {"step_idx": np.asarray(self._step_idx, np.int64),
                 "meta": np.asarray(json.dumps(meta))}
        if self._measured_fc_bc is not None:
            fc, bc = self._measured_fc_bc
            state["measured_fc"] = np.asarray(fc, np.float64)
            state["measured_bc"] = np.asarray(bc, np.float64)
        return state

    def save_loop_state(self, path: str) -> None:
        save_checkpoint(path, self.loop_state(), step=self._step_idx)

    def _restore_loop_common(self, path: str) -> Dict[str, Any]:
        """Restore the shared loop state; returns the meta dict so the
        driver can pick up its extras."""
        Ls = self.base.num_layers
        template: Dict[str, np.ndarray] = {
            "step_idx": np.zeros((), np.int64), "meta": np.asarray("")}
        if self.cost_source == "measured":
            with np.load(path) as probe:
                has_measured = "measured_fc" in probe.files
            if has_measured:       # absent ⇒ saved before 1st measurement
                template["measured_fc"] = np.zeros((Ls,), np.float64)
                template["measured_bc"] = np.zeros((Ls,), np.float64)
        tree, _ = load_checkpoint(path, template)
        meta = json.loads(str(tree["meta"]))
        self._step_idx = int(tree["step_idx"])
        self.scheduler.load_state_dict(dict(meta["scheduler"]))
        self._plan = self._plan_from_obj(meta["plan"])
        self._measured_epoch = int(meta.get("measured_epoch", -1))
        if "measured_fc" in tree:
            self._measured_fc_bc = (np.asarray(tree["measured_fc"]),
                                    np.asarray(tree["measured_bc"]))
        self.events = self._events_from_obj(meta["events"])
        planner = getattr(self, "planner", None)
        if planner is not None and meta.get("planner") is not None:
            planner.load_state_dict(meta["planner"])
        self._step_fn = None       # recompiled lazily on the next step
        self._costs = None
        return meta
