"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel directory ships three files:

* ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
  VMEM tiling (TPU is the TARGET; this container validates via
  ``interpret=True``);
* ``ops.py`` — the jit'd public wrapper (custom_vjp where training uses it);
* ``ref.py`` — the pure-jnp oracle the tests sweep against.

Kernels:

* ``bucket_pack`` — DynaComm transmissions move *buckets* of heterogeneous
  layer tensors; fusing them into one contiguous buffer before the
  collective (and scattering back after) is the per-mini-procedure data
  movement.  Tiled HBM→VMEM copies with scalar-prefetched offsets.
* ``compress`` — gradient compression fused into the same pass:
  ``quantize_pack``/``dequantize_unpack`` (int8 + per-TILE scales) and the
  ``sparsify``/``densify`` magnitude top-k gather/scatter pair backing
  ``repro.compress``.
* ``flash_attention`` — blockwise causal attention with sliding-window and
  logit-softcap support (gemma2/gemma3), online softmax in VMEM.
* ``rglru_scan`` — the RG-LRU linear recurrence, vectorized over channels
  (lanes), sequential over time blocks with a VMEM-resident carry.
"""
