"""Subprocess helper: multi-device checks for the DynaComm ZeRO trainer.

Run with 4 forged host devices (XLA_FLAGS set by the parent test).  Prints
one JSON line the parent asserts on.  Checks:

1. collective structure — #all-gathers == |D_f| buckets and
   #reduce-scatters == |D_b| buckets in the compiled HLO, per strategy;
2. "accuracy untouched" (paper Fig. 10, strengthened): losses are
   bit-identical across sequential / LBL / iBatch / DynaComm schedules;
3. ZeRO trainer vs single-device reference: same losses to fp32 roundoff.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.analysis import collective_counts
from repro.configs import get_config
from repro.core import plan_from_decision, random_costs, schedule
from repro.dist.zero import ZeroTrainer
from repro.models import init_params, num_sched_layers, train_loss
from repro.optim import adamw


def main():
    cfg = get_config("granite-3-2b").reduced()
    Ls = num_sched_layers(cfg)
    mesh = Mesh(np.array(jax.devices()).reshape(4,), ("data",))
    B, T = 8, 32
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    out = {"strategies": {}}
    costs = random_costs(Ls, seed=0, dt=1e-3)
    for strat in ("sequential", "lbl", "ibatch", "dynacomm"):
        f, b = schedule(costs, strat)
        plan = plan_from_decision(f, b, Ls)
        tr = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=adamw(1e-3))
        state = tr.init_state(jax.random.PRNGKey(0))
        step = jax.jit(tr.build_train_step())
        hlo = step.lower(state, batch).compile().as_text()
        counts = collective_counts(hlo)
        n_ag, n_rs = counts["all-gather"], counts["reduce-scatter"]
        losses = []
        for _ in range(3):
            state, loss = step(state, batch)
            losses.append(float(loss))
        out["strategies"][strat] = {
            "fwd_buckets": len(plan.forward), "ag": n_ag,
            "bwd_buckets": len(plan.backward), "rs": n_rs,
            "losses": losses,
        }

    # ZeRO-3 re-gather mode: one extra pull per mid-layer backward bucket,
    # bit-identical losses
    f, b = schedule(costs, "dynacomm")
    plan = plan_from_decision(f, b, Ls)
    tr3 = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=adamw(1e-3),
                      zero3=True)
    state3 = tr3.init_state(jax.random.PRNGKey(0))
    step3 = jax.jit(tr3.build_train_step())
    hlo3 = step3.lower(state3, batch).compile().as_text()
    losses3 = []
    for _ in range(3):
        state3, loss = step3(state3, batch)
        losses3.append(float(loss))
    mid_buckets = sum(1 for bk in plan.backward
                      if any(0 < l < Ls - 1 for l in bk))
    out["zero3"] = {
        "losses": losses3,
        "ag": collective_counts(hlo3)["all-gather"],
        "expected_ag": len(plan.forward) + mid_buckets,
    }

    # single-device reference
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    ostate = opt.init(params)

    @jax.jit
    def ref_step(params, ostate, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch, aux_weight=0.01))(params)
        params, ostate = opt.update(grads, ostate, params)
        return params, ostate, loss

    ref_losses = []
    for _ in range(3):
        params, ostate, loss = ref_step(params, ostate, batch)
        ref_losses.append(float(loss))
    out["reference_losses"] = ref_losses
    print(json.dumps(out))


if __name__ == "__main__":
    main()
