"""``python -m repro.analysis`` — lint and verify subcommands.

``lint`` walks source trees with the AST lints (jax never imported);
``verify`` builds a runtime from a ``RuntimeConfig`` JSON and runs the
HLO schedule-conformance passes.  Both print the human rendering, write
the findings JSON with ``--json``, and exit non-zero iff any
error-severity finding was produced — which is what gates CI.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis.findings import (ERROR, Finding, findings_to_json,
                                     render_findings)


def _write_json(path: str, findings: List[Finding], **extra) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write(findings_to_json(findings, **extra))
        f.write("\n")


def _exit_code(findings: List[Finding]) -> int:
    return 1 if any(f.severity == ERROR for f in findings) else 0


def _run_lint(args: argparse.Namespace) -> int:
    from repro.analysis.lints import lint_paths
    findings = lint_paths(args.paths)
    print(render_findings(
        findings,
        header=f"lint over {', '.join(args.paths)}: "
               f"{len(findings)} finding(s)"))
    if args.json_path:
        _write_json(args.json_path, findings, command="lint",
                    paths=list(args.paths))
    return _exit_code(findings)


def _run_verify(args: argparse.Namespace) -> int:
    # forge host devices BEFORE anything imports jax: the smoke configs
    # need a real data axis (axis_size 1 lets XLA elide every collective,
    # which would verify nothing)
    if args.devices and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    from repro.analysis.runtime_verify import verify_runtime
    from repro.runtime.config import RuntimeConfig
    config = RuntimeConfig.load(args.config)
    findings, info = verify_runtime(config, steps=args.steps)
    print(render_findings(
        findings,
        header=f"verify {args.config} [{config.runtime}]: "
               f"{len(findings)} finding(s)"))
    if args.json_path:
        _write_json(args.json_path, findings, command="verify",
                    config=args.config, info=info)
    return _exit_code(findings)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis: determinism lints + HLO "
                    "schedule-conformance verification")
    sub = parser.add_subparsers(dest="command", required=True)

    lint_p = sub.add_parser(
        "lint", help="run the AST determinism lints over files/trees")
    lint_p.add_argument("paths", nargs="+",
                        help="python files or directory trees")
    lint_p.add_argument("--json", dest="json_path", default=None,
                        help="also write the findings JSON here")

    verify_p = sub.add_parser(
        "verify", help="build a runtime and verify its compiled "
                       "schedule against the plan")
    verify_p.add_argument("--config", required=True,
                          help="RuntimeConfig JSON "
                               "(examples/runtime_configs/*.json)")
    verify_p.add_argument("--steps", type=int, default=None,
                          help="units of progress to run where needed "
                               "(default: regime-appropriate minimum)")
    verify_p.add_argument("--devices", type=int, default=2,
                          help="forged host device count (default 2; 0 "
                               "= leave XLA_FLAGS alone)")
    verify_p.add_argument("--json", dest="json_path", default=None,
                          help="also write the findings JSON here")

    args = parser.parse_args(argv)
    if args.command == "lint":
        return _run_lint(args)
    return _run_verify(args)


if __name__ == "__main__":
    sys.exit(main())
