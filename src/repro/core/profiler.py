"""Real-time profiling module (paper Section IV-A).

Produces the cost vectors ``pt, fc, bc, gt`` and the overhead ``Δt`` that
feed the schedulers, from one of three sources:

* **analytic** — per-layer FLOP/byte counts (from the model zoo's
  ``layer_profiles()`` or from ``compiled.cost_analysis()`` in the dry-run)
  pushed through a hardware model (`EdgeNetworkModel` for the paper's
  testbed, `TPUSystemModel` for the adaptation target);
* **measured** — wall-clock timing of jitted per-layer forward/VJP callables
  (the CPU-runtime analogue of mxnet.profiler), median of repeated runs;
* **recorded** — literal cost vectors (used by the Fig. 12 complexity
  benchmark on randomly generated profiles, as in the paper).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from repro.core.costmodel import LayerCosts
from repro.core.netmodel import EdgeNetworkModel, TPUSystemModel


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """Static per-layer workload description."""

    name: str
    param_bytes: float
    flops_fwd: float
    flops_bwd: float | None = None     # default: 2x forward (dL/dx + dL/dw)
    grad_bytes: float | None = None    # default: == param_bytes

    @property
    def bwd(self) -> float:
        return 2.0 * self.flops_fwd if self.flops_bwd is None else self.flops_bwd

    @property
    def gbytes(self) -> float:
        return self.param_bytes if self.grad_bytes is None else self.grad_bytes


def costs_from_profiles(profiles: Sequence[LayerProfile],
                        *,
                        net: EdgeNetworkModel | TPUSystemModel,
                        compute_flops_per_s: float | None = None) -> LayerCosts:
    """Analytic cost vectors from layer workloads + a hardware model.

    ``compute_flops_per_s`` overrides the compute rate (needed for the edge
    regime, where `EdgeNetworkModel` has no compute side — the paper's Xeon
    workers); for `TPUSystemModel` it defaults to peak*mfu.
    """
    pbytes = np.array([p.param_bytes for p in profiles], dtype=np.float64)
    gbytes = np.array([p.gbytes for p in profiles], dtype=np.float64)
    f_fwd = np.array([p.flops_fwd for p in profiles], dtype=np.float64)
    f_bwd = np.array([p.bwd for p in profiles], dtype=np.float64)

    pt = net.transfer_time(pbytes)
    gt = net.transfer_time(gbytes)
    if compute_flops_per_s is not None:
        fc = f_fwd / compute_flops_per_s
        bc = f_bwd / compute_flops_per_s
    elif isinstance(net, TPUSystemModel):
        fc = net.compute_time(f_fwd)
        bc = net.compute_time(f_bwd)
    else:
        raise ValueError("edge regime requires compute_flops_per_s")
    return LayerCosts(pt=pt, fc=fc, bc=bc, gt=gt, dt=net.dt)


# ---------------------------------------------------------------------------
# Measured profiling (CPU runtime)
# ---------------------------------------------------------------------------


def _block(x):
    import jax
    jax.block_until_ready(x)
    return x


def time_callable(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds of ``fn(*args)`` (blocking on the result)."""
    for _ in range(warmup):
        _block(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        _block(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


def measure_layer_costs(fwd_fns: Sequence[Callable],
                        bwd_fns: Sequence[Callable],
                        fwd_args: Sequence[tuple],
                        bwd_args: Sequence[tuple],
                        *,
                        param_bytes: Sequence[float],
                        net: EdgeNetworkModel | TPUSystemModel,
                        iters: int = 5) -> LayerCosts:
    """Wall-clock fc/bc per layer; pt/gt analytic from bytes + network model.

    This mirrors the paper's deployment: compute costs are *profiled* on the
    worker, transmission costs follow the network condition.
    """
    fc = np.array([time_callable(f, *a, iters=iters)
                   for f, a in zip(fwd_fns, fwd_args)])
    bc = np.array([time_callable(f, *a, iters=iters)
                   for f, a in zip(bwd_fns, bwd_args)])
    pb = np.asarray(param_bytes, dtype=np.float64)
    return LayerCosts(pt=net.transfer_time(pb), fc=fc, bc=bc,
                      gt=net.transfer_time(pb), dt=net.dt)


class LayerTimingHook:
    """Per-(phase, layer) wall-clock accumulator for jitted per-layer applies.

    The run-time analogue of the paper's mxnet.profiler hook: the dynamic
    trainer wraps each sched layer's jitted forward / VJP callable with
    :meth:`timed`, every call records a blocking wall-clock sample, and
    :meth:`median` turns the samples into the ``fc`` / ``bc`` cost vectors
    (dropping the first ``warmup`` samples per key, which include compile
    time).  Phases are free-form strings; the trainer uses ``"fc"``/``"bc"``.
    """

    def __init__(self, warmup: int = 1):
        if warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {warmup}")
        self.warmup = warmup
        self._samples: dict[tuple[str, int], list[float]] = {}

    def record(self, phase: str, layer: int, seconds: float) -> None:
        self._samples.setdefault((phase, layer), []).append(float(seconds))

    def timed(self, phase: str, layer: int, fn: Callable) -> Callable:
        """Wrap ``fn`` so each call blocks on its result and records."""
        def wrapped(*args, **kwargs):
            t0 = time.perf_counter()
            out = _block(fn(*args, **kwargs))
            self.record(phase, layer, time.perf_counter() - t0)
            return out
        return wrapped

    def num_samples(self, phase: str, layer: int) -> int:
        return len(self._samples.get((phase, layer), ()))

    def median(self, phase: str, num_layers: int) -> np.ndarray:
        """Per-layer median seconds for ``phase`` over layers 0..L-1."""
        out = np.zeros(num_layers, dtype=np.float64)
        for l in range(num_layers):
            samples = self._samples.get((phase, l), [])[self.warmup:]
            if not samples:
                raise ValueError(
                    f"no post-warmup samples for phase {phase!r} layer {l} "
                    f"(have {self.num_samples(phase, l)}, warmup "
                    f"{self.warmup}); call each timed fn >= warmup+1 times")
            out[l] = float(np.median(samples))
        return out

    def costs(self, *, param_bytes: Sequence[float],
              net: EdgeNetworkModel | TPUSystemModel,
              grad_bytes: Sequence[float] | None = None) -> LayerCosts:
        """Assemble ``LayerCosts``: measured fc/bc + analytic pt/gt/Δt."""
        pb = np.asarray(param_bytes, dtype=np.float64)
        gb = pb if grad_bytes is None else np.asarray(grad_bytes, np.float64)
        L = pb.shape[0]
        return LayerCosts(pt=net.transfer_time(pb), fc=self.median("fc", L),
                          bc=self.median("bc", L), gt=net.transfer_time(gb),
                          dt=net.dt)

    def reset(self) -> None:
        self._samples.clear()


class EwmaDriftDetector:
    """Detect run-time drift from *observed* step times (no scripted
    ``NetworkSchedule`` needed).

    Keeps an exponentially-weighted moving average of per-step wall time;
    when ``patience`` consecutive samples deviate from the baseline by more
    than ``threshold`` (relative), :meth:`update` returns ``True`` once and
    the baseline re-seeds from the drifted sample — so a persistent shift
    (the uplink degraded, a worker slowed down) fires exactly one trigger,
    while one-off stragglers (GC pause, preemption blip) are absorbed.

    The first ``warmup`` samples only seed the baseline (they include
    compile time and cache-cold effects) and can never trigger.
    """

    def __init__(self, *, alpha: float = 0.2, threshold: float = 0.3,
                 patience: int = 3, warmup: int = 2):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if threshold <= 0.0:
            raise ValueError(f"threshold must be > 0, got {threshold}")
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        if warmup < 0:
            raise ValueError(f"warmup must be >= 0, got {warmup}")
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.warmup = warmup
        self.reset()

    @property
    def baseline(self) -> float | None:
        """Current EWMA of non-drifting step times (None before samples)."""
        return self._ewma

    @property
    def num_triggers(self) -> int:
        return self._triggers

    def update(self, seconds: float) -> bool:
        """Feed one observed step time; True ⇒ drift detected this step."""
        if seconds < 0:
            raise ValueError(f"step time must be >= 0, got {seconds}")
        self._seen += 1
        if self._seen <= self.warmup or self._ewma is None:
            # warmup seeds (and re-seeds after a reset) the baseline
            self._ewma = seconds if self._ewma is None else (
                self.alpha * seconds + (1 - self.alpha) * self._ewma)
            return False
        rel = abs(seconds - self._ewma) / max(self._ewma, 1e-12)
        if rel > self.threshold:
            self._streak += 1
            if self._streak >= self.patience:
                # persistent shift: trigger once, re-seed from the new regime
                self._ewma = seconds
                self._streak = 0
                self._triggers += 1
                return True
            return False                 # suspicious, but within patience
        self._streak = 0
        self._ewma = self.alpha * seconds + (1 - self.alpha) * self._ewma
        return False

    def state_dict(self) -> dict:
        """Checkpointable detector state (baseline, counters)."""
        return {"ewma": self._ewma, "seen": self._seen,
                "streak": self._streak, "triggers": self._triggers}

    def load_state_dict(self, state: dict) -> None:
        self._ewma = None if state["ewma"] is None else float(state["ewma"])
        self._seen = int(state["seen"])
        self._streak = int(state["streak"])
        self._triggers = int(state["triggers"])

    def reset(self) -> None:
        self._ewma: float | None = None
        self._seen = 0
        self._streak = 0
        self._triggers = 0


def random_costs(L: int, *, seed: int = 0, dt: float = 1e-2,
                 comm_scale: float = 1.0, comp_scale: float = 1.0) -> LayerCosts:
    """Randomly generated profiling results (paper Fig. 12 methodology)."""
    rng = np.random.default_rng(seed)
    return LayerCosts(
        pt=rng.uniform(0.1, 10.0, L) * 1e-3 * comm_scale,
        fc=rng.uniform(0.1, 10.0, L) * 1e-3 * comp_scale,
        bc=rng.uniform(0.2, 20.0, L) * 1e-3 * comp_scale,
        gt=rng.uniform(0.1, 10.0, L) * 1e-3 * comm_scale,
        dt=dt,
    )
