"""Pallas TPU kernel: blocked RG-LRU linear recurrence.

``h_t = a_t * h_{t-1} + x_t`` is sequential in t but embarrassingly parallel
over channels — the natural TPU mapping is channels on the 128-lane axis and
time streamed through VMEM in blocks:

* grid ``(B, W/bw, T/bt)`` with the time axis innermost and sequential
  ("arbitrary"); the carry h lives in a VMEM scratch vector per (batch,
  channel-tile) program family;
* each step loads an (bt, bw) tile of a and x, runs the recurrence over the
  tile's bt rows with an in-kernel ``fori_loop`` (each row is a (bw,)
  lane-vector op on the VPU), and writes the (bt, bw) tile of h.

This is the kernel backing recurrentgemma's recurrent blocks; the pure-XLA
fallback is ``jax.lax.associative_scan`` (ref.py / models.ssm).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._compat.pallas import CompilerParams as _CompilerParams
from repro._compat.pallas import resolve_interpret

DEFAULT_BT = 128
DEFAULT_BW = 128


def _rglru_kernel(a_ref, x_ref, h_ref, carry_ref, *, bt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[...].astype(jnp.float32)     # (bt, bw)
    x = x_ref[...].astype(jnp.float32)

    def row(t, h):
        h = a[t] * h + x[t]
        h_ref[t, :] = h.astype(h_ref.dtype)
        return h

    h_last = jax.lax.fori_loop(0, bt, row, carry_ref[...])
    carry_ref[...] = h_last


def rglru_scan_pallas(a: jnp.ndarray, x: jnp.ndarray, *,
                      bt: int = DEFAULT_BT, bw: int = DEFAULT_BW,
                      interpret: bool | None = None) -> jnp.ndarray:
    """a, x: (B, T, W); T % bt == 0 == W % bw → h (B, T, W)."""
    b, t, w = a.shape
    assert t % bt == 0 and w % bw == 0
    kernel = functools.partial(_rglru_kernel, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=(b, w // bw, t // bt),
        in_specs=[
            pl.BlockSpec((None, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((None, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
        ],
        out_specs=pl.BlockSpec((None, bt, bw), lambda bi, wi, ti: (bi, ti, wi)),
        out_shape=jax.ShapeDtypeStruct((b, t, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(a, x)
