from repro.serve.decode import batched_generate, build_decode_step, prefill

__all__ = ["prefill", "build_decode_step", "batched_generate"]
