"""recurrentgemma-2b [arXiv:2402.19427] — RG-LRU + local attention, 1:2."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    citation="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    activation="geglu",
    gated_mlp=True,
    layer_pattern=("rglru", "rglru", "local_attn"),
    sliding_window=2048,
    rglru_lru_width=2560,
    supports_long_context=True,   # O(1)-state recurrence + windowed attention
)
