"""Decision → collective-bucket mapping.

The TPU-native integration: a forward decision's segments become parameter
**all-gather buckets** (the "pull"), a backward decision's segments become
gradient **reduce-scatter buckets** (the "push").  This module is pure
bookkeeping — it converts 1-indexed layer segments into 0-indexed layer-id
groups the distributed trainer consumes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from repro.core.costmodel import (Segment, validate_backward_segments,
                                  validate_forward_segments)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Groups of 0-indexed layer ids, in launch order."""

    forward: Tuple[Tuple[int, ...], ...]   # all-gather buckets, first launched first
    backward: Tuple[Tuple[int, ...], ...]  # reduce-scatter buckets, first launched first

    @property
    def num_forward_collectives(self) -> int:
        return len(self.forward)

    @property
    def num_backward_collectives(self) -> int:
        return len(self.backward)


def plan_from_decision(fwd_segments: Sequence[Segment],
                       bwd_segments: Sequence[Segment],
                       num_layers: int) -> BucketPlan:
    validate_forward_segments(fwd_segments, num_layers)
    validate_backward_segments(bwd_segments, num_layers)
    fwd = tuple(tuple(range(lo - 1, hi)) for lo, hi in fwd_segments)
    bwd = tuple(tuple(range(hi - 1, lo - 2, -1)) for lo, hi in bwd_segments)
    return BucketPlan(forward=fwd, backward=bwd)


def decision_from_plan(plan: BucketPlan) -> Tuple[Tuple[Segment, ...],
                                                  Tuple[Segment, ...]]:
    """Inverse of :func:`plan_from_decision` — 1-indexed segments.

    Round-trips: ``decision_from_plan(plan_from_decision(f, b, L)) ==
    (f, b)`` for any valid decision."""
    if not plan.forward or not plan.backward:
        raise ValueError("plan has no buckets")
    fwd = tuple((min(b) + 1, max(b) + 1) for b in plan.forward)
    bwd = tuple((min(b) + 1, max(b) + 1) for b in plan.backward)
    L = max(hi for _, hi in fwd)
    validate_forward_segments(fwd, L)
    validate_backward_segments(bwd, L)
    return fwd, bwd


def flat_layer_order(plan_groups: Tuple[Tuple[int, ...], ...]) -> Tuple[int, ...]:
    return tuple(l for group in plan_groups for l in group)
