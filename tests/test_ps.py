"""Parameter-server subsystem tests.

Single-device: topology/sharding, per-topology cost projection (asymmetric
per-link Δt), per-worker scheduling + consensus, the PS discrete-event
simulator + timeline rendering, the versioned server (segmented pulls,
staleness gate, eviction), and bounded-staleness async training on the
smoke CNN.

Multi-device (4 forged host devices via subprocess): sync-mode PSTrainer
bit-identity against ZeroTrainer and the one-pull + one-push-per-segment
HLO transfer structure, for all four strategies.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LayerCosts, TopologyCosts, backward_time,
                        consensus_decision, decision_from_plan, dp_backward,
                        iteration_time, plan_from_decision, random_costs,
                        schedule, schedule_topology, simulate_ps_iteration)
from repro.core.viz import render_ps_timeline
from repro.models.cnn import small_cnn_init, small_cnn_loss
from repro.optim import sgd
from repro.ps import (AsyncPSTrainer, PSServer, PSTopology, StaleVersion,
                      asymmetric_link)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------


class TestPSTopology:
    def test_uniform_builder(self):
        topo = PSTopology.uniform(2, 3, down_bps=10e9, up_bps=1e9)
        assert topo.num_servers == 2 and topo.num_workers == 3
        assert topo.links[0].down.bandwidth_bps == 10e9
        assert topo.links[0].up.bandwidth_bps == 1e9

    def test_validation(self):
        link = asymmetric_link(10e9, 1e9)
        with pytest.raises(ValueError, match="num_servers"):
            PSTopology(num_servers=0, links=(link,), worker_flops=(1e9,))
        with pytest.raises(ValueError, match="at least one worker"):
            PSTopology(num_servers=1, links=(), worker_flops=())
        with pytest.raises(ValueError, match="worker_flops"):
            PSTopology(num_servers=1, links=(link,), worker_flops=(1e9, 1e9))
        with pytest.raises(ValueError, match="positive"):
            PSTopology(num_servers=1, links=(link,), worker_flops=(0.0,))
        with pytest.raises(TypeError, match="network interface"):
            from repro.ps import LinkModel
            LinkModel(down=object(), up=object())

    def test_contiguous_shard_ownership(self):
        topo = PSTopology.uniform(3, 1)
        shards = [topo.shard_of_layer(l, 7) for l in range(7)]
        assert shards == sorted(shards)              # contiguous blocks
        assert set(shards) == {0, 1, 2}              # every shard owns some
        union = sum((topo.layers_of_shard(s, 7) for s in range(3)), ())
        assert sorted(union) == list(range(7))       # exact partition
        with pytest.raises(ValueError):
            topo.shard_of_layer(7, 7)
        with pytest.raises(ValueError):
            topo.layers_of_shard(3, 7)

    def test_owner_of_bucket(self):
        topo = PSTopology.uniform(2, 1)
        assert topo.owner_of_bucket((0, 1), 4) == 0
        assert topo.owner_of_bucket((3, 2), 4) == 1
        with pytest.raises(ValueError, match="empty"):
            topo.owner_of_bucket((), 4)

    def test_worker_costs_asymmetric(self):
        """pt/Δt from the downlink, gt/Δt_bwd from the uplink, fc/bc from
        the worker's own compute rate."""
        topo = PSTopology(
            num_servers=1,
            links=(asymmetric_link(10e9, 1e9),
                   asymmetric_link(10e9, 1e9, rtt_s=0.1)),
            worker_flops=(1e10, 2e10))
        pb, ff = [8e6, 8e6], [1e9, 1e9]
        c0 = topo.worker_costs(0, param_bytes=pb, flops_fwd=ff)
        np.testing.assert_allclose(c0.pt, 8e6 * 8 / 10e9)
        np.testing.assert_allclose(c0.gt, 8e6 * 8 / 1e9)   # 10x slower up
        np.testing.assert_allclose(c0.fc, 0.1)
        np.testing.assert_allclose(c0.bc, 0.2)             # default 2x fwd
        assert c0.dt == topo.links[0].down.dt
        assert c0.dt_push == topo.links[0].up.dt
        c1 = topo.worker_costs(1, param_bytes=pb, flops_fwd=ff)
        np.testing.assert_allclose(c1.fc, 0.05)            # 2x faster worker
        assert c1.dt_push > c0.dt_push                     # 0.1s RTT uplink
        with pytest.raises(ValueError, match="worker 2"):
            topo.worker_costs(2, param_bytes=pb, flops_fwd=ff)


# ---------------------------------------------------------------------------
# per-topology cost model + scheduling
# ---------------------------------------------------------------------------


class TestTopologyCosts:
    def _topo(self):
        return TopologyCosts(workers=(
            random_costs(6, seed=0),
            random_costs(6, seed=0, comp_scale=5.0, comm_scale=2.0)))

    def test_validation(self):
        with pytest.raises(ValueError, match="at least one"):
            TopologyCosts(workers=())
        with pytest.raises(ValueError, match="layer count"):
            TopologyCosts(workers=(random_costs(4), random_costs(5)))

    def test_makespan_is_straggler_time(self):
        topo = self._topo()
        d = schedule(topo.workers[0], "dynacomm")
        times = topo.iteration_times(*d)
        assert topo.makespan(*d) == max(times)
        assert topo.straggler(*d) == int(np.argmax(times))

    def test_per_worker_plans_differ_under_heterogeneity(self):
        topo = self._topo()
        decisions = schedule_topology(topo, "dynacomm")
        assert len(decisions) == 2
        assert decisions[0] != decisions[1]

    def test_consensus_minimizes_makespan_over_candidates(self):
        topo = self._topo()
        decision, makespan = consensus_decision(topo, "dynacomm")
        assert makespan == topo.makespan(*decision)
        for cand in schedule_topology(topo, "dynacomm"):
            assert makespan <= topo.makespan(*cand) + 1e-12


class TestAsymmetricDt:
    def test_dt_push_defaults_to_dt(self):
        c = random_costs(4, seed=1)
        assert c.dt_bwd is None and c.dt_push == c.dt

    def test_backward_time_uses_push_dt(self):
        base = random_costs(4, seed=1)
        asym = LayerCosts(pt=base.pt, fc=base.fc, bc=base.bc, gt=base.gt,
                          dt=base.dt, dt_bwd=base.dt * 3)
        segs = ((1, 4),)
        fwd_same = base.scaled()  # forward unaffected by dt_bwd
        assert backward_time(asym, segs) == pytest.approx(
            backward_time(base, segs) + 2 * base.dt)
        from repro.core import forward_time
        assert forward_time(asym, ((1, 4),)) == forward_time(fwd_same,
                                                             ((1, 4),))

    def test_dp_backward_optimal_under_asymmetric_dt(self):
        """The DP's objective must equal f_m when Δt_push != Δt_pull
        (the DPResult constructor asserts this internally) and beat the
        symmetric-Δt decision when the push overhead dominates."""
        base = random_costs(8, seed=3, dt=1e-4)
        asym = LayerCosts(pt=base.pt, fc=base.fc, bc=base.bc, gt=base.gt,
                          dt=base.dt, dt_bwd=5e-2)
        res = dp_backward(asym)
        assert res.time == pytest.approx(backward_time(asym, res.segments))
        # expensive per-push overhead forces fewer, larger segments
        assert len(res.segments) <= len(dp_backward(base).segments)

    def test_validation(self):
        with pytest.raises(ValueError, match="dt_bwd"):
            LayerCosts(pt=[1.0], fc=[1.0], bc=[1.0], gt=[1.0], dt=0.1,
                       dt_bwd=-1.0)


# ---------------------------------------------------------------------------
# PS simulator + rendering
# ---------------------------------------------------------------------------


class TestPSSimulator:
    def _topo(self):
        return TopologyCosts(workers=(random_costs(5, seed=0),
                                      random_costs(5, seed=0,
                                                   comp_scale=3.0)))

    def test_shared_decision_broadcasts(self):
        topo = self._topo()
        d = schedule(topo.workers[0], "dynacomm")
        tl = simulate_ps_iteration(topo, d)
        assert tl.num_workers == 2
        assert tl.makespan == pytest.approx(topo.makespan(*d))
        assert tl.straggler == topo.straggler(*d)

    def test_per_worker_decisions(self):
        topo = self._topo()
        decisions = schedule_topology(topo, "dynacomm")
        tl = simulate_ps_iteration(topo, decisions)
        for w, wtl in enumerate(tl.workers):
            assert wtl.total == pytest.approx(
                iteration_time(topo.workers[w], *decisions[w]))
        waits = tl.barrier_waits
        assert min(waits) == 0.0                       # straggler never waits
        assert waits[tl.straggler] == 0.0

    def test_decision_count_mismatch_rejected(self):
        topo = self._topo()
        d = schedule(topo.workers[0], "dynacomm")
        with pytest.raises(ValueError, match="decisions"):
            simulate_ps_iteration(topo, [d, d, d])

    def test_render_ps_timeline(self):
        topo = self._topo()
        d = schedule(topo.workers[0], "dynacomm")
        text = render_ps_timeline(topo, d, width=60)
        lines = text.splitlines()
        assert "makespan" in lines[0] and "straggler" in lines[0]
        # one header + link lane + compute lane per worker
        assert len(lines) == 1 + 3 * topo.num_workers
        assert sum("barrier wait" in l for l in lines) == topo.num_workers
        assert sum(l.strip().startswith("link") for l in lines) == 2
        # the straggler's reported wait is zero
        straggler_header = lines[1 + 3 * tlstraggler(topo, d)]
        assert "wait 0.0000s" in straggler_header


def tlstraggler(topo, d):
    return simulate_ps_iteration(topo, d).straggler


class TestDecisionPlanRoundTrip:
    @pytest.mark.parametrize("strategy", ["sequential", "lbl", "dynacomm"])
    def test_round_trip(self, strategy):
        costs = random_costs(7, seed=2)
        decision = schedule(costs, strategy)
        plan = plan_from_decision(*decision, 7)
        assert decision_from_plan(plan) == decision


# ---------------------------------------------------------------------------
# the versioned server
# ---------------------------------------------------------------------------


def _make_server(num_layers=4, staleness=1, size=6):
    from repro.dist.collectives import make_flat_spec, flatten_tree
    topo = PSTopology.uniform(2, 2)
    trees = [{"w": jnp.arange(size, dtype=jnp.float32) + l}
             for l in range(num_layers)]
    specs = [make_flat_spec(t, 1) for t in trees]
    flats = [flatten_tree(t, s) for t, s in zip(trees, specs)]
    server = PSServer(specs, topo, sgd(0.5), flats,
                      staleness_bound=staleness)
    return server, specs


def _grads(specs, bucket, value=1.0):
    return {l: jnp.full((specs[l].padded,), value, jnp.float32)
            for l in bucket}


class TestPSServer:
    def test_versioned_pull_is_snapshot_consistent(self):
        """A pull pinned at version v is unaffected by a concurrent push."""
        server, specs = _make_server()
        v, first = server.pull_bucket((0, 1), worker=0)
        assert v == 0
        # another worker pushes everything → version bumps
        for bucket in ((3, 2), (1, 0)):
            server.push_bucket(1, 0, bucket, _grads(specs, bucket))
        assert server.version == 1
        # worker 0 finishes its segmented pull at the pinned version
        v2, rest = server.pull_bucket((2, 3), version=v, worker=0)
        assert v2 == v
        np.testing.assert_array_equal(rest[2], jnp.arange(6) + 2)  # pre-push
        _, head = server.pull_bucket((2, 3), worker=0)
        assert not np.array_equal(head[2], rest[2])                # post-push

    def test_segmented_push_commits_once_complete(self):
        server, specs = _make_server()
        assert server.push_bucket(0, 0, (3, 2), _grads(specs, (3, 2))) is None
        res = server.push_bucket(0, 0, (1, 0), _grads(specs, (1, 0)))
        assert res is not None and res.accepted and res.staleness == 0
        assert res.version == server.version == 1

    def test_staleness_gate(self):
        server, specs = _make_server(staleness=1)

        def push_all(worker, version):
            res = None
            for bucket in ((3, 2), (1, 0)):
                res = server.push_bucket(worker, version, bucket,
                                         _grads(specs, bucket))
            return res

        assert push_all(0, 0).accepted                 # staleness 0
        assert push_all(1, 0).accepted                 # staleness 1 == k
        res = push_all(2, 0)                           # staleness 2 > k
        assert not res.accepted and res.staleness == 2
        assert server.version == 2                     # rejected: no apply
        assert server.ledger.rejected_pushes == 1

    def test_snapshot_eviction(self):
        server, specs = _make_server(staleness=0)
        for v in range(2):
            for bucket in ((3, 2), (1, 0)):
                server.push_bucket(0, v, bucket, _grads(specs, bucket))
        assert server.snapshot_versions == (2,)        # only head retained
        with pytest.raises(StaleVersion, match="evicted"):
            server.pull_bucket((0,), version=0)

    def test_ledger_and_bytes(self):
        server, specs = _make_server()
        nbytes = server.segment_bytes((0, 1))
        assert nbytes == specs[0].total * 4 + specs[1].total * 4
        server.pull_bucket((0, 1), worker=0)
        server.pull_bucket((2, 3), worker=0)
        assert server.ledger.num_pulls == 2
        assert server.ledger.pulled_bytes[0] == server.segment_bytes((0, 1)) \
            + server.segment_bytes((2, 3))

    def test_validation(self):
        server, specs = _make_server()
        with pytest.raises(ValueError, match="empty"):
            server.pull_bucket(())
        with pytest.raises(ValueError, match="lacks grads"):
            server.push_bucket(0, 0, (0, 1), _grads(specs, (0,)))
        server.push_bucket(0, 0, (0,), _grads(specs, (0,)))
        with pytest.raises(ValueError, match="twice"):
            server.push_bucket(0, 0, (0,), _grads(specs, (0,)))
        with pytest.raises(ValueError, match="staleness_bound"):
            _make_server(staleness=-1)


# ---------------------------------------------------------------------------
# bounded-staleness async training (smoke CNN)
# ---------------------------------------------------------------------------


def _cnn_loss(layers, batch):
    return small_cnn_loss({"layers": layers}, batch["images"],
                          batch["labels"])


def _fixed_batch(*_):
    """One fixed batch for every worker: loss must strictly improve."""
    r = np.random.default_rng(7)
    return {"images": jnp.asarray(r.normal(size=(8, 32, 32, 3)), jnp.float32),
            "labels": jnp.asarray(r.integers(0, 10, size=(8,)), jnp.int32)}


def _async_trainer(k, workers=3, flops=None, optimizer=None,
                   throttle="reject", plan=None):
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    if plan is None:
        plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)
    topo = PSTopology(
        num_servers=2,
        links=tuple(asymmetric_link(10e9, 1e9) for _ in range(workers)),
        worker_flops=flops or (1e10,) * workers)
    return AsyncPSTrainer(init_layers=params["layers"], loss_fn=_cnn_loss,
                          optimizer=optimizer or sgd(0.05), topology=topo,
                          plan=plan, staleness=k, throttle=throttle)


def _slow_worker_trainer(k, throttle):
    """The starvation fixture: 4 workers, worker 3 at 1/4 compute rate
    (iteration durations 1, 1, 1, 4 simulated seconds)."""
    return _async_trainer(k, workers=4, flops=(4e10, 4e10, 4e10, 1e10),
                          throttle=throttle)


class TestAsyncBoundedStaleness:
    @pytest.mark.parametrize("k", [0, 1, 2])
    def test_staleness_bound_respected(self, k):
        log = _async_trainer(k).run(12, _fixed_batch)
        assert len(log.accepted) == 12
        assert log.max_staleness <= k
        for e in log.events:
            if not e.result.accepted:
                assert e.result.staleness > k

    def test_k_equal_workers_minus_one_never_rejects(self):
        """Homogeneous workers commit round-robin; k = W-1 absorbs the
        window exactly."""
        log = _async_trainer(2, workers=3).run(12, _fixed_batch)
        assert log.num_rejected == 0

    def test_smoke_cnn_converges(self):
        from repro.optim import adamw
        log = _async_trainer(1, optimizer=adamw(1e-2)).run(30, _fixed_batch)
        losses = log.losses
        assert losses[-1] < losses[0] * 0.55

    def test_deterministic(self):
        l1 = _async_trainer(1).run(10, _fixed_batch).losses
        l2 = _async_trainer(1).run(10, _fixed_batch).losses
        assert l1 == l2

    def test_heterogeneous_durations_from_flops(self):
        """Without explicit costs, the simulated clock scales with
        worker_flops: the 2x-slower worker commits half as often."""
        log = _async_trainer(3, workers=2, flops=(2e10, 1e10)).run(
            12, _fixed_batch)
        by_worker = [sum(1 for e in log.accepted if e.worker == w)
                     for w in range(2)]
        assert by_worker[0] > by_worker[1] > 0

    def test_k0_serializes(self):
        """k=0: every accepted gradient was computed at the head version."""
        log = _async_trainer(0, workers=2).run(8, _fixed_batch)
        assert all(e.result.staleness == 0 for e in log.accepted)
        assert log.num_rejected > 0       # the concurrent pull gets dropped

    def test_plan_must_cover_model(self):
        from repro.core import BucketPlan
        params = small_cnn_init(jax.random.PRNGKey(0))
        plan = plan_from_decision(((1, 2),), ((1, 2),), 2)
        topo = PSTopology.uniform(1, 1)
        with pytest.raises(ValueError, match="forward buckets cover"):
            AsyncPSTrainer(init_layers=params["layers"], loss_fn=_cnn_loss,
                           optimizer=sgd(0.05), topology=topo, plan=plan,
                           staleness=1)
        # backward gaps are rejected up front too (not via a late assert)
        L = len(params["layers"])
        partial = BucketPlan(forward=(tuple(range(L)),),
                             backward=((L - 1, L - 2),))
        with pytest.raises(ValueError, match="backward buckets cover"):
            AsyncPSTrainer(init_layers=params["layers"], loss_fn=_cnn_loss,
                           optimizer=sgd(0.05), topology=topo, plan=partial,
                           staleness=1)


# ---------------------------------------------------------------------------
# SSP wait-at-barrier throttling (ISSUE 4)
# ---------------------------------------------------------------------------


class TestSSPThrottle:
    def test_reject_starves_slow_worker(self):
        """The documented ROADMAP failure mode: at k=1, a 4x-slower worker
        always commits > k versions behind the head the fast workers keep
        advancing — every one of its pushes is evicted, it NEVER
        contributes a gradient."""
        log = _slow_worker_trainer(1, "reject").run(16, _fixed_batch)
        assert log.accepted_by_worker().get(3, 0) == 0
        assert log.num_rejected > 0
        # its attempts were real: rejections from worker 3 are on record
        assert any(e.worker == 3 and not e.result.accepted
                   for e in log.events)

    def test_wait_lets_every_worker_contribute(self):
        """Same fleet, wait throttle: fast workers block at the barrier
        instead; the slow worker lands >= 1 accepted push, nothing is
        ever rejected, and the staleness bound still holds."""
        log = _slow_worker_trainer(1, "wait").run(16, _fixed_batch)
        by_worker = log.accepted_by_worker()
        for w in range(4):
            assert by_worker.get(w, 0) >= 1, f"worker {w} starved"
        assert log.num_rejected == 0
        assert log.max_staleness <= 1
        assert log.total_wait_s > 0        # somebody actually waited

    @pytest.mark.parametrize("k", [0, 1, 2])
    def test_wait_never_violates_bound(self, k):
        log = _slow_worker_trainer(k, "wait").run(12, _fixed_batch)
        assert log.max_staleness <= k
        assert log.num_rejected == 0
        assert len(log.accepted) == 12

    def test_wait_k0_serializes_without_recompute(self):
        """k=0 under wait is sequential SGD like reject mode, but via
        blocking: no rejections, no wasted recomputation."""
        log = _async_trainer(0, workers=2, throttle="wait").run(
            8, _fixed_batch)
        assert all(e.result.staleness == 0 for e in log.accepted)
        assert log.num_rejected == 0

    def test_wait_commits_in_version_order_within_window(self):
        """SSP's invariant, machine-checked: at every accepted commit the
        gradient's compute version is within k of the *post-commit* head
        (PushResult.version), monotone head growth, one bump per commit."""
        log = _slow_worker_trainer(1, "wait").run(16, _fixed_batch)
        heads = [e.result.version for e in log.events]
        assert heads == list(range(1, len(log.events) + 1))
        for e in log.events:
            assert e.result.version - e.version <= 1 + 1  # head bump + k

    def test_throttle_validation(self):
        with pytest.raises(ValueError, match="throttle"):
            _async_trainer(1, throttle="drop")

    def test_run_resumes_without_reset(self):
        """run(reset=False) continues the same event loop: cumulative log,
        advancing simulated clock, no re-priming of batches."""
        tr = _slow_worker_trainer(1, "wait")
        first = tr.run(6, _fixed_batch)
        t1 = first.makespan
        second = tr.run(6, _fixed_batch, reset=False)
        assert second is first                    # one cumulative log
        assert len(second.accepted) == 12
        assert second.makespan > t1               # the clock kept going

    def test_resume_drains_barrier_entries_left_by_push_target(self):
        """A run whose push target is reached while another completed
        worker stands *eligible* at the barrier must not defer that
        commit to the next queue completion on resume: it commits at the
        clock it became eligible, with the SSP wait it actually paid.

        2 workers with durations (1, 4), k=1: worker 0 commits at t=1,
        blocks at the barrier from t=2 on its second push; worker 1
        commits at t=4 (target of 2 reached), which is exactly when
        worker 0's entry becomes eligible."""
        tr = _async_trainer(1, workers=2, flops=(4e10, 1e10),
                            throttle="wait")
        first = tr.run(2, _fixed_batch)
        assert [e.sim_time for e in first.events] == [1.0, 4.0]
        log = tr.run(1, _fixed_batch, reset=False)
        e = log.events[-1]
        assert e.worker == 0
        assert e.sim_time == 4.0            # not worker 1's next finish (8)
        assert e.wait_s == pytest.approx(2.0)   # blocked t=2..4, no more
        assert e.result.accepted and e.result.staleness <= 1


class TestAsyncDeterminism:
    """Two runs with the same seed/topology must be bit-identical — the
    whole event sequence, not just the losses (ISSUE 4 satellite)."""

    @staticmethod
    def _trace(log):
        return [(e.worker, e.sim_time, e.version, e.result.accepted,
                 e.result.staleness, e.result.version, e.loss, e.retries,
                 e.wait_s) for e in log.events]

    @pytest.mark.parametrize("throttle", ["reject", "wait"])
    def test_bit_identical_runs(self, throttle):
        a = _slow_worker_trainer(1, throttle).run(12, _fixed_batch)
        b = _slow_worker_trainer(1, throttle).run(12, _fixed_batch)
        assert self._trace(a) == self._trace(b)
        assert a.losses == b.losses


class TestPerWorkerPlans:
    """Asynchronous planning mode: each worker runs its own decomposition
    (``schedule_topology``), which the server's per-(worker, version)
    accumulation supports without changes."""

    def _plans(self):
        params = small_cnn_init(jax.random.PRNGKey(0))
        L = len(params["layers"])
        coarse = plan_from_decision(((1, L),), ((1, L),), L)
        fine = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)
        return L, coarse, fine

    def test_distinct_plans_run_and_respect_bound(self):
        _, coarse, fine = self._plans()
        tr = _async_trainer(1, workers=3, plan=[coarse, fine, fine])
        log = tr.run(9, _fixed_batch)
        assert log.max_staleness <= 1
        assert tr.plans == (coarse, fine, fine)
        with pytest.raises(ValueError, match="per-worker"):
            tr.plan                     # no single shared plan to return

    def test_plan_count_must_match_workers(self):
        _, coarse, fine = self._plans()
        with pytest.raises(ValueError, match="plans for 3"):
            _async_trainer(1, workers=3, plan=[coarse, fine])

    def test_set_plans_swaps_between_runs(self):
        _, coarse, fine = self._plans()
        tr = _async_trainer(1, workers=3, plan=coarse)
        tr.run(3, _fixed_batch)
        tr.set_plans(fine)
        log = tr.run(3, _fixed_batch, reset=False)
        assert tr.plan == fine
        assert len(log.accepted) == 6


class TestDynamicAsyncPS:
    """Per-worker re-planning across topology epochs (the dynamic-PS
    combination, async side)."""

    def _schedule(self, factor=8.0):
        from repro.ps import uplink_degradation
        base = PSTopology(
            num_servers=2,
            links=tuple(asymmetric_link(1e9, 100e6) for _ in range(3)),
            worker_flops=(1e9, 1e9, 2.5e8))
        return uplink_degradation(base, factor=factor, at_epoch=1)

    def _driver(self, throttle="wait"):
        from repro.ps import DynamicAsyncPSTrainer
        from repro.ps.dynamic import profiles_from_specs
        from repro.dist.collectives import make_flat_spec
        params = small_cnn_init(jax.random.PRNGKey(0))
        specs = [make_flat_spec(t, 1) for t in params["layers"]]
        return DynamicAsyncPSTrainer(
            init_layers=params["layers"], loss_fn=_cnn_loss,
            optimizer=sgd(0.05), topology=self._schedule(),
            pushes_per_epoch=6, staleness=1, throttle=throttle,
            profiles=profiles_from_specs(specs, flops_per_param=1000.0))

    def test_replans_on_epoch_boundaries(self):
        dyn = self._driver()
        log = dyn.run(3, _fixed_batch)
        assert dyn.epoch == 3
        assert len(log.accepted) == 18            # cumulative across epochs
        assert [e.epoch for e in dyn.events] == [0, 1, 2]
        assert [e.at_push for e in dyn.events] == [0, 6, 12]
        # the uplink degradation at epoch 1 re-segments the plans...
        assert dyn.events[1].plan_changed
        # ...and the heterogeneous fleet genuinely plans per worker
        assert len(set(dyn.events[0].worker_plans)) > 1
        assert log.max_staleness <= 1

    def test_wait_throttle_carries_across_replans(self):
        dyn = self._driver(throttle="wait")
        log = dyn.run(2, _fixed_batch)
        assert log.num_rejected == 0
        by_worker = log.accepted_by_worker()
        for w in range(3):
            assert by_worker.get(w, 0) >= 1

    def test_run_pushes_exact_total_with_partial_epoch(self):
        """run_pushes honours the exact requested total: whole epochs of
        pushes_per_epoch with a re-plan on each boundary, then a partial
        final epoch for the remainder."""
        dyn = self._driver()
        log = dyn.run_pushes(14, _fixed_batch)     # 6 + 6 + 2
        assert len(log.accepted) == 14
        assert [e.epoch for e in dyn.events] == [0, 1, 2]
        assert [e.at_push for e in dyn.events] == [0, 6, 12]
        assert log.max_staleness <= 1

    def test_validation(self):
        from repro.ps import DynamicAsyncPSTrainer
        params = small_cnn_init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="pushes_per_epoch"):
            DynamicAsyncPSTrainer(
                init_layers=params["layers"], loss_fn=_cnn_loss,
                optimizer=sgd(0.05), topology=self._schedule(),
                pushes_per_epoch=0)
        with pytest.raises(ValueError, match="num_pushes"):
            self._driver().run_pushes(0, _fixed_batch)


# ---------------------------------------------------------------------------
# multi-device sync-mode checks (subprocess, 4 forged devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestPSTrainerMultiDevice:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, os.path.join(REPO, "tests", "helpers",
                                          "ps_trainer_check.py")],
            capture_output=True, text=True, env=env, timeout=1200)
        assert proc.returncode == 0, proc.stderr[-3000:]
        return json.loads(proc.stdout.strip().splitlines()[-1])

    def test_losses_bit_identical_to_zero_trainer(self, result):
        """Sync-mode PS == ZeRO on the same plan, to the bit."""
        for strat, r in result["strategies"].items():
            assert r["losses"] == r["zero_losses"], strat

    def test_one_pull_one_push_per_segment(self, result):
        """HLO transfers == 2x segment count: one all-gather per forward
        segment, one reduce-scatter per backward segment, all strategies."""
        for strat, r in result["strategies"].items():
            assert r["ag"] == r["fwd_segments"], (strat, r)
            assert r["rs"] == r["bwd_segments"], (strat, r)
            assert r["ag"] + r["rs"] == \
                r["fwd_segments"] + r["bwd_segments"], (strat, r)

    def test_strategies_produce_distinct_segmentations(self, result):
        s = result["strategies"]
        assert s["sequential"]["fwd_segments"] == 1
        assert s["lbl"]["fwd_segments"] > s["sequential"]["fwd_segments"]

    def test_consensus_is_min_over_candidates(self, result):
        c = result["consensus"]
        assert c["makespan"] == pytest.approx(min(c["candidate_makespans"]))

    def test_dynacomm_beats_sequential_makespan(self, result):
        s = result["strategies"]
        assert s["dynacomm"]["makespan"] <= s["sequential"]["makespan"]
