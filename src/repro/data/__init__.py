from repro.data.pipeline import (SyntheticCIFAR, SyntheticText, batch_for,
                                 make_pipeline)

__all__ = ["SyntheticText", "SyntheticCIFAR", "make_pipeline", "batch_for"]
