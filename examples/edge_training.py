"""End-to-end driver: train a ~100M-parameter model with the DynaComm
bucketed ZeRO trainer for a few hundred steps.

Since the ``repro.runtime`` registry landed, this whole pipeline —
profile → DP decision → bucket plan → bucketed trainer — is one config
literal: the example builds a ``RuntimeConfig``, hands the (custom,
~100M-param) arch to ``build_runtime``, and drives the returned
``Trainer`` protocol object.  Swap ``runtime="zero"`` for any other
registered name to run the same model under a different regime.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/edge_training.py --steps 200
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.runtime import (MeasureConfig, NetworkConfig, RuntimeConfig,
                           ScheduleConfig, build_runtime)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--strategy", default="dynacomm")
    ap.add_argument("--bw-gbps", type=float, default=1.0)
    args = ap.parse_args()

    # ~100M-param reduced variant of the chosen architecture
    cfg = dataclasses.replace(
        get_config(args.arch).reduced(num_layers=args.layers,
                                      d_model=args.d_model, vocab=8192),
        name=f"{args.arch}-demo")
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}  arch: {cfg.name}  layers: {cfg.num_layers}  "
          f"d_model: {cfg.d_model}")

    # the whole regime is one config literal; the custom arch rides along
    config = RuntimeConfig(
        runtime="zero", arch=cfg.name, batch=args.batch, seq=args.seq,
        schedule=ScheduleConfig(
            strategy=args.strategy,
            network=NetworkConfig(bandwidth_gbps=args.bw_gbps)),
        measure=MeasureConfig(compute_flops_per_s=1e12))
    rt = build_runtime(config, model=cfg)
    plan = rt.plan
    print(f"strategy {args.strategy}: {len(plan.forward)} pull buckets, "
          f"{len(plan.backward)} push buckets (scheduling took "
          f"{rt.scheduler.last_scheduling_seconds * 1e3:.2f} ms)")

    t0 = time.perf_counter()
    losses = rt.fit(args.steps, log_every=20)
    dt = (time.perf_counter() - t0) / max(len(losses), 1)
    led = rt.ledger
    print(f"{len(losses)} steps at {dt:.3f}s/step; moved "
          f"{led['pull_bytes'] / 1e9:.2f} GB down / "
          f"{led['push_bytes'] / 1e9:.2f} GB up")
    print("done.")


if __name__ == "__main__":
    main()
