"""Pallas kernel: fuse a bucket of padded layer segments into one flat buffer.

DynaComm's transmission mini-procedures move *buckets* of per-layer flat
parameter/gradient vectors.  Before the collective, the runtime packs the
bucket's K segments (each padded to a TILE multiple) into one contiguous
buffer so the all-gather / reduce-scatter sees a single operand; after the
collective the inverse unpack restores per-layer views.

Layout: segments (K, Lmax), aligned lengths prefetched as scalars.  Grid is
(K, Lmax // TILE); program (k, t) copies input tile (k, t) to output tile
``offset[k]//TILE + t`` — a pure HBM→VMEM→HBM streaming copy, 128-lane
aligned, no compute.  Tiles past a segment's aligned length are masked by
redirecting them to a scratch slot at the end of the output buffer.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._compat.pallas import resolve_interpret

TILE = 512  # 4 sublanes x 128 lanes at f32


def aligned(n: int) -> int:
    return ((n + TILE - 1) // TILE) * TILE


def _pack_kernel(offsets_ref, seg_ref, out_ref):
    # out BlockSpec index_map already placed us at the target tile; the body
    # is a straight VMEM copy.
    out_ref[...] = seg_ref[...]


def _pack_index_out(k, t, offsets_ref):
    # target tile for (segment k, tile t); tiles beyond the segment's aligned
    # length land in the trailing scratch tile.
    base = offsets_ref[k] // TILE
    ntiles = offsets_ref[k + 1] // TILE - base
    in_range = t < ntiles
    return (jnp.where(in_range, base + t, offsets_ref[-1] // TILE),)


def _check_aligned_lengths(aligned_lengths: Sequence[int], k_count: int) -> None:
    if len(aligned_lengths) != k_count:
        raise ValueError(f"got {len(aligned_lengths)} aligned lengths for "
                         f"{k_count} segments")
    for n in aligned_lengths:
        if n <= 0 or n % TILE:
            raise ValueError(f"aligned lengths must be positive multiples of "
                             f"TILE={TILE}, got {tuple(aligned_lengths)}")


def pack_pallas(segments: jnp.ndarray, aligned_lengths: Sequence[int], *,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """segments: (K, Lmax) with Lmax % TILE == 0 → (sum(aligned_lengths),)."""
    interpret = resolve_interpret(interpret)
    if segments.ndim != 2:
        raise ValueError(f"segments must be (K, Lmax), got {segments.shape}")
    k_count, lmax = segments.shape
    if lmax % TILE:
        raise ValueError(f"segment row length {lmax} is not a multiple of "
                         f"TILE={TILE}")
    _check_aligned_lengths(aligned_lengths, k_count)
    offsets = np.concatenate([[0], np.cumsum(aligned_lengths)]).astype(np.int32)
    total = int(offsets[-1])

    grid = (k_count, lmax // TILE)
    out = pl.pallas_call(
        _pack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((None, TILE), lambda k, t, offs: (k, t))],
            out_specs=pl.BlockSpec((TILE,), _pack_index_out),
        ),
        out_shape=jax.ShapeDtypeStruct((total + TILE,), segments.dtype),
        interpret=interpret,
    )(jnp.asarray(offsets), segments)
    return out[:total]


def _unpack_index_in(k, t, offsets_ref):
    base = offsets_ref[k] // TILE
    ntiles = offsets_ref[k + 1] // TILE - base
    in_range = t < ntiles
    # out-of-range tiles read tile 0 (the write side zero-masks them)
    return (jnp.where(in_range, base + t, 0),)


def _unpack_masked_kernel(offsets_ref, flat_ref, out_ref):
    k = pl.program_id(0)
    t = pl.program_id(1)
    ntiles = (offsets_ref[k + 1] - offsets_ref[k]) // TILE
    @pl.when(t < ntiles)
    def _():
        out_ref[...] = flat_ref[...]
    @pl.when(t >= ntiles)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)


def unpack_pallas(flat: jnp.ndarray, aligned_lengths: Sequence[int],
                  lmax: int, *, interpret: Optional[bool] = None) -> jnp.ndarray:
    """flat (sum(aligned_lengths),) → (K, Lmax) zero-padded views."""
    interpret = resolve_interpret(interpret)
    if lmax % TILE:
        raise ValueError(f"lmax {lmax} is not a multiple of TILE={TILE}")
    k_count = len(aligned_lengths)
    _check_aligned_lengths(aligned_lengths, k_count)
    offsets = np.concatenate([[0], np.cumsum(aligned_lengths)]).astype(np.int32)
    if flat.shape != (int(offsets[-1]),):
        raise ValueError(f"flat buffer shape {flat.shape} != "
                         f"({int(offsets[-1])},) implied by aligned lengths")

    grid = (k_count, lmax // TILE)
    out = pl.pallas_call(
        _unpack_masked_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((TILE,), _unpack_index_in)],
            out_specs=pl.BlockSpec((None, TILE), lambda k, t, offs: (k, t)),
        ),
        out_shape=jax.ShapeDtypeStruct((k_count, lmax), flat.dtype),
        interpret=interpret,
    )(jnp.asarray(offsets), flat)
    return out
