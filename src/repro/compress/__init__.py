"""Gradient compression for the transmission hot path.

The push direction (worker → server gradients) dominates the wire on
asymmetric edge uplinks, so this package compresses pushes only; pulls
stay fp32.  ``make_compressor`` builds a scheme, the PS/ZeRO trainers
carry it (with error-feedback residuals in trainer state), and the cost
model takes it as a first-class input so the DP re-segments under the
cheaper ``gt``.
"""

from repro.compress.compressor import (SCHEMES, Compressor, Int8Compressor,
                                       TopKCompressor, make_compressor)

__all__ = ["SCHEMES", "Compressor", "Int8Compressor", "TopKCompressor",
           "make_compressor"]
