"""Runtime-regime x model-family cost matrix (``repro.runtime``).

One bench, ``runtime_matrix``, published by CI as part of
``BENCH_pipeline.json``: every registered runtime regime priced against
one representative of each model family — paper CNN (vgg19),
transformer (granite-3-2b), MoE (granite-moe-1b-a400m), SSM
(recurrentgemma-2b) — entirely at the analytic cost-model level (no jax
compiles), so the sweep is cheap enough to run on every CI pass.

Per (family, runtime) the row reports the one-optimizer-step makespan
under that regime's communication pattern and the speedup over the
unoverlapped sequential baseline of the same regime:

* ``local`` — pure compute, no communication (the floor);
* ``zero`` / ``dynamic`` — single shared uplink, DynaComm vs sequential
  decomposition (``dynamic`` priced after its mid-run bandwidth shift);
* ``ps`` / ``dynamic-ps`` — heterogeneous PS fleet, consensus decision,
  straggler makespan;
* ``ps-async`` / ``dynamic-ps-async`` / ``fleet-async`` — per-worker
  decisions, mean worker iteration (``fleet-async`` adds a 4x
  straggler to the roster);
* ``pipeline`` — 4-stage balanced partition, 1F1B replay with
  DynaComm-segmented boundary transfers vs whole-tensor.
"""

from __future__ import annotations

from typing import Dict, List

FAMILIES = (
    ("cnn", "vgg19"),
    ("transformer", "granite-3-2b"),
    ("moe", "granite-moe-1b-a400m"),
    ("ssm", "recurrentgemma-2b"),
)

BANDWIDTH_GBPS = 1.0
SHIFT_GBPS = 0.25            # the dynamic regimes' mid-run drift target
COMPUTE_FLOPS = 1e12
STAGES = 4
MICROBATCHES = 4


def _profiles(family: str, model: str):
    """(profiles, per-micro-batch boundary activation bytes)."""
    if family == "cnn":
        from repro.models.cnn import PAPER_CNNS
        # Mid-network VGG feature map (28x28x512, f32) per sample.
        return PAPER_CNNS[model](batch=32), 8 * 28 * 28 * 512 * 4
    from repro.configs import INPUT_SHAPES, get_config
    from repro.models.profiles import layer_profiles
    cfg = get_config(model)
    shape = INPUT_SHAPES["train_4k"]
    act = (shape.global_batch // MICROBATCHES) * shape.seq_len \
        * cfg.d_model * 4
    return layer_profiles(cfg, shape), act


def _topology(straggler: bool = False):
    from repro.ps import PSTopology, asymmetric_link
    workers = 4
    flops = [COMPUTE_FLOPS if w < workers // 2 else COMPUTE_FLOPS / 2
             for w in range(workers)]
    if straggler:
        flops[-1] = COMPUTE_FLOPS / 4
    return PSTopology(
        num_servers=2,
        links=tuple(asymmetric_link(2e9, BANDWIDTH_GBPS * 1e9,
                                    rtt_s=0.01, setup_s=0.003)
                    for _ in range(workers)),
        worker_flops=tuple(flops))


def _single_link_rows(family, model, profiles, net_gbps):
    """zero/dynamic: one uplink, DynaComm vs sequential decomposition."""
    from repro.core import (EdgeNetworkModel, costs_from_profiles,
                            iteration_time, schedule)
    net = EdgeNetworkModel(bandwidth_bps=net_gbps * 1e9)
    costs = costs_from_profiles(profiles, net=net,
                                compute_flops_per_s=COMPUTE_FLOPS)
    dyn = schedule(costs, "dynacomm")
    seq = schedule(costs, "sequential")
    return (iteration_time(costs, *dyn), iteration_time(costs, *seq))


def _ps_rows(family, model, profiles, *, straggler=False):
    """(consensus makespan, per-worker mean, sequential makespan)."""
    import numpy as np

    from repro.core import consensus_decision, iteration_time, schedule
    topo = _topology(straggler=straggler).topology_costs(profiles)
    _, makespan = consensus_decision(topo, "dynacomm")
    _, seq_makespan = consensus_decision(topo, "sequential")
    per_worker = [iteration_time(c, *schedule(c, "dynacomm"))
                  for c in topo.workers]
    return makespan, float(np.mean(per_worker)), seq_makespan


def _pipeline_row(family, model, profiles, act_bytes):
    from repro.core import EdgeNetworkModel
    from repro.pipeline import (boundary_costs, make_schedule,
                                partition_profiles, plan_boundary, simulate)

    net = EdgeNetworkModel(bandwidth_bps=BANDWIDTH_GBPS * 1e9)
    part = partition_profiles(profiles, STAGES,
                              compute_flops_per_s=COMPUTE_FLOPS)
    fwd, bwd, fx, bx, wx_f, wx_b = [], [], [], [], [], []
    for s, (lo, hi) in enumerate(part.segments):
        f = sum(p.flops_fwd for p in profiles[lo - 1:hi]) / COMPUTE_FLOPS
        b = sum(p.bwd for p in profiles[lo - 1:hi]) / COMPUTE_FLOPS
        fwd.append(f / MICROBATCHES)
        bwd.append(b / MICROBATCHES)
    for bdy in range(STAGES - 1):
        costs = boundary_costs(act_bytes, MICROBATCHES, net=net,
                               stage_fwd_s=fwd[bdy + 1],
                               stage_bwd_s=bwd[bdy], chunks=4)
        plan = plan_boundary(bdy, costs, microbatches=MICROBATCHES,
                             chunks=4)
        fx.append(plan.effective_waits[0])
        bx.append(plan.effective_waits[1])
        wx_f.append(plan.whole_waits[0])
        wx_b.append(plan.whole_waits[1])
    sched = make_schedule("1f1b", STAGES, MICROBATCHES)
    seg = simulate(sched, fwd, bwd, fwd_transfer=fx, bwd_transfer=bx)
    whole = simulate(sched, fwd, bwd, fwd_transfer=wx_f, bwd_transfer=wx_b)
    return seg, whole, part


def runtime_matrix() -> List[Dict]:
    """Every runtime regime priced against every model family."""
    rows = []
    for family, model in FAMILIES:
        profiles, act_bytes = _profiles(family, model)
        compute = sum(p.flops_fwd + p.bwd for p in profiles) / COMPUTE_FLOPS

        def row(runtime, iteration_s, baseline_s, **extra):
            rows.append({
                "family": family, "model": model, "runtime": runtime,
                "iteration_s": round(iteration_s, 4),
                "sequential_s": round(baseline_s, 4),
                "speedup": round(baseline_s / iteration_s, 4)
                if iteration_s > 0 else 1.0, **extra})

        row("local", compute, compute)

        dyn, seq = _single_link_rows(family, model, profiles,
                                     BANDWIDTH_GBPS)
        row("zero", dyn, seq)
        dyn_s, seq_s = _single_link_rows(family, model, profiles,
                                         SHIFT_GBPS)
        row("dynamic", dyn_s, seq_s, shifted_gbps=SHIFT_GBPS)

        mk, mean_w, seq_mk = _ps_rows(family, model, profiles)
        row("ps", mk, seq_mk)
        row("ps-async", mean_w, seq_mk)
        row("dynamic-ps", mk, seq_mk, shifted_gbps=SHIFT_GBPS)
        row("dynamic-ps-async", mean_w, seq_mk, shifted_gbps=SHIFT_GBPS)
        mk_f, mean_f, seq_f = _ps_rows(family, model, profiles,
                                       straggler=True)
        row("fleet-async", mean_f, seq_f, straggler_makespan=round(mk_f, 4))

        seg, whole, part = _pipeline_row(family, model, profiles, act_bytes)
        row("pipeline", seg.makespan, whole.makespan,
            stages=STAGES, microbatches=MICROBATCHES,
            bubble=round(seg.bubble_fraction, 4),
            partition=[list(s) for s in part.segments])
    return rows


MATRIX_BENCHES = {
    "runtime_matrix": runtime_matrix,
}
