"""Micro-batch pipeline schedules as deterministic event streams.

Two classic schedules over S stages × M micro-batches:

* **gpipe** — fill/drain: every stage runs all M forwards in micro-batch
  order, then all M backwards in reverse order;
* **1f1b** — PipeDream-flush: stage s warms up with ``min(S-s-1, M)``
  forwards, then alternates one-forward-one-backward, then drains.

Both are emitted as *per-stage totally-ordered task streams*
(:class:`StageTask` tuples) — pure data, no wall clock — and both admit
the same analytic bubble fraction under uniform stage costs::

    bubble / total = (S - 1) / (M + S - 1)

:func:`simulate` replays a schedule against per-stage forward/backward
durations and per-boundary transfer times with an exact event-driven
sweep, so tests can assert the analytic accounting *equals* simulated
idle time and benches can price non-uniform stages and slow links.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

FORWARD = "F"
BACKWARD = "B"

SCHEDULES = ("gpipe", "1f1b")


@dataclasses.dataclass(frozen=True)
class StageTask:
    """One unit of stage work: micro-batch ``microbatch``'s F or B pass."""

    stage: int
    microbatch: int
    kind: str        # FORWARD | BACKWARD

    def __post_init__(self):
        if self.kind not in (FORWARD, BACKWARD):
            raise ValueError(f"kind must be 'F' or 'B', got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class PipelineSchedule:
    """Per-stage ordered task streams for one optimizer step."""

    name: str
    num_stages: int
    num_microbatches: int
    streams: Tuple[Tuple[StageTask, ...], ...]   # streams[s] = stage s's order

    def __post_init__(self):
        if len(self.streams) != self.num_stages:
            raise ValueError("one stream per stage required")
        for s, stream in enumerate(self.streams):
            fs = [t.microbatch for t in stream if t.kind == FORWARD]
            bs = [t.microbatch for t in stream if t.kind == BACKWARD]
            if sorted(fs) != list(range(self.num_microbatches)) or \
                    sorted(bs) != list(range(self.num_microbatches)):
                raise ValueError(f"stage {s} stream must contain each "
                                 f"micro-batch exactly once per direction")


def gpipe_schedule(num_stages: int, num_microbatches: int) -> PipelineSchedule:
    """Fill/drain: all forwards, then all backwards in reverse order."""
    S, M = _check(num_stages, num_microbatches)
    streams = []
    for s in range(S):
        stream = [StageTask(s, m, FORWARD) for m in range(M)]
        stream += [StageTask(s, m, BACKWARD) for m in reversed(range(M))]
        streams.append(tuple(stream))
    return PipelineSchedule(name="gpipe", num_stages=S, num_microbatches=M,
                            streams=tuple(streams))


def one_f_one_b_schedule(num_stages: int,
                         num_microbatches: int) -> PipelineSchedule:
    """PipeDream-flush (1F1B): warmup, steady 1F1B alternation, drain.

    Stage s admits at most ``S - s`` in-flight micro-batches, so peak
    activation memory is O(S) instead of GPipe's O(M)."""
    S, M = _check(num_stages, num_microbatches)
    streams = []
    for s in range(S):
        warmup = min(S - s - 1, M)
        stream = [StageTask(s, m, FORWARD) for m in range(warmup)]
        for i in range(M - warmup):
            stream.append(StageTask(s, warmup + i, FORWARD))
            stream.append(StageTask(s, i, BACKWARD))
        for m in range(M - warmup, M):
            stream.append(StageTask(s, m, BACKWARD))
        streams.append(tuple(stream))
    return PipelineSchedule(name="1f1b", num_stages=S, num_microbatches=M,
                            streams=tuple(streams))


def make_schedule(name: str, num_stages: int,
                  num_microbatches: int) -> PipelineSchedule:
    if name == "gpipe":
        return gpipe_schedule(num_stages, num_microbatches)
    if name == "1f1b":
        return one_f_one_b_schedule(num_stages, num_microbatches)
    raise ValueError(f"unknown pipeline schedule {name!r}; "
                     f"choose from {list(SCHEDULES)}")


def _check(num_stages: int, num_microbatches: int) -> Tuple[int, int]:
    if num_stages < 1:
        raise ValueError(f"num_stages must be >= 1, got {num_stages}")
    if num_microbatches < 1:
        raise ValueError(
            f"num_microbatches must be >= 1, got {num_microbatches}")
    return int(num_stages), int(num_microbatches)


def analytic_bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """Idle share of stage-time under uniform costs: (S-1)/(M+S-1).

    Both gpipe and 1f1b pay exactly S-1 micro-batch slots of fill plus
    drain per direction; the fraction is of *total* stage time (busy +
    bubble), matching :attr:`PipelineTimeline.bubble_fraction`."""
    S, M = _check(num_stages, num_microbatches)
    return (S - 1) / (M + S - 1)


@dataclasses.dataclass(frozen=True)
class PipelineTimeline:
    """Exact replay of a schedule against stage/link costs."""

    makespan: float
    stage_busy: Tuple[float, ...]
    stage_idle: Tuple[float, ...]            # makespan - busy, per stage
    task_times: Dict[Tuple[int, int, str], Tuple[float, float]]

    @property
    def bubble_fraction(self) -> float:
        total = self.makespan * len(self.stage_busy)
        return 1.0 - sum(self.stage_busy) / total if total > 0 else 0.0


def simulate(sched: PipelineSchedule,
             fwd_times: Sequence[float],
             bwd_times: Sequence[float],
             *,
             fwd_transfer: Optional[Sequence[float]] = None,
             bwd_transfer: Optional[Sequence[float]] = None
             ) -> PipelineTimeline:
    """Event-driven replay: per-stage serial execution + boundary deps.

    ``fwd_times[s]`` / ``bwd_times[s]`` are per-micro-batch stage
    durations; ``fwd_transfer[b]`` / ``bwd_transfer[b]`` are the
    *effective* activation / activation-grad transfer times across
    boundary b (stage b → b+1), i.e. whatever the transfer planner says
    the receiving stage must wait beyond the producer finishing —
    DynaComm-segmented overlap shows up here as a smaller effective wait.

    F(s, m) needs F(s-1, m) + fwd_transfer[s-1]; B(s, m) needs
    B(s+1, m) + bwd_transfer[s] (last stage: its own F(s, m)).  Stages
    are serial in stream order.  Pure float arithmetic — deterministic.
    """
    S, M = sched.num_stages, sched.num_microbatches
    fwd = [float(x) for x in fwd_times]
    bwd = [float(x) for x in bwd_times]
    if len(fwd) != S or len(bwd) != S:
        raise ValueError("need one fwd/bwd duration per stage")
    fx = [0.0] * max(S - 1, 0) if fwd_transfer is None \
        else [float(x) for x in fwd_transfer]
    bx = [0.0] * max(S - 1, 0) if bwd_transfer is None \
        else [float(x) for x in bwd_transfer]
    if len(fx) != S - 1 or len(bx) != S - 1:
        raise ValueError("need one transfer time per boundary (S-1)")

    done: Dict[Tuple[int, int, str], Tuple[float, float]] = {}
    cursor = [0] * S          # next stream index per stage
    clock = [0.0] * S         # stage free time

    def ready(task: StageTask) -> Optional[float]:
        s, m = task.stage, task.microbatch
        if task.kind == FORWARD:
            if s == 0:
                return 0.0
            dep = done.get((s - 1, m, FORWARD))
            return None if dep is None else dep[1] + fx[s - 1]
        if s == S - 1:
            dep = done.get((s, m, FORWARD))
            return None if dep is None else dep[1]
        dep = done.get((s + 1, m, BACKWARD))
        return None if dep is None else dep[1] + bx[s]

    remaining = sum(len(st) for st in sched.streams)
    while remaining:
        progressed = False
        for s in range(S):
            while cursor[s] < len(sched.streams[s]):
                task = sched.streams[s][cursor[s]]
                at = ready(task)
                if at is None:
                    break
                start = max(clock[s], at)
                dur = fwd[s] if task.kind == FORWARD else bwd[s]
                end = start + dur
                done[(task.stage, task.microbatch, task.kind)] = (start, end)
                clock[s] = end
                cursor[s] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            raise RuntimeError("pipeline schedule deadlocked (cyclic deps)")

    makespan = max(clock) if clock else 0.0
    busy = tuple(M * (fwd[s] + bwd[s]) for s in range(S))
    idle = tuple(makespan - b for b in busy)
    return PipelineTimeline(makespan=makespan, stage_busy=busy,
                            stage_idle=idle, task_times=done)
