"""Post-compile HLO analysis: collective traffic + roofline terms.

``collective_bytes`` sums operand bytes of every all-gather / all-reduce
/ reduce-scatter / all-to-all / collective-permute — the quantity
``cost_analysis`` does not report.  The parsing lives in the structured
walker of :mod:`repro.analysis.hlo` (this module used to carry its own
regex scraper; ``repro.analysis`` promoted it, fixing the async
``-start``/``-done`` double count and tuple-operand leaf summing on the
way).  ``roofline`` combines collective bytes with HLO FLOPs/bytes into
the three terms of EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.analysis.hlo import collective_summary
from repro.core.netmodel import (TPU_HBM_BW, TPU_ICI_BW_PER_LINK,
                                 TPU_PEAK_FLOPS_BF16)


def cost_analysis_dict(compiled) -> Dict:
    """`Compiled.cost_analysis()` returns a dict or a one-element list of
    dicts depending on the jax version — normalize to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-op-kind operand bytes summed over the module (per device),
    plus a ``"_counts"`` entry with per-kind instruction counts.

    Both XLA printer styles are handled (bare ``%name`` operands and
    inline-typed ``f32[1,16]{1,0} %name``); async ``-start``/``-done``
    pairs count once, tuple-typed operands sum all leaves.
    """
    summary = collective_summary(hlo_text)
    out: Dict[str, int] = {kind: sum(b for _, b in entries)
                           for kind, entries in summary.items()}
    out["_counts"] = {kind: len(entries)
                     for kind, entries in summary.items()}
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops: float                  # per-device HLO FLOPs
    hbm_bytes: float              # per-device HLO bytes accessed
    coll_bytes: float             # per-device collective operand bytes
    coll_detail: Dict[str, int]
    chips: int

    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=lambda kv: terms[kv])

    @property
    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(*, flops: float, hbm_bytes: float, coll: Dict[str, int],
             chips: int, peak_flops: float = TPU_PEAK_FLOPS_BF16,
             hbm_bw: float = TPU_HBM_BW,
             ici_bw: float = TPU_ICI_BW_PER_LINK) -> Roofline:
    """FLOPs/bytes from ``cost_analysis`` are PER-DEVICE for a partitioned
    module, so each term divides by a single chip's capability; ``chips``
    is retained for reporting."""
    coll_total = sum(v for k, v in coll.items() if not k.startswith("_"))
    return Roofline(
        flops=flops, hbm_bytes=hbm_bytes, coll_bytes=coll_total,
        coll_detail=coll, chips=chips,
        compute_s=flops / peak_flops,
        memory_s=hbm_bytes / hbm_bw,
        collective_s=coll_total / ici_bw,
    )
