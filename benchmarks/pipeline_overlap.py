"""Pipeline-parallel benchmarks (``repro.pipeline``).

Two benches, published together by CI as ``BENCH_pipeline.json``:

* ``pipeline_overlap`` — the tentpole claim: DynaComm-segmented
  activation transfers vs the naive whole-tensor baseline, per uplink
  bandwidth and chunk granularity.  Each row prices one stage boundary
  (the virtual-layer DP of ``repro.pipeline.transfer``) *and* replays
  the full 1F1B timeline with the resulting effective waits, so the
  per-boundary speedup and the end-to-end makespan saving are reported
  side by side.  At edge bandwidths (100 Mbps) segmentation overlaps
  chunk transfers with stage compute and wins; at datacenter bandwidths
  transfers vanish and both collapse to the compute-bound makespan.
* ``pipeline_bubble`` — schedule accounting: per schedule (gpipe /
  1f1b) and (S, M), the analytic bubble fraction (S-1)/(M+S-1) against
  the event-driven simulation under uniform stage costs, plus the
  non-uniform-stage makespan where only the simulation is exact.
"""

from __future__ import annotations

from typing import Dict, List

# One micro-batch boundary tensor: batch 32 x seq 128 x hidden 512, f32.
ACT_BYTES = 32 * 128 * 512 * 4
MICROBATCHES = 4
STAGE_FWD_S = 0.05      # receiving stage's per-micro-batch forward
STAGE_BWD_S = 0.10      # producing stage's per-micro-batch backward

BANDWIDTHS_GBPS = (0.1, 1.0, 10.0)
CHUNKS = (1, 2, 4, 8)


def pipeline_overlap() -> List[Dict]:
    """Segmented vs whole-tensor boundary transfers, per bandwidth."""
    from repro.core import EdgeNetworkModel
    from repro.pipeline import (boundary_costs, make_schedule,
                                plan_boundary, simulate)

    S = 2
    sched = make_schedule("1f1b", S, MICROBATCHES)
    fwd = [STAGE_FWD_S] * S
    bwd = [STAGE_BWD_S] * S
    rows = []
    for gbps in BANDWIDTHS_GBPS:
        net = EdgeNetworkModel(bandwidth_bps=gbps * 1e9)
        for chunks in CHUNKS:
            costs = boundary_costs(ACT_BYTES, MICROBATCHES, net=net,
                                   stage_fwd_s=STAGE_FWD_S,
                                   stage_bwd_s=STAGE_BWD_S, chunks=chunks)
            plan = plan_boundary(0, costs, microbatches=MICROBATCHES,
                                 chunks=chunks)
            seg = simulate(sched, fwd, bwd,
                           fwd_transfer=[plan.effective_waits[0]],
                           bwd_transfer=[plan.effective_waits[1]])
            whole = simulate(sched, fwd, bwd,
                             fwd_transfer=[plan.whole_waits[0]],
                             bwd_transfer=[plan.whole_waits[1]])
            rows.append({
                "bandwidth_gbps": gbps,
                "chunks": chunks,
                "microbatches": MICROBATCHES,
                "fwd_segments": len(plan.decision[0]),
                "bwd_segments": len(plan.decision[1]),
                "segmented_boundary_s": round(
                    plan.fwd_time + plan.bwd_time, 4),
                "whole_boundary_s": round(
                    plan.whole_fwd_time + plan.whole_bwd_time, 4),
                "boundary_speedup": round(plan.speedup, 4),
                "segmented_makespan_s": round(seg.makespan, 4),
                "whole_makespan_s": round(whole.makespan, 4),
                "makespan_speedup": round(
                    whole.makespan / seg.makespan, 4),
                "segmented_bubble": round(seg.bubble_fraction, 4),
                "whole_bubble": round(whole.bubble_fraction, 4),
            })
    return rows


def pipeline_bubble() -> List[Dict]:
    """Analytic vs simulated bubble accounting per schedule and (S, M)."""
    from repro.pipeline import (analytic_bubble_fraction, make_schedule,
                                simulate)

    rows = []
    for name in ("gpipe", "1f1b"):
        for S in (2, 4):
            for M in (2, 4, 8):
                sched = make_schedule(name, S, M)
                uniform = simulate(sched, [1.0] * S, [2.0] * S)
                analytic = analytic_bubble_fraction(S, M)
                # Non-uniform stages: first stage 2x the rest — only the
                # event-driven replay prices this correctly.
                skew_fwd = [2.0] + [1.0] * (S - 1)
                skew_bwd = [4.0] + [2.0] * (S - 1)
                skew = simulate(sched, skew_fwd, skew_bwd)
                rows.append({
                    "schedule": name, "stages": S, "microbatches": M,
                    "analytic_bubble": round(analytic, 6),
                    "simulated_bubble": round(uniform.bubble_fraction, 6),
                    "analytic_matches": abs(
                        analytic - uniform.bubble_fraction) < 1e-9,
                    "uniform_makespan": round(uniform.makespan, 4),
                    "skewed_makespan": round(skew.makespan, 4),
                    "skewed_bubble": round(skew.bubble_fraction, 6),
                })
    return rows


PIPELINE_BENCHES = {
    "pipeline_overlap": pipeline_overlap,
    "pipeline_bubble": pipeline_bubble,
}
