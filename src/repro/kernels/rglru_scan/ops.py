"""Public wrapper for the RG-LRU scan kernel (pad + custom VJP).

Backward differentiates through the associative-scan oracle (the linear
recurrence has a clean transpose; the kernel fwd / reference bwd pairing
keeps training numerically identical to the XLA path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def rglru_scan(a: jnp.ndarray, x: jnp.ndarray, bt: int = 128, bw: int = 128,
               interpret: bool | None = None) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + x_t over axis 1; a, x: (B, T, W)."""
    b, t, w = a.shape
    pt = (-t) % bt
    pw = (-w) % bw
    ap = jnp.pad(a, ((0, 0), (0, pt), (0, pw)))
    xp = jnp.pad(x, ((0, 0), (0, pt), (0, pw)))
    h = rglru_scan_pallas(ap, xp, bt=bt, bw=bw, interpret=interpret)
    return h[:, :t, :w]


def _fwd(a, x, bt, bw, interpret):
    return rglru_scan(a, x, bt, bw, interpret), (a, x)


def _bwd(bt, bw, interpret, res, g):
    a, x = res
    _, vjp = jax.vjp(rglru_scan_ref, a, x)
    return vjp(g)


rglru_scan.defvjp(_fwd, _bwd)
