"""Config registry: ``get_config(name)`` / ``--arch <id>``."""

from repro.configs.base import (INPUT_SHAPES, ArchConfig, InputShape,
                                shape_applicable)
from repro.configs.granite_moe_1b_a400m import CONFIG as _granite_moe
from repro.configs.xlstm_350m import CONFIG as _xlstm
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.gemma3_4b import CONFIG as _gemma3
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.gemma_7b import CONFIG as _gemma7b
from repro.configs.granite_3_2b import CONFIG as _granite2b
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma

ARCHITECTURES = {
    cfg.name: cfg
    for cfg in (
        _granite_moe, _xlstm, _llava, _gemma3, _hubert,
        _gemma7b, _granite2b, _grok, _gemma2, _rgemma,
    )
}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise ValueError(
            f"unknown architecture {name!r}; available: {sorted(ARCHITECTURES)}"
        ) from None


__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "ARCHITECTURES",
           "get_config", "shape_applicable"]
