"""Distribution-layer tests.

Single-device: flat-spec plumbing, sharding rules, bucket plans.
Multi-device (4 forged host devices, via subprocess so the main pytest
process keeps its single-device jax): the DynaComm ZeRO trainer's
structural and numerical claims.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.dist.collectives import (flatten_tree, make_flat_spec,
                                    unflatten_tree)
from repro.dist.sharding import param_pspec
from repro.models import init_params, sched_layer_trees

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestFlatSpecs:
    @pytest.mark.parametrize("axis_size", [2, 4, 8])
    def test_flatten_roundtrip(self, axis_size):
        cfg = get_config("gemma2-2b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        for tree in sched_layer_trees(params):
            spec = make_flat_spec(tree, axis_size)
            assert spec.padded % axis_size == 0
            flat = flatten_tree(tree, spec)
            assert flat.shape == (spec.padded,)
            back = unflatten_tree(flat, spec)
            for a, b in zip(jax.tree_util.tree_leaves(tree),
                            jax.tree_util.tree_leaves(back)):
                assert a.dtype == b.dtype
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           atol=1e-7)


class TestBucketValidation:
    """gather/reduce-scatter bucket preconditions fail fast with clear
    messages instead of index-erroring (or silently mixing shard layouts)."""

    def _specs(self, axis_sizes):
        tree = {"w": jnp.ones((4, 4))}
        return [make_flat_spec(tree, a) for a in axis_sizes]

    def test_empty_bucket_rejected(self):
        from repro.dist.collectives import (gather_bucket,
                                            reduce_scatter_bucket)
        specs = self._specs([2, 2])
        with pytest.raises(ValueError, match="empty bucket"):
            gather_bucket([jnp.ones(8)] * 2, specs, (), "data")
        with pytest.raises(ValueError, match="empty bucket"):
            reduce_scatter_bucket({}, specs, (), "data")

    def test_unknown_layer_rejected(self):
        from repro.dist.collectives import gather_bucket
        specs = self._specs([2, 2])
        with pytest.raises(ValueError, match="unknown layers"):
            gather_bucket([jnp.ones(8)] * 2, specs, (0, 5), "data")

    def test_mixed_axis_size_rejected(self):
        from repro.dist.collectives import (gather_bucket,
                                            reduce_scatter_bucket)
        specs = self._specs([2, 4])
        with pytest.raises(ValueError, match="mixes axis sizes"):
            gather_bucket([jnp.ones(8), jnp.ones(4)], specs, (0, 1), "data")
        grads = {l: {"w": jnp.ones((4, 4))} for l in (0, 1)}
        with pytest.raises(ValueError, match="mixes axis sizes"):
            reduce_scatter_bucket(grads, specs, (0, 1), "data")


class TestShardingRules:
    def test_canonical_dims(self):
        kw = dict(model_axis="model", data_axes=("data",), model_size=16,
                  data_size=16)
        # mlp up: (d, f) → f over model, d over data
        spec = param_pspec("layers/0/mlp/up", (2048, 8192), **kw)
        assert spec == jax.sharding.PartitionSpec("data", "model")
        # wo: (q_dim, d) → model on dim0
        spec = param_pspec("layers/0/attn/wo", (4096, 2048), **kw)
        assert spec == jax.sharding.PartitionSpec("model", "data")
        # norm scale: indivisible → replicated
        spec = param_pspec("layers/0/norm1", (17,), **kw)
        assert spec == jax.sharding.PartitionSpec(None,)

    def test_stacked_offset(self):
        kw = dict(model_axis="model", data_axes=("data",), model_size=16,
                  data_size=16, dim_offset=1)
        spec = param_pspec("stack/0/mlp/up", (40, 2048, 8192), **kw)
        assert spec == jax.sharding.PartitionSpec(None, "data", "model")

    def test_indivisible_falls_back(self):
        kw = dict(model_axis="model", data_axes=("data",), model_size=16,
                  data_size=16)
        # kv proj with kv_dim 8 (< 16): replicate model, data on dim0
        spec = param_pspec("layers/0/attn/wk", (2048, 8), **kw)
        assert spec == jax.sharding.PartitionSpec("data", None)

    @pytest.mark.parametrize("arch", sorted(ARCHITECTURES))
    def test_all_full_configs_get_specs(self, arch):
        """Every full-size param leaf gets a valid, divisible spec."""
        from repro.dist.sharding import params_shardings
        from jax.sharding import Mesh
        import numpy as np

        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda k: init_params(cfg, k, jnp.bfloat16), jax.random.PRNGKey(0))
        devs = np.array(jax.devices() * 1)

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        def rule(path, leaf):
            ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                          for p in path)
            spec = param_pspec(ps, tuple(leaf.shape), model_axis="model",
                               data_axes=("data",), model_size=16,
                               data_size=16)
            for dim, ax in enumerate(spec):
                if ax is not None:
                    assert leaf.shape[dim] % 16 == 0, (arch, ps, leaf.shape)
            return spec

        jax.tree_util.tree_map_with_path(rule, shapes)


@pytest.mark.slow
class TestZeroTrainerMultiDevice:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run(
            [sys.executable, os.path.join(REPO, "tests", "helpers",
                                          "zero_trainer_check.py")],
            capture_output=True, text=True, env=env, timeout=1200)
        assert proc.returncode == 0, proc.stderr[-3000:]
        return json.loads(proc.stdout.strip().splitlines()[-1])

    def test_collective_counts_match_buckets(self, result):
        for strat, r in result["strategies"].items():
            assert r["ag"] == r["fwd_buckets"], (strat, r)
            assert r["rs"] == r["bwd_buckets"], (strat, r)

    def test_losses_bit_identical_across_schedules(self, result):
        """Paper Fig. 10 'accuracy untouched', strengthened to exactness."""
        seqs = [r["losses"] for r in result["strategies"].values()]
        for other in seqs[1:]:
            assert other == seqs[0]

    def test_matches_single_device_reference(self, result):
        ref = result["reference_losses"]
        dyn = result["strategies"]["dynacomm"]["losses"]
        np.testing.assert_allclose(dyn, ref, rtol=2e-5)

    def test_bucket_structure_differs(self, result):
        s = result["strategies"]
        assert s["sequential"]["fwd_buckets"] == 1
        assert s["lbl"]["fwd_buckets"] > s["dynacomm"]["fwd_buckets"] >= 1 \
            or s["dynacomm"]["fwd_buckets"] >= 1

    def test_zero3_regather_mode(self, result):
        """ZeRO-3: backward re-pulls appear per D_b bucket; math unchanged."""
        z3 = result["zero3"]
        assert z3["ag"] == z3["expected_ag"]
        assert z3["losses"] == result["strategies"]["dynacomm"]["losses"]
