"""Memoized, incremental, and asynchronous planning (paper Table I).

The paper argues the DP's cost disappears inside the Δt + gt¹ idle
window while the last gradient push of an iteration is in flight.  The
schedulers historically only *checked* that claim after running the DP
synchronously on the step path; this module makes the hiding real and
attacks the planner's own cost, which at fleet scale (one O(L³) DP per
worker, re-run on every membership change) is a hot path of its own:

* :class:`Planner` — a content-keyed memo cache over
  ``(strategy, LayerCosts)`` → ``Decision``.  Keys hash the exact cost
  *bytes*, so the W identical DPs of a homogeneous fleet collapse to one
  solve plus W−1 dictionary hits, and revisited knots of a
  piecewise-constant ``NetworkSchedule``/``TopologySchedule`` cycle are
  hits across re-plans.  For the DP strategy, a *warm* solve kicks in
  when only the communication side changed against a cached sibling
  (same fc/bc — the ``bandwidth_shift`` / ``uplink_degradation``
  scenarios): the sibling's decision is evaluated under the new costs in
  O(L) and the resulting incumbent bound prunes the Bellman sweep
  (``dp_forward(..., incumbent=)``), while the compute-side prefix sums
  are reused verbatim.  Warm results are *exactly* equal — segments and
  time — to a fresh solve (property-tested).
* :class:`AsyncPlanner` — the off-step-path variant: a deterministic
  two-phase submit/collect protocol.  ``submit`` enqueues the solve for
  a *predicted* future cost point (epoch e+1's costs, computable during
  epoch e whenever the cost source is analytic) on a background thread;
  ``decide`` collects it at the boundary.  Because every solve is a pure
  function of its inputs, the collected decision is bit-identical to a
  synchronous one regardless of thread timing — if the plan is not ready
  (or was never submitted: measured costs, a surprise membership
  change), ``decide`` falls back to solving inline.  Only the *where*
  of the compute moves, never the *what*.

Both schedulers (:class:`~repro.core.scheduler.DynaCommScheduler`,
:class:`~repro.core.scheduler.TopologyScheduler`) accept a ``planner=``
seam; every dynamic driver threads one through.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import dp
from repro.core.costmodel import (LayerCosts, TopologyCosts, backward_time,
                                  forward_time)
from repro.core.scheduler import Decision, STRATEGIES, schedule

__all__ = ["Planner", "AsyncPlanner", "PlannerStats", "cost_key"]

#: decisions retained by default (LRU); sized well past any smoke/bench
#: schedule's distinct (strategy, costs) points
DEFAULT_CACHE_SIZE = 256


def cost_key(costs: LayerCosts) -> Tuple:
    """Exact content key of a :class:`LayerCosts` (array bytes + Δt
    scalars).  Two cost objects with bit-identical vectors share a key —
    no hashing collisions to reason about, dict equality is byte
    equality."""
    return (costs.pt.tobytes(), costs.fc.tobytes(), costs.bc.tobytes(),
            costs.gt.tobytes(), float(costs.dt),
            None if costs.dt_bwd is None else float(costs.dt_bwd))


def _compute_key(costs: LayerCosts) -> Tuple:
    """Key of the compute side only (fc/bc) — the part that is unchanged
    when just bandwidth/Δt scalars move between epochs."""
    return (costs.fc.tobytes(), costs.bc.tobytes())


def _key_to_json(x):
    """Recursively JSON-encode a cache key: raw cost bytes become hex
    (``{"b": ...}``), nested tuples become ``{"t": [...]}`` — strings,
    floats, and None pass through.  ``json`` float text is the shortest
    round-tripping repr, so keys decode byte-exact."""
    if isinstance(x, bytes):
        return {"b": x.hex()}
    if isinstance(x, tuple):
        return {"t": [_key_to_json(v) for v in x]}
    return x


def _key_from_json(x):
    if isinstance(x, dict):
        if "b" in x:
            return bytes.fromhex(x["b"])
        return tuple(_key_from_json(v) for v in x["t"])
    return x


def _decision_to_json(decision: Decision):
    return [[list(seg) for seg in side] for side in decision]


def _decision_from_json(obj) -> Decision:
    return tuple(tuple(tuple(int(v) for v in seg) for seg in side)
                 for side in obj)


@dataclasses.dataclass
class _WarmEntry:
    """A cached solve reusable as a warm start for same-compute costs."""

    decision: Decision
    fc_pref: np.ndarray           # forward compute prefix sums
    bc_pref: np.ndarray           # reversed backward compute prefix sums


@dataclasses.dataclass
class PlannerStats:
    """Counters for the benches and the CI hit-rate gate."""

    solves: int = 0               # cold full solves
    warm_solves: int = 0          # DP solves warm-started from a sibling
    hits: int = 0                 # exact content-key cache hits
    evictions: int = 0            # LRU evictions from the decision cache
    async_submitted: int = 0      # background jobs enqueued
    async_ready: int = 0          # collected with the result already done
    async_waited: int = 0         # collect had to wait on an in-flight job
    sync_fallbacks: int = 0       # decide() with nothing submitted

    @property
    def lookups(self) -> int:
        return self.hits + self.solves + self.warm_solves

    @property
    def hit_rate(self) -> float:
        """Fraction of decide() lookups served from the memo cache."""
        n = self.lookups
        return self.hits / n if n else 0.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d


class Planner:
    """Content-keyed memoizing planner (see module docstring).

    Thread-safe: :class:`AsyncPlanner` solves on a background thread into
    the same cache.  ``cache_size`` bounds the decision LRU; the warm
    index keeps at most one sibling per distinct compute profile, LRU-
    bounded by the same size.
    """

    def __init__(self, *, cache_size: int = DEFAULT_CACHE_SIZE):
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        self.cache_size = cache_size
        self._decisions: "OrderedDict[Tuple, Decision]" = OrderedDict()
        self._warm: "OrderedDict[Tuple, _WarmEntry]" = OrderedDict()
        # whole-topology consensus results: (decision, makespan) keyed by
        # every worker's content key — revisited knots skip the candidate
        # makespan evaluations too, not just the DPs
        self._consensus: "OrderedDict[Tuple, Tuple[Decision, float]]" = \
            OrderedDict()
        self.stats = PlannerStats()
        self._lock = threading.RLock()

    # -- lookup / solve -------------------------------------------------

    @staticmethod
    def _key(costs: LayerCosts, strategy: str) -> Tuple:
        return (strategy,) + cost_key(costs)

    def _lookup(self, key: Tuple) -> Optional[Decision]:
        """Cache probe under the lock; counts a hit when found."""
        decision = self._decisions.get(key)
        if decision is not None:
            self._decisions.move_to_end(key)
            self.stats.hits += 1
        return decision

    def _store(self, key: Tuple, costs: LayerCosts, strategy: str,
               decision: Decision, fc_pref: np.ndarray,
               bc_pref: np.ndarray) -> None:
        self._decisions[key] = decision
        self._decisions.move_to_end(key)
        while len(self._decisions) > self.cache_size:
            self._decisions.popitem(last=False)
            self.stats.evictions += 1
        if strategy == "dynacomm":
            ck = _compute_key(costs)
            self._warm[ck] = _WarmEntry(decision=decision,
                                        fc_pref=fc_pref, bc_pref=bc_pref)
            self._warm.move_to_end(ck)
            while len(self._warm) > self.cache_size:
                self._warm.popitem(last=False)

    def _solve(self, costs: LayerCosts, strategy: str, key: Tuple
               ) -> Decision:
        """Full or warm solve + store.  The DP math runs outside the
        lock (it is pure); only bookkeeping is serialized."""
        with self._lock:
            warm = self._warm.get(_compute_key(costs)) \
                if strategy == "dynacomm" else None
        fc_pref = bc_pref = None
        if warm is not None:
            # Same compute profile, different bandwidth/Δt scalars: the
            # sibling's segmentation is feasible here too, so its O(L)
            # evaluation under the *new* costs bounds the optimum from
            # above and prunes the Bellman sweep; the compute prefix
            # sums carry over verbatim.
            f = dp.dp_forward(costs,
                              incumbent=forward_time(costs,
                                                     warm.decision[0]),
                              fc_pref=warm.fc_pref)
            b = dp.dp_backward(costs,
                               incumbent=backward_time(costs,
                                                       warm.decision[1]),
                               bc_pref=warm.bc_pref)
            decision: Decision = (f.segments, b.segments)
            fc_pref, bc_pref = warm.fc_pref, warm.bc_pref
        else:
            decision = schedule(costs, strategy)
        if fc_pref is None:
            fc_pref = np.concatenate([[0.0], np.cumsum(costs.fc)])
            bc_pref = np.concatenate([[0.0], np.cumsum(costs.bc[::-1])])
        with self._lock:
            if warm is not None:
                self.stats.warm_solves += 1
            else:
                self.stats.solves += 1
            self._store(key, costs, strategy, decision, fc_pref, bc_pref)
        return decision

    # -- the planning API -----------------------------------------------

    def decide(self, costs: LayerCosts, strategy: str) -> Decision:
        """The (memoized) decision for one worker's costs — exactly what
        ``schedule(costs, strategy)`` returns, cached by content."""
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        key = self._key(costs, strategy)
        with self._lock:
            hit = self._lookup(key)
        if hit is not None:
            return hit
        return self._solve(costs, strategy, key)

    def decide_topology(self, topo: TopologyCosts, strategy: str
                        ) -> Tuple[Decision, ...]:
        """Per-worker decisions — ``schedule_topology`` through the memo
        cache, so a homogeneous fleet costs one DP, not W."""
        return tuple(self.decide(c, strategy) for c in topo.workers)

    def consensus(self, topo: TopologyCosts, strategy: str
                  ) -> Tuple[Decision, float]:
        """``consensus_decision`` through the memo cache: candidates are
        the per-worker decisions (deduped, first occurrence order), the
        winner minimizes the synchronous makespan — identical tie-breaks
        to the uncached path.  The whole-topology result is itself
        cached, so a revisited knot costs one dictionary probe instead
        of W DPs plus the candidate makespan sweep."""
        tkey = (strategy,) + tuple(cost_key(c) for c in topo.workers)
        with self._lock:
            cached = self._consensus.get(tkey)
            if cached is not None:
                self._consensus.move_to_end(tkey)
                self.stats.hits += 1
                return cached
        candidates = list(dict.fromkeys(self.decide_topology(topo,
                                                             strategy)))
        best = min(candidates, key=lambda d: topo.makespan(*d))
        result = (best, topo.makespan(*best))
        with self._lock:
            self._consensus[tkey] = result
            self._consensus.move_to_end(tkey)
            while len(self._consensus) > self.cache_size:
                self._consensus.popitem(last=False)
        return result

    def clear(self) -> None:
        """Drop all cached decisions and warm entries (counters stay)."""
        with self._lock:
            self._decisions.clear()
            self._warm.clear()
            self._consensus.clear()

    def __len__(self) -> int:
        return len(self._decisions)

    # -- persistence ----------------------------------------------------

    def state_dict(self) -> Dict:
        """JSON-serializable snapshot of every cache (not the counters).

        Content keys hold raw cost bytes; they travel as hex so the
        snapshot survives ``json.dumps`` inside the loop-state metadata.
        A restored planner serves the same hits a warm one would — a
        resumed run's first re-plan at an already-seen cost point is a
        cache hit, not a fresh solve (tested)."""
        with self._lock:
            return {
                "cache_size": self.cache_size,
                "decisions": [[_key_to_json(k), _decision_to_json(d)]
                              for k, d in self._decisions.items()],
                "warm": [[_key_to_json(k),
                          {"decision": _decision_to_json(w.decision),
                           "fc_pref": [float(v) for v in w.fc_pref],
                           "bc_pref": [float(v) for v in w.bc_pref]}]
                         for k, w in self._warm.items()],
                "consensus": [[_key_to_json(k),
                               [_decision_to_json(d), float(mk)]]
                              for k, (d, mk) in self._consensus.items()],
            }

    def load_state_dict(self, state: Dict) -> None:
        """Restore the caches from :meth:`state_dict` (insertion order —
        and thus LRU order — preserved; counters start fresh)."""
        with self._lock:
            self._decisions.clear()
            self._warm.clear()
            self._consensus.clear()
            for k, d in state.get("decisions", ()):
                self._decisions[_key_from_json(k)] = _decision_from_json(d)
            for k, w in state.get("warm", ()):
                self._warm[_key_from_json(k)] = _WarmEntry(
                    decision=_decision_from_json(w["decision"]),
                    fc_pref=np.asarray(w["fc_pref"], np.float64),
                    bc_pref=np.asarray(w["bc_pref"], np.float64))
            for k, pair in state.get("consensus", ()):
                d, mk = pair
                self._consensus[_key_from_json(k)] = \
                    (_decision_from_json(d), float(mk))


class AsyncPlanner(Planner):
    """Two-phase submit/collect planner (see module docstring).

    Phase one (``submit``/``submit_topology``) runs during epoch e: the
    driver predicts epoch e+1's cost point and enqueues its solve on the
    background thread — the wall-clock window the paper's Table I says
    is idle.  Phase two (``decide``, called by the scheduler at the
    boundary) collects: a finished job is a dictionary hit
    (``async_ready``), an in-flight one is joined (``async_waited`` —
    still off the critical path for everything already computed), and a
    never-submitted point solves inline (``sync_fallbacks``).  Decisions
    are pure functions of their inputs, so all three paths return
    bit-identical results.
    """

    def __init__(self, *, cache_size: int = DEFAULT_CACHE_SIZE):
        super().__init__(cache_size=cache_size)
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-planner")
        self._pending: Dict[Tuple, "Future[Decision]"] = {}

    def submit(self, costs: LayerCosts, strategy: str) -> bool:
        """Phase one: enqueue the solve for a predicted cost point.
        Returns whether a new background job was created (False when the
        point is already cached or in flight)."""
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        key = self._key(costs, strategy)
        with self._lock:
            # finished speculative jobs live on in the decision cache
            self._pending = {k: f for k, f in self._pending.items()
                             if not f.done()}
            if key in self._decisions or key in self._pending:
                return False
            future = self._executor.submit(self._solve, costs, strategy,
                                           key)
            self._pending[key] = future
            self.stats.async_submitted += 1
            return True

    def submit_topology(self, topo: TopologyCosts, strategy: str) -> int:
        """Phase one over a whole topology; returns jobs enqueued."""
        return sum(int(self.submit(c, strategy)) for c in topo.workers)

    def decide(self, costs: LayerCosts, strategy: str) -> Decision:
        """Phase two: collect (waiting if the job is still in flight) or
        fall back to an inline solve."""
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        key = self._key(costs, strategy)
        with self._lock:
            hit = self._lookup(key)
            future = None if hit is not None else self._pending.pop(key,
                                                                    None)
        if hit is not None:
            return hit
        if future is not None:
            if future.done():
                self.stats.async_ready += 1
            else:
                self.stats.async_waited += 1
            return future.result()
        self.stats.sync_fallbacks += 1
        return self._solve(costs, strategy, key)

    def drain(self) -> None:
        """Block until every submitted job has landed in the cache
        (tests; not needed by the trainers)."""
        with self._lock:
            pending = list(self._pending.values())
        for future in pending:
            future.result()

    def close(self) -> None:
        """Shut the background thread down (idempotent)."""
        self._executor.shutdown(wait=True)
