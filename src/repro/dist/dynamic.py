"""Run-time dynamic re-scheduling for the bucketed ZeRO trainer.

This module closes the paper's run-time loop (Section IV): profiling →
DP decision → bucket plan → *live* plan swap, once per epoch.  PR 1 built
the two halves — ``repro.core`` decides, ``repro.dist.zero`` executes — and
``DynamicTrainer`` is the driver that connects them during training:

* per-sched-layer ``fc``/``bc`` come from *measured* wall-clock timings of
  the jitted per-layer applies (``LayerTimingHook``, the mxnet.profiler
  analogue) or from the analytic profiles (deterministic; the default);
* ``pt``/``gt``/``Δt`` come from the *active* network model — a
  ``NetworkSchedule`` makes the network condition time-varying (e.g. the
  uplink dropping 10 Gbps → 1 Gbps at epoch k), which is what makes
  re-scheduling visible;
* on every epoch boundary the ``DynaCommScheduler`` re-plans; when the
  decision changes, the plan is converted with ``plan_from_decision`` and a
  new compiled step is swapped in.  Compiled steps are cached **keyed by
  ``BucketPlan``**, so a revisited plan (bandwidth recovers) never
  re-traces — the swap is a dictionary lookup;
* every re-schedule records a ``RescheduleEvent`` carrying the scheduling
  wall time and the paper's Table I ``scheduling_overhead_hidden`` check
  (does the DP fit in the idle window while the last gradient push is in
  flight?).

Because the ZeRO state layout (one ``FlatSpec`` flat buffer per sched
layer) is plan-independent, states carry across plan swaps unchanged, and
the loss trajectory of a dynamic run is bit-identical to running the same
plan sequence statically (asserted by ``tests/test_dynamic.py``).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.configs.base import ArchConfig, InputShape
from repro.core.buckets import BucketPlan, plan_from_decision
from repro.core.costmodel import LayerCosts
from repro.core.netmodel import NetworkSchedule, as_schedule
from repro.core.profiler import LayerTimingHook, costs_from_profiles
from repro.core.scheduler import Decision, DynaCommScheduler
from repro.dist.zero import ZeroTrainer
from repro.launch.hlo_analysis import collective_bytes
from repro.models import model as model_lib
from repro.models.profiles import layer_profiles
from repro.optim import Optimizer


def hlo_collective_counts(hlo_text: str) -> Tuple[int, int]:
    """(#all-gathers, #reduce-scatters) in a compiled HLO dump."""
    counts = collective_bytes(hlo_text)["_counts"]
    return counts["all-gather"], counts["reduce-scatter"]


def sequential_plan(num_layers: int) -> BucketPlan:
    """The whole model as one pull and one push bucket (always valid)."""
    return BucketPlan(forward=(tuple(range(num_layers)),),
                      backward=(tuple(range(num_layers - 1, -1, -1)),))


@dataclasses.dataclass(frozen=True)
class RescheduleEvent:
    """One scheduling pass (paper Table I bookkeeping)."""

    step: int                     # global step index at the epoch boundary
    epoch: int
    plan: BucketPlan              # plan active after this pass
    plan_changed: bool            # decision differed from the previous epoch
    retraced: bool                # False ⇒ compiled-step cache hit (or no swap)
    scheduling_seconds: float     # wall time of the DP re-plan
    overhead_hidden: bool         # fits in the Δt + gt¹ idle window (Table I)
    trigger: str = "epoch"        # "epoch" boundary | "drift" detector


class PlanStepCache:
    """``BucketPlan``-keyed AOT compiled-step cache shared by the dynamic
    drivers (this module's ``DynamicTrainer`` and
    ``repro.ps.dynamic.DynamicPSTrainer``): each distinct plan is traced
    and compiled exactly once (``.lower().compile()``), revisits are
    dictionary lookups, and per-plan HLO collective counts are kept for
    the structural assertions."""

    def __init__(self):
        self._steps: Dict[BucketPlan, Callable] = {}
        self._hlo: Dict[BucketPlan, Tuple[int, int]] = {}
        self.traces = 0                # compile-cache misses
        self.hits = 0                  # plan *swaps* served from the cache

    @property
    def plans(self) -> Tuple[BucketPlan, ...]:
        return tuple(self._steps)

    def hlo_counts(self, plan: BucketPlan) -> Tuple[int, int]:
        """(#all-gathers, #reduce-scatters) of a cached plan's step."""
        if plan not in self._hlo:
            raise KeyError(f"plan {plan} has no compiled step yet")
        return self._hlo[plan]

    def step_for(self, plan: BucketPlan, build_step: Callable[[], Callable],
                 state, batch, *, count_hit: bool) -> Tuple[Callable, bool]:
        """The compiled step for ``plan``, compiling via ``build_step()``
        on a miss.  Returns ``(step_fn, retraced)``; ``count_hit`` tells
        whether a cache hit is an actual plan swap (a post-restore
        recompile of the unchanged plan is not)."""
        if plan in self._steps:
            if count_hit:
                self.hits += 1
            return self._steps[plan], False
        self.traces += 1
        compiled = jax.jit(build_step()).lower(state, batch).compile()
        self._hlo[plan] = hlo_collective_counts(compiled.as_text())
        self._steps[plan] = compiled
        return compiled, True


@dataclasses.dataclass
class DynamicTrainer:
    """Epoch-boundary re-scheduling driver around :class:`ZeroTrainer`.

    ``network`` may be a static model or a :class:`NetworkSchedule`;
    ``cost_source`` picks deterministic analytic profiles (default) or
    measured per-layer wall-clock timings for fc/bc.
    """

    cfg: ArchConfig
    mesh: Any
    optimizer: Optimizer
    network: Any
    steps_per_epoch: int
    strategy: str = "dynacomm"
    cost_source: str = "analytic"          # "analytic" | "measured"
    input_shape: Optional[InputShape] = None
    compute_flops_per_s: Optional[float] = 1e12
    measure_iters: int = 3
    measure_warmup: int = 1
    remeasure_every: int = 1      # epochs between fc/bc re-measurements;
                                  # 0 = measure once (pre-PR-3 behavior)
    drift_detector: Optional[Any] = None   # e.g. core.EwmaDriftDetector
    zero3: bool = False
    axis_name: str = "data"
    aux_weight: float = 0.01

    def __post_init__(self):
        if self.steps_per_epoch < 1:
            raise ValueError(f"steps_per_epoch must be >= 1, got "
                             f"{self.steps_per_epoch}")
        if self.cost_source not in ("analytic", "measured"):
            raise ValueError(f"cost_source must be 'analytic' or 'measured', "
                             f"got {self.cost_source!r}")
        if self.remeasure_every < 0:
            raise ValueError(f"remeasure_every must be >= 0, got "
                             f"{self.remeasure_every}")
        self.network: NetworkSchedule = as_schedule(self.network)
        self.scheduler = DynaCommScheduler(strategy=self.strategy,
                                           reschedule_every=self.steps_per_epoch)
        self.hook = LayerTimingHook(warmup=self.measure_warmup)
        Ls = model_lib.num_sched_layers(self.cfg)
        self.base = ZeroTrainer(cfg=self.cfg, mesh=self.mesh,
                                plan=sequential_plan(Ls),
                                optimizer=self.optimizer, zero3=self.zero3,
                                axis_name=self.axis_name,
                                aux_weight=self.aux_weight)
        self.events: List[RescheduleEvent] = []
        self._cache = PlanStepCache()
        self._step_idx = 0
        self._decision: Optional[Decision] = None
        self._plan: Optional[BucketPlan] = None
        self._step_fn: Optional[Callable] = None
        self._costs: Optional[LayerCosts] = None
        self._measured_fc_bc: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._measured_epoch = -1
        self._drift_pending = False

    # ------------------------------------------------------------------
    # state / introspection
    # ------------------------------------------------------------------

    def init_state(self, key):
        return self.base.init_state(key)

    @property
    def step_index(self) -> int:
        return self._step_idx

    @property
    def epoch(self) -> int:
        return self._step_idx // self.steps_per_epoch

    @property
    def plan(self) -> Optional[BucketPlan]:
        """The currently active bucket plan (None before the first step)."""
        return self._plan

    @property
    def plans_seen(self) -> Tuple[BucketPlan, ...]:
        return self._cache.plans

    @property
    def traces(self) -> int:
        """Compiled-step cache misses (one trace per distinct plan)."""
        return self._cache.traces

    @property
    def cache_hits(self) -> int:
        """Plan swaps served from the compiled-step cache."""
        return self._cache.hits

    def hlo_counts(self, plan: Optional[BucketPlan] = None) -> Tuple[int, int]:
        """(#all-gathers, #reduce-scatters) of a cached plan's compiled step."""
        return self._cache.hlo_counts(self._plan if plan is None else plan)

    # ------------------------------------------------------------------
    # cost vectors
    # ------------------------------------------------------------------

    def _input_shape_for(self, batch) -> InputShape:
        if self.input_shape is not None:
            return self.input_shape
        if "tokens" not in batch:
            raise ValueError("cannot derive an InputShape from a batch "
                             "without 'tokens'; pass input_shape= explicitly")
        B, T = batch["tokens"].shape
        return InputShape("dynamic", int(T), int(B), "train")

    def costs_for_epoch(self, epoch: int, state, batch, *,
                        remeasure: bool = False) -> LayerCosts:
        """fc/bc from the configured source; pt/gt/Δt from the epoch's
        network model.

        With ``cost_source="measured"``, fc/bc are re-measured every
        ``remeasure_every`` re-schedule epochs (so *compute* drift — a
        thermally throttled edge device, a contended CPU — is seen, not
        just network drift); ``remeasure=True`` forces a fresh measurement
        (the drift-detector path).
        """
        net = self.network.model_at(epoch)
        if self.cost_source == "analytic":
            return costs_from_profiles(
                layer_profiles(self.cfg, self._input_shape_for(batch)),
                net=net, compute_flops_per_s=self.compute_flops_per_s)
        stale = (self.remeasure_every > 0 and
                 epoch - self._measured_epoch >= self.remeasure_every)
        if self._measured_fc_bc is None or stale or remeasure:
            measured = self.measure_costs(state, batch, net=net)
            self._measured_fc_bc = (measured.fc, measured.bc)
            self._measured_epoch = epoch
            return measured
        fc, bc = self._measured_fc_bc
        pb = np.asarray(model_lib.sched_layer_bytes(self.cfg), np.float64)
        return LayerCosts(pt=net.transfer_time(pb), fc=fc, bc=bc,
                          gt=net.transfer_time(pb), dt=net.dt)

    def measure_costs(self, state, batch, *, net=None,
                      iters: Optional[int] = None) -> LayerCosts:
        """Measured per-sched-layer fc/bc via the :class:`LayerTimingHook`.

        Each sched layer's forward apply and VJP is jitted and timed
        standalone (the run-time analogue of the paper's per-layer
        mxnet.profiler pass); pt/gt/Δt stay analytic from ``net``.
        """
        net = self.network.model_at(self.epoch) if net is None else net
        iters = self.measure_iters if iters is None else iters
        tr, hook = self.base, self.hook
        Ls, kinds = tr.num_layers, tr._kinds
        calls = hook.warmup + iters
        trees = jax.device_get(
            model_lib.sched_layer_trees(tr.params_from_state(state)))
        hook.reset()

        one = jnp.ones((), jnp.float32)
        aux_ct = jnp.asarray(tr.aux_weight, jnp.float32)

        embed_fwd = jax.jit(lambda p, b: tr._apply_embed(p, b))
        h0 = jax.block_until_ready(embed_fwd(trees[0], batch))
        ct_h = jnp.ones_like(h0)
        timed = hook.timed("fc", 0, embed_fwd)
        for _ in range(calls):
            timed(trees[0], batch)
        embed_bwd = jax.jit(lambda p, b, ct: jax.vjp(
            lambda pp: tr._apply_embed(pp, b), p)[1](ct))
        timed = hook.timed("bc", 0, embed_bwd)
        for _ in range(calls):
            timed(trees[0], batch, ct_h)

        # one jitted fwd/bwd per distinct layer kind — layers of the same
        # kind share the compilation (their shapes match)
        blk_fwd = {k: jax.jit(lambda p, x, _k=k: tr._apply_block(p, x, _k))
                   for k in set(kinds)}
        blk_bwd = {k: jax.jit(lambda p, x, ct, a, _k=k: jax.vjp(
                       lambda pp, xx: tr._apply_block(pp, xx, _k), p, x
                   )[1]((ct, a)))
                   for k in set(kinds)}
        for l in range(1, Ls - 1):
            kind = kinds[l - 1]
            timed = hook.timed("fc", l, blk_fwd[kind])
            for _ in range(calls):
                timed(trees[l], h0)
            timed = hook.timed("bc", l, blk_bwd[kind])
            for _ in range(calls):
                timed(trees[l], h0, ct_h, aux_ct)

        fin_fwd = jax.jit(lambda pf, pe, x, b: tr._apply_final(pf, pe, x, b))
        timed = hook.timed("fc", Ls - 1, fin_fwd)
        for _ in range(calls):
            timed(trees[Ls - 1], trees[0], h0, batch)
        fin_bwd = jax.jit(lambda pf, pe, x, b, ct: jax.vjp(
            lambda a, c, d: tr._apply_final(a, c, d, b), pf, pe, x)[1](ct))
        timed = hook.timed("bc", Ls - 1, fin_bwd)
        for _ in range(calls):
            timed(trees[Ls - 1], trees[0], h0, batch, one)

        pb = np.asarray(model_lib.sched_layer_bytes(self.cfg), np.float64)
        return hook.costs(param_bytes=pb, net=net)

    # ------------------------------------------------------------------
    # the dynamic loop
    # ------------------------------------------------------------------

    def _maybe_reschedule(self, i: int, state, batch) -> None:
        drift = self._drift_pending
        self._drift_pending = False
        boundary = i % self.steps_per_epoch == 0 or drift
        if boundary:
            self._costs = self.costs_for_epoch(i // self.steps_per_epoch,
                                               state, batch, remeasure=drift)
            if drift:
                self.scheduler.invalidate()
        decision = self.scheduler.decision_for_iteration(self._costs)
        changed = decision != self._decision
        # (``_step_fn is None`` off-boundary ⇒ loop state was just restored
        # from a checkpoint: recompile the active plan, no scheduling event)
        if not boundary and not changed and self._step_fn is not None:
            return
        plan = plan_from_decision(*decision, self.base.num_layers)
        prev = self._plan
        retraced = False
        if plan != prev or self._step_fn is None:
            self._step_fn, retraced = self._cache.step_for(
                plan,
                lambda: self.base.with_plan(plan).build_train_step(),
                state, batch, count_hit=plan != prev)
            self._plan = plan
        self._decision = decision
        if boundary or changed:
            self.events.append(RescheduleEvent(
                step=i, epoch=i // self.steps_per_epoch, plan=plan,
                plan_changed=prev is not None and plan != prev,
                retraced=retraced,
                scheduling_seconds=self.scheduler.last_scheduling_seconds,
                overhead_hidden=self.scheduler.scheduling_overhead_hidden(
                    self._costs),
                trigger="drift" if drift else "epoch"))

    def step(self, state, batch):
        """One training step; re-plans on epoch boundaries — and, when a
        ``drift_detector`` is attached, whenever *observed* step times
        shift persistently (the detector's verdict applies from the next
        step).  Returns ``(new_state, mean_loss)``."""
        self._maybe_reschedule(self._step_idx, state, batch)
        if self.drift_detector is None:
            new_state, loss = self._step_fn(state, batch)
        else:
            t0 = time.perf_counter()
            new_state, loss = self._step_fn(state, batch)
            jax.block_until_ready(loss)
            if self.drift_detector.update(time.perf_counter() - t0):
                self._drift_pending = True
        self._step_idx += 1
        return new_state, loss

    # ------------------------------------------------------------------
    # loop-state checkpointing (``repro.checkpoint``)
    #
    # The *model* state is checkpointed separately (it is an ordinary
    # pytree); these methods capture the dynamic-loop bookkeeping — the
    # step/scheduler iteration counters, the active decision/plan, and
    # the RescheduleEvent history — so a resumed run re-schedules on the
    # same epoch boundaries and replays the same plan sequence.  Compiled
    # steps are not serializable; the restored plan recompiles lazily on
    # the first post-restore step (no scheduling event is recorded).
    # ------------------------------------------------------------------

    @staticmethod
    def _plan_to_obj(plan: Optional[BucketPlan]):
        if plan is None:
            return None
        return {"forward": [list(b) for b in plan.forward],
                "backward": [list(b) for b in plan.backward]}

    @staticmethod
    def _plan_from_obj(obj) -> Optional[BucketPlan]:
        if obj is None:
            return None
        return BucketPlan(
            forward=tuple(tuple(b) for b in obj["forward"]),
            backward=tuple(tuple(b) for b in obj["backward"]))

    def loop_state(self) -> Dict[str, np.ndarray]:
        """The dynamic-loop bookkeeping as a checkpointable pytree."""
        meta = {
            "scheduler": self.scheduler.state_dict(),
            "plan": self._plan_to_obj(self._plan),
            "drift_pending": self._drift_pending,
            "drift_detector": (self.drift_detector.state_dict()
                               if self.drift_detector is not None and
                               hasattr(self.drift_detector, "state_dict")
                               else None),
            "events": [{
                "step": e.step, "epoch": e.epoch,
                "plan": self._plan_to_obj(e.plan),
                "plan_changed": e.plan_changed, "retraced": e.retraced,
                "scheduling_seconds": e.scheduling_seconds,
                "overhead_hidden": e.overhead_hidden, "trigger": e.trigger,
            } for e in self.events],
            "measured_epoch": self._measured_epoch,
        }
        state = {"step_idx": np.asarray(self._step_idx, np.int64),
                 "meta": np.asarray(json.dumps(meta))}
        if self._measured_fc_bc is not None:
            fc, bc = self._measured_fc_bc
            state["measured_fc"] = np.asarray(fc, np.float64)
            state["measured_bc"] = np.asarray(bc, np.float64)
        return state

    def save_loop_state(self, path: str) -> None:
        save_checkpoint(path, self.loop_state(), step=self._step_idx)

    def restore_loop_state(self, path: str) -> None:
        Ls = self.base.num_layers
        template: Dict[str, np.ndarray] = {
            "step_idx": np.zeros((), np.int64), "meta": np.asarray("")}
        if self.cost_source == "measured":
            with np.load(path) as probe:
                has_measured = "measured_fc" in probe.files
            if has_measured:       # absent ⇒ saved before 1st measurement
                template["measured_fc"] = np.zeros((Ls,), np.float64)
                template["measured_bc"] = np.zeros((Ls,), np.float64)
        tree, _ = load_checkpoint(path, template)
        meta = json.loads(str(tree["meta"]))
        self._step_idx = int(tree["step_idx"])
        sched = dict(meta["scheduler"])
        self.scheduler.load_state_dict(sched)
        self._decision = self.scheduler._decision
        self._plan = self._plan_from_obj(meta["plan"])
        self._measured_epoch = int(meta.get("measured_epoch", -1))
        if "measured_fc" in tree:
            self._measured_fc_bc = (np.asarray(tree["measured_fc"]),
                                    np.asarray(tree["measured_bc"]))
        self.events = [RescheduleEvent(
            step=e["step"], epoch=e["epoch"],
            plan=self._plan_from_obj(e["plan"]),
            plan_changed=e["plan_changed"], retraced=e["retraced"],
            scheduling_seconds=e["scheduling_seconds"],
            overhead_hidden=e["overhead_hidden"],
            trigger=e.get("trigger", "epoch")) for e in meta["events"]]
        self._step_fn = None       # recompiled lazily on the next step
        self._costs = None
        self._drift_pending = bool(meta.get("drift_pending", False))
        det_state = meta.get("drift_detector")
        if det_state is not None and self.drift_detector is not None and \
                hasattr(self.drift_detector, "load_state_dict"):
            self.drift_detector.load_state_dict(det_state)

    def run(self, state, batch_fn: Callable[[int], Any], num_steps: int, *,
            log_every: int = 0):
        """Drive ``num_steps`` steps with ``batch_fn(i) -> batch``.

        Returns ``(state, losses)`` with one float loss per step."""
        losses: List[float] = []
        for i in range(num_steps):
            state, loss = self.step(state, batch_fn(i))
            losses.append(float(loss))
            if log_every and (i + 1) % log_every == 0:
                f, b = (len(self._plan.forward), len(self._plan.backward))
                print(f"step {i + 1:4d}  epoch {self.epoch}  "
                      f"loss {losses[-1]:.4f}  buckets {f}/{b}")
        return state, losses
