from repro.kernels.bucket_pack.ops import bucket_pack, bucket_unpack

__all__ = ["bucket_pack", "bucket_unpack"]
