"""Pure-jnp oracle for the RG-LRU linear recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + x_t along axis=1.  a, x: (B, T, W)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h
