"""Stage-partitioned pipeline-parallel trainer.

``PipelineTrainer`` executes the model as S contiguous stages of sched
layers (embed, blocks..., head — the :class:`StagePartition` decides the
split).  Each stage owns a *jitted per-stage apply*; micro-batch
activations cross stage boundaries as FlatSpec-described flat float32
buffers, and every crossing is accounted in a
:class:`~repro.ps.server.TransferLedger` keyed by boundary index.

Numerical contract — the losses are bit-identical to the single-device
per-layer reference (the ZeroTrainer math on one device) at M = 1 for
any stage count, because every stage runs the *same* per-layer ops in
the same order; only the XLA program boundaries move:

* forward: ``_embed_inputs`` → ``apply_block``... → head, with the CE
  *numerator* accumulated per micro-batch and one division by the
  full-batch mask count at the end (at M = 1 this is literally
  ``cross_entropy``'s sum/maximum/divide);
* backward: per-layer VJPs in descending order inside each stage
  (activations recomputed stage-locally — the standard pipeline
  recompute), with the tied-head embedding cotangent routed back to the
  stage that owns the embedding, exactly like the ZeroTrainer;
* optimizer: the shared ``Optimizer.update`` on the per-sched-layer
  flat buffers.

MoE auxiliary losses are summed per stage then combined in stage order;
with aux ≠ 0 and S > 1 the summation *grouping* differs from the
single-program reference, so MoE configs agree to f32 roundoff rather
than bitwise (dense models emit exact-zero aux and stay bitwise).

``stage_devices=`` places each stage's parameters, batch slice, and
boundary buffers on an explicit device (``jax.device_put`` before each
stage call), so on a forged multi-device host the boundary buffers are
*real* cross-device transfers — the slow 4-device test drives this.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.costmodel import LayerCosts
from repro.dist.collectives import (FlatSpec, flatten_tree, make_flat_spec,
                                    unflatten_tree)
from repro.models import blocks as blocks_lib
from repro.models import model as model_lib
from repro.optim import Optimizer
from repro.pipeline.partition import StagePartition, partition_loads
from repro.pipeline.schedule import (PipelineSchedule, PipelineTimeline,
                                     make_schedule, simulate)
from repro.pipeline.transfer import (TransferPlan, boundary_costs,
                                     plan_boundary)
from repro.ps.server import TransferLedger

#: ledger key for the tied-embedding broadcast to the head stage (the
#: one transfer that is not a neighbor-boundary crossing)
EMBED_LINK = -1


@dataclasses.dataclass
class PipelineTrainer:
    """S-stage pipeline execution of one model over micro-batches."""

    cfg: ArchConfig
    optimizer: Optimizer
    num_stages: int = 2
    num_microbatches: int = 1
    schedule_name: str = "1f1b"
    aux_weight: float = 0.01
    partition: Optional[StagePartition] = None   # default: uniform loads
    stage_devices: Optional[Sequence[Any]] = None
    planner: Optional[Any] = None                # transfer-planning seam
    transfer_strategy: str = "dynacomm"
    costs: Optional[LayerCosts] = None           # for timeline()/plans
    net: Optional[Any] = None                    # EdgeNetworkModel-like
    transfer_chunks: int = 1

    def __post_init__(self):
        self.num_layers = model_lib.num_sched_layers(self.cfg)
        if not 1 <= self.num_stages <= self.num_layers:
            raise ValueError(
                f"num_stages must be in [1, {self.num_layers}] "
                f"(sched layers), got {self.num_stages}")
        if self.num_microbatches < 1:
            raise ValueError(f"num_microbatches must be >= 1, got "
                             f"{self.num_microbatches}")
        if self.partition is None:
            self.partition = partition_loads(
                [1.0] * self.num_layers, self.num_stages)
        if self.partition.num_stages != self.num_stages or \
                self.partition.num_layers != self.num_layers:
            raise ValueError(
                f"partition covers {self.partition.num_layers} layers in "
                f"{self.partition.num_stages} stages; trainer wants "
                f"{self.num_layers} layers in {self.num_stages} stages")
        if self.stage_devices is not None and \
                len(self.stage_devices) != self.num_stages:
            raise ValueError("need one device per stage")
        self.schedule: PipelineSchedule = make_schedule(
            self.schedule_name, self.num_stages, self.num_microbatches)

        shapes = jax.eval_shape(
            lambda k: model_lib.init_params(self.cfg, k, jnp.float32),
            jax.random.PRNGKey(0))
        self.specs: List[FlatSpec] = [
            make_flat_spec(tree, 1)
            for tree in model_lib.sched_layer_trees(shapes)]
        self._kinds = self.cfg.layer_kinds()
        self._ledger = TransferLedger()
        self._bspecs: Optional[List[FlatSpec]] = None  # per boundary
        self._fwd_fns = None
        self._bwd_fns = None
        self._transfer_plans: Optional[List[TransferPlan]] = None
        self._den_fn = jax.jit(self._mask_den)
        self._update_fn = jax.jit(self.optimizer.update)
        aw = self.aux_weight / self.num_microbatches

        def combine(nums, den, auxs):
            num = nums[0]
            for x in nums[1:]:
                num = num + x
            aux = auxs[0]
            for a in auxs[1:]:
                aux = aux + a
            return num / den + jnp.asarray(aw, jnp.float32) * aux
        self._combine_fn = jax.jit(combine)

    # ------------------------------------------------------------------
    # per-sched-layer applies (identical math to the ZeroTrainer's)
    # ------------------------------------------------------------------

    def _apply_embed(self, embed_tree, batch):
        return model_lib._embed_inputs(self.cfg, {"embed": embed_tree}, batch)

    def _apply_block(self, block_tree, x, kind):
        y, _, aux = blocks_lib.apply_block(block_tree, x, self.cfg, kind,
                                           mode="train", cache=None)
        return y, aux

    def _padded_labels(self, logits, batch):
        labels = batch["labels"]
        if self.cfg.frontend == "vision":
            nv = logits.shape[1] - labels.shape[1]
            pad = jnp.full(labels.shape[:1] + (nv,), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return labels

    def _ce_num(self, final_tree, embed_tree, x, batch):
        """The numerator of ``cross_entropy`` — same ops, no division."""
        logits = model_lib._head(
            self.cfg, {"embed": embed_tree, "final": final_tree}, x)
        labels = self._padded_labels(logits, batch)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        x32 = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(x32, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(x32 - m), axis=-1)) + m[..., 0]
        iota = jax.lax.broadcasted_iota(jnp.int32, x32.shape, x32.ndim - 1)
        picked = jnp.sum(jnp.where(iota == safe[..., None], x32, 0.0),
                         axis=-1)
        return jnp.sum((lse - picked) * mask)

    def _mask_den(self, batch):
        """``cross_entropy``'s denominator from the full batch's labels."""
        labels = batch["labels"]
        if self.cfg.frontend == "vision" and "vision_embeds" in batch:
            nv = batch["vision_embeds"].shape[1]
            pad = jnp.full(labels.shape[:1] + (nv,), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        mask = (labels >= 0).astype(jnp.float32)
        return jnp.maximum(jnp.sum(mask), 1.0)

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------

    def init_state(self, key) -> Dict[str, Any]:
        """Identical init to the single-device reference, flattened."""
        def make(k):
            params = model_lib.init_params(self.cfg, k, jnp.float32)
            flats = [flatten_tree(tree, spec) for tree, spec in
                     zip(model_lib.sched_layer_trees(params), self.specs)]
            return {"flat_params": flats,
                    "opt": self.optimizer.init(flats),
                    "step": jnp.zeros((), jnp.int32)}
        state = jax.jit(make)(key)
        return self._place_state(state)

    def _place_state(self, state):
        """Pin each stage's buffers to its device when stages are placed."""
        if self.stage_devices is None:
            return state
        stage_of = self.partition.stage_of

        def put(l, leaf):
            return jax.device_put(leaf, self.stage_devices[stage_of[l]])
        state = dict(state)
        state["flat_params"] = [put(l, f)
                                for l, f in enumerate(state["flat_params"])]
        return state

    def params_from_state(self, state) -> Any:
        trees = [unflatten_tree(jnp.asarray(f), spec)
                 for f, spec in zip(state["flat_params"], self.specs)]
        return model_lib.params_from_sched_layers(trees)

    # ------------------------------------------------------------------
    # per-stage compiled applies
    # ------------------------------------------------------------------

    def _stage_flats(self, state, s: int) -> Tuple[Any, ...]:
        return tuple(state["flat_params"][l]
                     for l in self.partition.layers_of(s))

    def _make_fwd(self, s: int, bspec_in: Optional[FlatSpec],
                  bspec_out: Optional[FlatSpec]):
        """Stage forward; emits the boundary activation as its FlatSpec
        flat buffer (raw when ``bspec_out`` is None — the shape probe)."""
        layers = self.partition.layers_of(s)
        Ls, kinds = self.num_layers, self._kinds
        has_embed = 0 in layers
        has_head = (Ls - 1) in layers

        def fwd(flats_s, *args):
            trees = {l: unflatten_tree(f, self.specs[l])
                     for l, f in zip(layers, flats_s)}
            i = 0
            if has_embed:
                batch = args[i]; i += 1
                h = self._apply_embed(trees[0], batch)
            else:
                h = unflatten_tree(args[i], bspec_in); i += 1
                if has_head:
                    batch = args[i]; i += 1
            aux = jnp.zeros((), jnp.float32)
            for l in layers:
                if l == 0 or l == Ls - 1:
                    continue
                h, a = self._apply_block(trees[l], h, kinds[l - 1])
                aux = aux + a
            if has_head:
                embed_tree = trees[0] if has_embed \
                    else unflatten_tree(args[i], self.specs[0])
                num = self._ce_num(trees[Ls - 1], embed_tree, h, batch)
                return num, aux
            if bspec_out is not None:
                h = flatten_tree(h, bspec_out)
            return h, aux
        return fwd

    def _make_bwd(self, s: int, bspec_in: Optional[FlatSpec],
                  bspec_out: Optional[FlatSpec]):
        """Stage backward: recompute forward stage-locally, then the same
        descending per-layer VJP loop as the ZeroTrainer."""
        layers = self.partition.layers_of(s)
        Ls, kinds = self.num_layers, self._kinds
        has_embed = 0 in layers
        has_head = (Ls - 1) in layers
        aux_ct_val = self.aux_weight / self.num_microbatches

        def bwd(flats_s, *args):
            trees = {l: unflatten_tree(f, self.specs[l])
                     for l, f in zip(layers, flats_s)}
            i = 0
            if has_embed:
                batch = args[i]; i += 1
                h = self._apply_embed(trees[0], batch)
            else:
                h_in = unflatten_tree(args[i], bspec_in); i += 1
                h = h_in
                if has_head:
                    batch = args[i]; i += 1
            if has_head:
                embed_tree = trees[0] if has_embed \
                    else unflatten_tree(args[i], self.specs[0])
                if not has_embed:
                    i += 1
                den = args[i]; i += 1
            else:
                ct_in = unflatten_tree(args[i], bspec_out); i += 1

            # ---- recompute forward, saving each layer's input ----------
            acts: Dict[int, jnp.ndarray] = {}
            for l in layers:
                if l == 0 or l == Ls - 1:
                    continue
                acts[l] = h
                h, _ = self._apply_block(trees[l], h, kinds[l - 1])
            if has_head:
                acts[Ls - 1] = h

            # ---- descending per-layer VJPs -----------------------------
            one = jnp.ones((), jnp.float32)
            aux_ct = jnp.asarray(aux_ct_val, jnp.float32)
            grads: Dict[int, Any] = {}
            embed_from_head = None
            ct_h = None if has_head else ct_in
            for l in reversed(layers):
                if l == Ls - 1:
                    _, vjp = jax.vjp(
                        lambda pf, pe, hh: self._ce_num(pf, pe, hh,
                                                        batch) / den,
                        trees[l], embed_tree, acts[l])
                    g_final, embed_from_head, ct_h = vjp(one)
                    grads[l] = g_final
                elif l == 0:
                    _, vjp = jax.vjp(
                        lambda pe: self._apply_embed(pe, batch), trees[0])
                    (g_embed,) = vjp(ct_h)
                    if embed_from_head is not None:   # head in same stage
                        g_embed = jax.tree_util.tree_map(
                            jnp.add, g_embed, embed_from_head)
                        embed_from_head = None
                    grads[0] = g_embed
                else:
                    kind = kinds[l - 1]
                    _, vjp = jax.vjp(
                        lambda p, hh, _k=kind: self._apply_block(p, hh, _k),
                        trees[l], acts[l])
                    g_block, ct_h = vjp((ct_h, aux_ct))
                    grads[l] = g_block

            gflats = tuple(flatten_tree(grads[l], self.specs[l])
                           for l in layers)
            outs: List[Any] = [gflats]
            if not has_embed:     # cotangent for the incoming boundary
                outs.append(flatten_tree(ct_h, bspec_in))
            if has_head and not has_embed:  # tied-head embedding grad home
                outs.append(flatten_tree(embed_from_head, self.specs[0]))
            return tuple(outs)
        return bwd

    def _ensure_compiled(self, batch) -> None:
        if self._fwd_fns is not None:
            return
        micro = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // self.num_microbatches,) + tuple(x.shape[1:]),
                x.dtype), batch)
        flat_structs = [jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
                        for spec in self.specs]

        bspecs: List[FlatSpec] = []
        fwd_fns = []
        h_struct = None
        for s in range(self.num_stages):
            bspec_in = bspecs[s - 1] if s > 0 else None
            raw = self._make_fwd(s, bspec_in, None)
            flats_s = tuple(flat_structs[l]
                            for l in self.partition.layers_of(s))
            args = self._fwd_args_struct(s, h_struct, micro, flat_structs)
            out = jax.eval_shape(raw, flats_s, *args)
            if s < self.num_stages - 1:
                bspec = make_flat_spec(out[0], 1)
                bspecs.append(bspec)
                h_struct = jax.ShapeDtypeStruct((bspec.padded,), jnp.float32)
                fwd_fns.append(jax.jit(self._make_fwd(s, bspec_in, bspec)))
            else:
                fwd_fns.append(jax.jit(raw))
        self._bspecs = bspecs
        self._fwd_fns = fwd_fns
        self._bwd_fns = [
            jax.jit(self._make_bwd(
                s,
                bspecs[s - 1] if s > 0 else None,
                bspecs[s] if s < self.num_stages - 1 else None))
            for s in range(self.num_stages)]

    def _fwd_args_struct(self, s, h_struct, micro, flat_structs):
        layers = self.partition.layers_of(s)
        has_embed = 0 in layers
        has_head = (self.num_layers - 1) in layers
        args: List[Any] = []
        if has_embed:
            args.append(micro)
        else:
            args.append(h_struct)
            if has_head:
                args.append(micro)
        if has_head and not has_embed:
            args.append(flat_structs[0])
        return tuple(args)

    def _bwd_args_struct(self, s, micro, flat_structs):
        layers = self.partition.layers_of(s)
        has_embed = 0 in layers
        has_head = (self.num_layers - 1) in layers
        bspec_in = self._bspecs[s - 1] if s > 0 else None
        args: List[Any] = []
        if has_embed:
            args.append(micro)
        else:
            args.append(jax.ShapeDtypeStruct((bspec_in.padded,),
                                             jnp.float32))
            if has_head:
                args.append(micro)
        if has_head:
            if not has_embed:
                args.append(flat_structs[0])
            args.append(jax.ShapeDtypeStruct((), jnp.float32))
        else:
            bspec_out = self._bspecs[s]
            args.append(jax.ShapeDtypeStruct((bspec_out.padded,),
                                             jnp.float32))
        return tuple(args)

    def stage_hlo(self, batch) -> List[Tuple[str, str]]:
        """Compiled (forward, backward) HLO text per stage.

        The conformance pass asserts each per-stage program contains zero
        cross-replica collectives: every inter-stage byte moves through
        the explicit boundary buffers the ledger accounts, never through
        a collective XLA slipped in."""
        self._ensure_compiled(batch)
        micro = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // self.num_microbatches,) + tuple(x.shape[1:]),
                x.dtype), batch)
        flat_structs = [jax.ShapeDtypeStruct((spec.padded,), jnp.float32)
                        for spec in self.specs]
        out = []
        for s in range(self.num_stages):
            flats_s = tuple(flat_structs[l]
                            for l in self.partition.layers_of(s))
            h_struct = None
            if s > 0:
                h_struct = jax.ShapeDtypeStruct(
                    (self._bspecs[s - 1].padded,), jnp.float32)
            fargs = self._fwd_args_struct(s, h_struct, micro, flat_structs)
            bargs = self._bwd_args_struct(s, micro, flat_structs)
            out.append((
                self._fwd_fns[s].lower(flats_s, *fargs).compile().as_text(),
                self._bwd_fns[s].lower(flats_s, *bargs).compile().as_text(),
            ))
        return out

    # ------------------------------------------------------------------
    # the train step (host-driven per-stage pipeline)
    # ------------------------------------------------------------------

    def _split(self, batch) -> List[Any]:
        M = self.num_microbatches
        b0 = jax.tree_util.tree_leaves(batch)[0].shape[0]
        if b0 % M:
            raise ValueError(f"batch size {b0} not divisible by "
                             f"{M} micro-batches")
        mbs = b0 // M
        return [jax.tree_util.tree_map(
                    lambda x: x[m * mbs:(m + 1) * mbs], batch)
                for m in range(M)]

    def _put(self, x, s: int):
        if self.stage_devices is None:
            return x
        return jax.device_put(x, self.stage_devices[s])

    def step(self, state, batch):
        """One optimizer step; returns ``(new_state, loss)``.

        Forward then backward over all micro-batches, stage by stage on
        the host; the :class:`PipelineSchedule` orders the same task set
        on real hardware (and prices it in :meth:`timeline`) — the loss
        and gradients are order-invariant, so the host replay executes
        stages in dependency order."""
        self._ensure_compiled(batch)
        S, M, Ls = self.num_stages, self.num_microbatches, self.num_layers
        micros = self._split(batch)
        den = self._den_fn(self._put(batch, S - 1))
        embed_flat = None
        if S > 1:
            embed_flat = self._put(state["flat_params"][0], S - 1)
            self._ledger.record_pull(EMBED_LINK, self.specs[0].total * 4)
        stage_flats = [tuple(self._put(f, s) for f in
                             self._stage_flats(state, s))
                       for s in range(S)]

        # ---- forward: boundary activations flow down the stages --------
        bnd: List[List[Any]] = [[] for _ in range(M)]   # bnd[m][b] = flat
        nums, auxs = [], []
        for m, mb in enumerate(micros):
            h = None
            for s in range(S):
                args = self._fwd_call_args(s, h, mb, embed_flat)
                out, aux_sm = self._fwd_fns[s](stage_flats[s], *args)
                auxs.append(aux_sm)
                if s < S - 1:
                    h = self._put(out, s + 1)
                    bnd[m].append(h)
                    self._ledger.record_pull(s, self._bspecs[s].total * 4)
                else:
                    nums.append(out)

        # ---- backward: per-stage VJPs, activation grads flow back ------
        acc: List[Optional[Any]] = [None] * Ls
        embed_home = None
        for m, mb in enumerate(micros):
            ct = None
            for s in reversed(range(S)):
                args = self._bwd_call_args(s, m, mb, embed_flat, den, ct,
                                           bnd)
                outs = self._bwd_fns[s](stage_flats[s], *args)
                gflats = outs[0]
                if s > 0:
                    ct = self._put(outs[1], s - 1)
                    self._ledger.record_push(
                        s - 1, self._bspecs[s - 1].total * 4)
                if s == S - 1 and s > 0:
                    efh = self._put(outs[2], 0)
                    self._ledger.record_push(EMBED_LINK,
                                             self.specs[0].total * 4)
                    embed_home = efh if embed_home is None \
                        else jnp.add(embed_home, efh)
                for l, g in zip(self.partition.layers_of(s), gflats):
                    acc[l] = g if acc[l] is None else jnp.add(acc[l], g)
        if embed_home is not None:
            acc[0] = jnp.add(acc[0], embed_home)

        # ---- combine loss + shared optimizer update --------------------
        loss = self._combine_fn(
            tuple(self._put(n, 0) for n in nums), self._put(den, 0),
            tuple(self._put(a, 0) for a in auxs))
        flats_in, opt_in = state["flat_params"], state["opt"]
        if self.stage_devices is not None:
            d0 = self.stage_devices[0]
            flats_in = [jax.device_put(f, d0) for f in flats_in]
            acc = [jax.device_put(g, d0) for g in acc]
            opt_in = jax.device_put(opt_in, d0)
        new_flats, new_opt = self._update_fn(acc, opt_in, flats_in)
        new_state = {"flat_params": new_flats, "opt": new_opt,
                     "step": state["step"] + 1}
        return self._place_state(new_state), loss

    def _fwd_call_args(self, s, h, mb, embed_flat):
        layers = self.partition.layers_of(s)
        has_embed = 0 in layers
        has_head = (self.num_layers - 1) in layers
        mb_s = self._put(mb, s) if (has_embed or has_head) else None
        args: List[Any] = []
        if has_embed:
            args.append(mb_s)
        else:
            args.append(h)
            if has_head:
                args.append(mb_s)
        if has_head and not has_embed:
            args.append(embed_flat)
        return tuple(args)

    def _bwd_call_args(self, s, m, mb, embed_flat, den, ct, bnd):
        layers = self.partition.layers_of(s)
        has_embed = 0 in layers
        has_head = (self.num_layers - 1) in layers
        mb_s = self._put(mb, s) if (has_embed or has_head) else None
        args: List[Any] = []
        if has_embed:
            args.append(mb_s)
        else:
            args.append(bnd[m][s - 1])
            if has_head:
                args.append(mb_s)
        if has_head:
            if not has_embed:
                args.append(embed_flat)
            args.append(self._put(den, s))
        else:
            args.append(ct)
        return tuple(args)

    # ------------------------------------------------------------------
    # accounting / cost-model views
    # ------------------------------------------------------------------

    @property
    def ledger(self) -> Dict[str, Any]:
        led = self._ledger
        return {"pull_bytes": sum(led.pulled_bytes.values()),
                "push_bytes": sum(led.pushed_bytes.values()),
                "pull_wire_bytes": sum(led.pulled_wire_bytes.values()),
                "push_wire_bytes": sum(led.pushed_wire_bytes.values()),
                "num_pulls": led.num_pulls,
                "num_pushes": led.num_pushes,
                "boundary_pull_bytes": dict(led.pulled_bytes),
                "boundary_push_bytes": dict(led.pushed_bytes)}

    def stage_times(self, costs: LayerCosts) -> Tuple[List[float],
                                                      List[float]]:
        """Per-stage per-micro-batch (fwd, bwd) seconds from cost vectors."""
        M = self.num_microbatches
        fwd, bwd = [], []
        for s in range(self.num_stages):
            ls = self.partition.layers_of(s)
            fwd.append(float(sum(costs.fc[l] for l in ls)) / M)
            bwd.append(float(sum(costs.bc[l] for l in ls)) / M)
        return fwd, bwd

    def activation_bytes(self) -> List[int]:
        """Per-boundary micro-batch activation bytes (needs a compiled
        step: boundary shapes come from the first batch)."""
        if self._bspecs is None:
            raise RuntimeError("no boundary specs yet: run a step first")
        return [spec.total * 4 for spec in self._bspecs]

    def transfer_plans(self) -> Optional[List[TransferPlan]]:
        """DynaComm-segmented plan per boundary (None before first step
        or without ``costs``/``net``)."""
        if self._transfer_plans is not None:
            return self._transfer_plans
        if self.costs is None or self.net is None or self._bspecs is None:
            return None
        fwd, bwd = self.stage_times(self.costs)
        plans = []
        for b, nbytes in enumerate(self.activation_bytes()):
            c = boundary_costs(nbytes, self.num_microbatches, net=self.net,
                               stage_fwd_s=fwd[b + 1], stage_bwd_s=bwd[b + 1],
                               chunks=self.transfer_chunks)
            plans.append(plan_boundary(b, c, planner=self.planner,
                                       strategy=self.transfer_strategy,
                                       microbatches=self.num_microbatches,
                                       chunks=self.transfer_chunks))
        self._transfer_plans = plans
        return plans

    def timeline(self) -> Optional[PipelineTimeline]:
        """Simulated replay of the active schedule under the cost model,
        with DynaComm-segmented effective boundary waits."""
        if self.costs is None:
            return None
        fwd, bwd = self.stage_times(self.costs)
        plans = self.transfer_plans()
        if plans:
            fx = [p.effective_waits[0] for p in plans]
            bx = [p.effective_waits[1] for p in plans]
        else:
            fx = bx = None
        return simulate(self.schedule, fwd, bwd,
                        fwd_transfer=fx, bwd_transfer=bx)
