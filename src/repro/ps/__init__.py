"""Parameter-Server execution subsystem — the paper's actual topology.

``repro.dist`` executes DynaComm plans through symmetric ZeRO collectives
(the TPU-native adaptation); this package executes them in the paper's
own deployment shape: S server shards × W edge workers, segmented
parameter pulls down and gradient pushes up over per-worker asymmetric
links, synchronously (``PSTrainer``, bit-identical to the ZeRO trainer)
or asynchronously under a bounded staleness ``k`` (``AsyncPSTrainer``,
with server-side rejection or SSP wait-at-barrier throttling).

``TopologySchedule`` makes the fabric time-varying, and the
``repro.ps.dynamic`` drivers re-derive the decomposition once per
topology epoch — the paper's run-time loop in the PS regime.
"""

from repro.ps.async_mode import (THROTTLES, AsyncPSTrainer, AsyncPushEvent,
                                 AsyncRunLog)
from repro.ps.dynamic import (AsyncRescheduleEvent, DynamicAsyncPSTrainer,
                              DynamicPSTrainer, profiles_from_specs)
from repro.ps.server import (PSServer, PushResult, StaleVersion,
                             TransferLedger)
from repro.ps.topology import (LinkModel, PSTopology, TopologySchedule,
                               as_topology_schedule, asymmetric_link,
                               uplink_degradation)
from repro.ps.worker import PSTrainer

__all__ = [
    "LinkModel", "PSTopology", "asymmetric_link",
    "TopologySchedule", "as_topology_schedule", "uplink_degradation",
    "PSServer", "PushResult", "StaleVersion", "TransferLedger",
    "PSTrainer",
    "THROTTLES", "AsyncPSTrainer", "AsyncPushEvent", "AsyncRunLog",
    "AsyncRescheduleEvent", "DynamicAsyncPSTrainer", "DynamicPSTrainer",
    "profiles_from_specs",
]
