"""Elastic-fleet demo: membership churn on the deterministic event engine.

Two acts:

1. **the registered runtime** — a ``fleet-async`` ``RuntimeConfig`` with
   a scripted membership schedule (a worker joins, another crashes
   mid-push) on the reduced text arch.  Each membership event re-plans
   every surviving worker through the topology scheduler, and with
   ``workers_per_shard`` the server re-shards in place, migrating
   versioned state (parameters + optimizer moments) without losing a
   byte — the post-migration pull equals the pre-migration snapshot
   bit-exactly.  ``fit(checkpoint_every=...)`` writes periodic
   checkpoints that include the live event loop, so the resumed run
   replays the remaining pushes bit-identically.
2. **silent failures** — the library API on the smoke CNN: a worker
   stalls (it just stops committing — nothing is announced) and the
   stall detector evicts it after ``stall_factor`` times its believed
   iteration time; another worker silently slows down 6x and the
   measured drift detector re-plans from its observed commit gaps.

    PYTHONPATH=src python examples/elastic_fleet.py
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet import (FleetEvent, FleetSchedule, FleetTrainer,
                         WorkerSpec)
from repro.models.cnn import small_cnn_init, small_cnn_loss
from repro.optim import sgd
from repro.runtime import (ExecutionConfig, FleetConfig, FleetEventConfig,
                           RuntimeConfig, ScheduleConfig, TopologyConfig,
                           build_runtime)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--pushes", type=int, default=12)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    args = ap.parse_args()

    # --- 1. the fleet-async runtime with scripted churn ----------------
    config = RuntimeConfig(
        runtime="fleet-async", arch=args.arch, reduced=True,
        batch=args.batch, seq=args.seq, optimizer="adamw", lr=1e-3,
        schedule=ScheduleConfig(topology=TopologyConfig(
            servers=2, workers=args.workers)),
        execution=ExecutionConfig(staleness=2, throttle="wait"),
        fleet=FleetConfig(events=(
            FleetEventConfig(time=0.01, kind="join", worker=args.workers,
                             down_gbps=5.0, up_gbps=0.5),
            FleetEventConfig(time=0.03, kind="fail", worker=1,
                             mode="crash"),
        ), workers_per_shard=2))
    rt = build_runtime(config)

    print(f"fleet-async on {config.arch} (reduced), "
          f"{args.workers} workers + scripted join/crash:")
    with tempfile.TemporaryDirectory() as tmp:
        ck = os.path.join(tmp, "fleet.npz")
        half = args.pushes // 2
        losses = rt.fit(half, checkpoint_every=half, checkpoint_path=ck)
        rest = rt.fit(args.pushes - half)

        # a second adapter restored from the periodic checkpoint replays
        # the remaining pushes bit-identically (loop state included)
        rt2 = build_runtime(config)
        rt2.restore_state(ck)
        rest2 = rt2.fit(args.pushes - half)
        print(f"  {len(losses + rest)} pushes, final loss "
              f"{rest[-1]:.4f}; resumed-from-checkpoint tail "
              f"{'bit-identical' if rest == rest2 else 'DIVERGED'}")

    for e in rt.events:
        if hasattr(e, "resharded"):
            extra = (f", resharded to {e.num_servers} shards "
                     f"({e.migrated_bytes / 1e6:.2f} MB migrated)"
                     if e.resharded else "")
            print(f"  t={e.sim_time:.3f} re-plan ({e.reason}): "
                  f"{e.num_workers} workers{extra}")

    # --- 2. silent failures: stall eviction + measured drift -----------
    params = small_cnn_init(jax.random.PRNGKey(0))

    def loss_fn(layers, batch):
        return small_cnn_loss({"layers": layers}, batch["images"],
                              batch["labels"])

    def batch_fn(w, i):
        r = np.random.default_rng(100003 * w + i)
        return {"images": jnp.asarray(r.normal(size=(2, 32, 32, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, 10, size=(2,)),
                                      jnp.int32)}

    # compute-heavy specs so a drifted compute rate moves the commit gap;
    # the drift (2.5x) stays under the stall factor (4x), so the slowed
    # worker keeps committing and the DRIFT detector — not the stall
    # check — is what reacts
    specs = {w: WorkerSpec(down_bps=100e9, up_bps=100e9, flops=1e8)
             for w in range(4)}
    schedule = FleetSchedule((
        FleetEvent(time=0.5, kind="drift", worker=0, factor=2.5),
        FleetEvent(time=1.0, kind="fail", worker=3, mode="stall"),
    ))
    tr = FleetTrainer(
        init_layers=params["layers"], loss_fn=loss_fn,
        optimizer=sgd(0.05, 0.9), workers=specs, schedule=schedule,
        num_servers=2, staleness=2, throttle="wait", stall_factor=4.0)
    log = tr.run(60, batch_fn)

    print("\nsmoke CNN, 4 workers: worker 0 silently drifts 2.5x slower "
          "at t=0.5, worker 3 silently stalls at t=1.0:")
    for e in tr.membership_events:
        print(f"  t={e.sim_time:.3f} {e.kind} worker {e.worker} "
              f"(fleet size {e.fleet_size})")
    drift_replans = [e for e in tr.replan_events if e.reason == "drift"]
    stall_evicts = [e for e in tr.membership_events
                    if e.kind == "stall-evict"]
    print(f"  {len(log.accepted)} pushes, max staleness "
          f"{log.max_staleness} <= k=2; drift re-plans: "
          f"{len(drift_replans)}, stall evictions: {len(stall_evicts)}")
    print("  -> nothing was scripted for the planner: the drift was "
          "*measured* from commit gaps, the stall was *detected* by the "
          "overdue-commit check")


if __name__ == "__main__":
    main()
