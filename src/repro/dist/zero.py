"""DynaComm-bucketed ZeRO trainer.

The TPU-native adaptation of the paper's pull/push procedures: a
``BucketPlan`` (from ``repro.core.buckets``) drives a data-parallel training
step in which

* parameters live sharded as one padded flat float32 buffer per sched layer
  (``state["flat_params"][l]`` has global shape ``(spec.padded,)`` split over
  the ``data`` axis — ZeRO: optimizer state and master weights are never
  replicated);
* the forward phase launches **exactly one all-gather per forward bucket**
  (the paper's parameter pull of a transmission segment);
* the backward phase launches **exactly one reduce-scatter per backward
  bucket** (the gradient push), walking layers top-down with per-layer VJPs
  so bucket boundaries are real program structure, not a post-hoc rewrite;
* with ``zero3=True`` the gathered weights are *not* kept alive across the
  forward/backward boundary: every backward bucket that contains a middle
  layer re-pulls its parameters with one extra all-gather (first/last sched
  layers are exempt — the head is hot at the fwd→bwd boundary and the
  embedding VJP needs no weights).

The step is built with ``shard_map`` so the collectives above are the
*only* all-gathers / reduce-scatters in the compiled HLO —
``tests/test_dist.py`` asserts the counts against the plan.
"""

from __future__ import annotations

import copy
import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.buckets import BucketPlan, flat_layer_order
from repro.dist.collectives import (FlatSpec, compressed_reduce_scatter_bucket,
                                    flatten_tree, gather_bucket,
                                    make_flat_spec, reduce_scatter_bucket,
                                    unflatten_tree)
from repro.models import blocks as blocks_lib
from repro.models import model as model_lib
from repro.optim import Optimizer


@dataclasses.dataclass
class ZeroTrainer:
    """Bucketed ZeRO data-parallel trainer over a 1-D ``data`` mesh axis."""

    cfg: ArchConfig
    mesh: Mesh
    plan: BucketPlan
    optimizer: Optimizer
    zero3: bool = False
    axis_name: str = "data"
    aux_weight: float = 0.01
    compressor: Optional[Any] = None

    def __post_init__(self):
        if self.compressor is not None and self.compressor.scheme == "none":
            self.compressor = None        # identity: skip the wrapper math
        if self.axis_name not in self.mesh.axis_names:
            raise ValueError(f"mesh has no {self.axis_name!r} axis: "
                             f"{self.mesh.axis_names}")
        self.axis_size = int(self.mesh.shape[self.axis_name])
        self.num_layers = model_lib.num_sched_layers(self.cfg)
        self._validate_plan()

        shapes = jax.eval_shape(
            lambda k: model_lib.init_params(self.cfg, k, jnp.float32),
            jax.random.PRNGKey(0))
        self.specs: List[FlatSpec] = [
            make_flat_spec(tree, self.axis_size)
            for tree in model_lib.sched_layer_trees(shapes)]
        self._kinds = self.cfg.layer_kinds()

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------

    def _validate_plan(self) -> None:
        Ls = self.num_layers
        fwd = flat_layer_order(self.plan.forward)
        bwd = flat_layer_order(self.plan.backward)
        if fwd != tuple(range(Ls)):
            raise ValueError(f"forward buckets {self.plan.forward} do not "
                             f"pull layers 0..{Ls - 1} in order")
        if bwd != tuple(range(Ls - 1, -1, -1)):
            raise ValueError(f"backward buckets {self.plan.backward} do not "
                             f"push layers {Ls - 1}..0 in order")

    def with_plan(self, plan: BucketPlan) -> "ZeroTrainer":
        """Same trainer driving a different bucket plan.

        The state layout (``FlatSpec`` per sched layer) depends only on the
        architecture and the axis size, never on the plan — so states carry
        across plan swaps unchanged.  Shares the already-computed specs
        instead of re-running ``eval_shape``.
        """
        new = copy.copy(self)
        new.plan = plan
        new._validate_plan()
        return new

    def _flat_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_name))

    def _replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------

    @property
    def _use_residuals(self) -> bool:
        return self.compressor is not None and self.compressor.error_feedback

    def _make_state(self, key) -> Dict[str, Any]:
        params = model_lib.init_params(self.cfg, key, jnp.float32)
        flats = [flatten_tree(tree, spec) for tree, spec in
                 zip(model_lib.sched_layer_trees(params), self.specs)]
        state = {"flat_params": flats,
                 "opt": self.optimizer.init(flats),
                 "step": jnp.zeros((), jnp.int32)}
        if self._use_residuals:
            # error-feedback residual of each device's own compressed push:
            # row d is device d's (padded,) carry for that sched layer
            state["residuals"] = [
                jnp.zeros((self.axis_size, spec.padded), jnp.float32)
                for spec in self.specs]
        return state

    def _state_layout(self, shapes, one_d, replicated, residual):
        """Map state leaves to shardings/specs: flat buffers by ndim, the
        error-feedback residuals (2-D, one row per device) explicitly."""
        out = {k: jax.tree_util.tree_map(
                   lambda s: one_d if s.ndim == 1 else replicated, v)
               for k, v in shapes.items() if k != "residuals"}
        if "residuals" in shapes:
            out["residuals"] = [residual for _ in shapes["residuals"]]
        return out

    def init_state(self, key) -> Dict[str, Any]:
        """Init identical to ``init_params(cfg, key)`` then flatten + shard."""
        shapes = jax.eval_shape(self._make_state, key)
        out_sh = self._state_layout(
            shapes, self._flat_sharding(), self._replicated(),
            NamedSharding(self.mesh, P(self.axis_name, None)))
        return jax.jit(self._make_state, out_shardings=out_sh)(key)

    # ------------------------------------------------------------------
    # per-sched-layer applies (closed over cfg; used forward AND in VJPs)
    # ------------------------------------------------------------------

    def _apply_embed(self, embed_tree, batch):
        return model_lib._embed_inputs(self.cfg, {"embed": embed_tree}, batch)

    def _apply_block(self, block_tree, x, kind):
        y, _, aux = blocks_lib.apply_block(block_tree, x, self.cfg, kind,
                                           mode="train", cache=None)
        return y, aux

    def _apply_final(self, final_tree, embed_tree, x, batch):
        """Final norm + (possibly embedding-tied) head + masked CE."""
        logits = model_lib._head(
            self.cfg, {"embed": embed_tree, "final": final_tree}, x)
        labels = batch["labels"]
        if self.cfg.frontend == "vision":
            nv = logits.shape[1] - labels.shape[1]
            pad = jnp.full(labels.shape[:1] + (nv,), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return model_lib.cross_entropy(logits, labels)

    # ------------------------------------------------------------------
    # the train step
    # ------------------------------------------------------------------

    def build_train_step(self):
        """Returns jit-able ``step(state, batch) -> (state, mean_loss)``."""
        state_shapes = jax.eval_shape(self._make_state, jax.random.PRNGKey(0))
        state_specs = self._state_layout(
            state_shapes, P(self.axis_name), P(), P(self.axis_name, None))

        def step(state, batch):
            batch_specs = jax.tree_util.tree_map(
                lambda b: P(self.axis_name, *([None] * (b.ndim - 1))), batch)
            fn = shard_map(self._local_step, mesh=self.mesh,
                           in_specs=(state_specs, batch_specs),
                           out_specs=(state_specs, P()),
                           check_rep=False)
            return fn(state, batch)

        return step

    def _local_step(self, state, batch):
        Ls, kinds = self.num_layers, self._kinds
        shards = list(state["flat_params"])
        res_local = state.get("residuals")     # local views: (1, padded_l)
        new_res = list(res_local) if res_local is not None else None

        # ---- pull phase: one all-gather per forward bucket --------------
        full: Dict[int, Any] = {}
        for bucket in self.plan.forward:
            full.update(gather_bucket(shards, self.specs, bucket,
                                      self.axis_name))

        # ---- forward, saving each layer's input activation --------------
        acts: Dict[int, jnp.ndarray] = {}
        aux = jnp.zeros((), jnp.float32)
        h = self._apply_embed(full[0], batch)
        for l in range(1, Ls - 1):
            acts[l] = h
            h, a = self._apply_block(full[l], h, kinds[l - 1])
            aux = aux + a
        acts[Ls - 1] = h
        ce = self._apply_final(full[Ls - 1], full[0], h, batch)
        loss_local = ce + self.aux_weight * aux

        # ---- ZeRO-3: re-pull mid-layer buckets for the backward ---------
        # The barrier keeps the re-gather a distinct program point from the
        # forward pull (so the forward copies are dead after their last
        # forward use and the re-gather cannot be folded into them).
        regathered: Dict[int, Any] = {}
        if self.zero3:
            barred = list(jax.lax.optimization_barrier(tuple(shards)))
            for bucket in self.plan.backward:
                if any(0 < l < Ls - 1 for l in bucket):
                    regathered.update(gather_bucket(barred, self.specs,
                                                    bucket, self.axis_name))

        # ---- backward: per-layer VJPs, one reduce-scatter per bucket ----
        one = jnp.ones((), jnp.float32)
        aux_ct = jnp.asarray(self.aux_weight, jnp.float32)
        grad_shards: List[Optional[jnp.ndarray]] = [None] * Ls
        embed_from_head = None     # tied-head contribution to the embedding
        ct_h = None                # cotangent w.r.t. the current activation
        for bucket in self.plan.backward:
            bucket_grads: Dict[int, Any] = {}
            for l in bucket:       # descending layer order within the bucket
                p_l = regathered.get(l, full[l])
                if l == Ls - 1:
                    _, vjp = jax.vjp(
                        lambda pf, pe, hh: self._apply_final(pf, pe, hh,
                                                             batch),
                        p_l, full[0], acts[l])
                    g_final, embed_from_head, ct_h = vjp(one)
                    bucket_grads[l] = g_final
                elif l == 0:
                    _, vjp = jax.vjp(
                        lambda pe: self._apply_embed(pe, batch), p_l)
                    (g_embed,) = vjp(ct_h)
                    bucket_grads[l] = jax.tree_util.tree_map(
                        jnp.add, g_embed, embed_from_head)
                else:
                    kind = kinds[l - 1]
                    _, vjp = jax.vjp(
                        lambda p, hh, _k=kind: self._apply_block(p, hh, _k),
                        p_l, acts[l])
                    g_block, ct_h = vjp((ct_h, aux_ct))
                    bucket_grads[l] = g_block
            if self.compressor is not None:
                res_in = ({l: res_local[l][0] for l in bucket}
                          if res_local is not None else None)
                pushed, res_out = compressed_reduce_scatter_bucket(
                    bucket_grads, self.specs, bucket, self.axis_name,
                    self.compressor, residuals=res_in)
                if res_out is not None:
                    for l, r in res_out.items():
                        new_res[l] = r[None, :]
            else:
                pushed = reduce_scatter_bucket(bucket_grads, self.specs,
                                               bucket, self.axis_name)
            for l, g in pushed.items():
                grad_shards[l] = g / self.axis_size     # sum → mean

        # ---- sharded optimizer update (ZeRO: on local shards only) ------
        new_flats, new_opt = self.optimizer.update(grad_shards, state["opt"],
                                                   shards)
        loss = jax.lax.pmean(loss_local, self.axis_name)
        new_state = {"flat_params": new_flats, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_res is not None:
            new_state["residuals"] = new_res
        return new_state, loss

    # ------------------------------------------------------------------
    # interop
    # ------------------------------------------------------------------

    def params_from_state(self, state) -> Any:
        """Materialize the canonical (unsharded) param pytree from a state —
        checkpoint/eval interop, not part of the hot path."""
        trees = []
        for flat, spec in zip(state["flat_params"], self.specs):
            trees.append(unflatten_tree(jnp.asarray(flat), spec))
        return model_lib.params_from_sched_layers(trees)
