"""Public wrapper: GQA layout handling, head-dim padding, custom VJP.

Forward runs the Pallas kernel; backward recomputes through the jnp oracle
(standard kernel-forward / reference-backward pairing — the training path
in this repo uses the XLA blockwise attention, so the kernel VJP exists for
API completeness and is exercised in tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _pad_head(x, mult=128):
    hd = x.shape[-1]
    pad = (-hd) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, hd


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal=True, window=0, softcap=0.0,
                    bq=128, bk=128, interpret=None):
    """q: (B, H, Tq, hd); k,v: (B, Hkv, Tk, hd) → (B, H, Tq, hd)."""
    b, h, tq, _ = q.shape
    hkv = k.shape[1]
    rep = h // hkv
    kb = jnp.repeat(k, rep, axis=1) if rep > 1 else k
    vb = jnp.repeat(v, rep, axis=1) if rep > 1 else v

    qp, hd = _pad_head(q)
    kp, _ = _pad_head(kb)
    vp, _ = _pad_head(vb)
    out = flash_attention_pallas(
        qp.reshape(b * h, tq, qp.shape[-1]),
        kp.reshape(b * h, kp.shape[2], kp.shape[-1]),
        vp.reshape(b * h, vp.shape[2], vp.shape[-1]),
        causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, scale=1.0 / (hd ** 0.5), interpret=interpret)
    return out.reshape(b, h, tq, -1)[..., :hd]


def _ref_fwd(q, k, v, causal, window, softcap):
    h, hkv = q.shape[1], k.shape[1]
    rep = h // hkv
    kb = jnp.repeat(k, rep, axis=1) if rep > 1 else k
    vb = jnp.repeat(v, rep, axis=1) if rep > 1 else v
    return attention_ref(q, kb, vb, causal=causal, window=window,
                         softcap=softcap)


def _fwd(q, k, v, causal, window, softcap, bq, bk, interpret):
    out = flash_attention(q, k, v, causal, window, softcap, bq, bk, interpret)
    return out, (q, k, v)


def _bwd(causal, window, softcap, bq, bk, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref_fwd(q_, k_, v_, causal, window,
                                                 softcap), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
