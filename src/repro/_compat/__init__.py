"""Dependency fallbacks for hermetic environments (see conftest.py)."""
