"""Training launcher: ``--arch <id>`` + input shape + strategy.

Two runtimes:

* ``--runtime local`` (default) — single-process jit training on whatever
  devices exist; reduced configs runnable on CPU.
* ``--runtime zero`` — the DynaComm-bucketed ZeRO trainer over a 1-D data
  mesh (all local devices), schedule chosen by ``--strategy``.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --reduced --runtime zero --strategy dynacomm --steps 50
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import ARCHITECTURES, get_config
from repro.configs.base import InputShape
from repro.core import (EdgeNetworkModel, costs_from_profiles,
                        DynaCommScheduler, plan_from_decision)
from repro.data.pipeline import SyntheticText
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw, sgd
from repro.train.loop import TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--runtime", choices=("local", "zero"), default="local")
    ap.add_argument("--strategy", default="dynacomm",
                    choices=("sequential", "lbl", "ibatch", "dynacomm"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=("adamw", "sgd"), default="adamw")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend != "none":
        raise SystemExit("train.py drives text archs; stubbed-modality "
                         "archs are exercised via the dry-run and tests")

    opt = adamw(args.lr) if args.optimizer == "adamw" else sgd(args.lr, 0.9)
    pipe = SyntheticText(cfg.vocab_size, args.seq, args.batch, seed=0)

    if args.runtime == "local":
        loop = TrainLoop(cfg=cfg, optimizer=opt, log_every=10,
                         checkpoint_path=args.checkpoint,
                         checkpoint_every=50 if args.checkpoint else 0)
        loop.run(jax.random.PRNGKey(0), iter(pipe), num_steps=args.steps)
        return

    # zero runtime: profile → schedule → bucketed trainer
    from repro.dist.zero import ZeroTrainer
    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(len(devs),), ("data",))
    shape = InputShape("cli", args.seq, args.batch, "train")
    costs = costs_from_profiles(layer_profiles(cfg, shape),
                                net=EdgeNetworkModel(bandwidth_bps=1e9),
                                compute_flops_per_s=1e12)
    sched = DynaCommScheduler(strategy=args.strategy)
    decision = sched.decision_for_iteration(costs)
    plan = plan_from_decision(*decision, num_sched_layers(cfg))
    print(f"[zero] {len(devs)} devices; {args.strategy}: "
          f"{len(plan.forward)} pull / {len(plan.backward)} push buckets")
    trainer = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=opt)
    state = trainer.init_state(jax.random.PRNGKey(0))
    step = jax.jit(trainer.build_train_step())
    t0 = time.perf_counter()
    for i in range(args.steps):
        state, loss = step(state, pipe.batch(i))
        if (i + 1) % 10 == 0:
            print(f"step {i + 1:4d}  loss {float(loss):.4f}  "
                  f"{(time.perf_counter() - t0) / (i + 1):.3f}s/step")


if __name__ == "__main__":
    main()
