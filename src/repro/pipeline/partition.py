"""Stage partitioning: split a profiled model into S contiguous stages.

The pipeline analogue of the transmission DPs in :mod:`repro.core.dp`:
given per-sched-layer compute loads (fc + bc — the per-micro-batch work a
stage must execute), :func:`repro.core.dp.dp_partition` finds the
contiguous split minimizing the *bottleneck stage* load, which is what
bounds pipeline throughput once the fill/drain bubble is amortized.

A :class:`StagePartition` carries the explicit maps both directions —
``segments`` (stage → 1-indexed inclusive sched-layer range, the
``Segment`` convention used everywhere in ``repro.core``) and
``stage_of`` (0-indexed sched layer → stage) — so the trainer, the
transfer planner, and the verifier never re-derive them inconsistently.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.core.costmodel import Segment, validate_forward_segments
from repro.core.dp import dp_partition
from repro.core.profiler import LayerProfile


@dataclasses.dataclass(frozen=True)
class StagePartition:
    """A contiguous split of ``num_layers`` sched layers into stages."""

    segments: Tuple[Segment, ...]   # stage s -> (lo, hi), 1-indexed inclusive
    loads: Tuple[float, ...]        # per-stage load (same units as input)
    bottleneck: float               # max(loads): the throughput bound

    def __post_init__(self):
        validate_forward_segments(self.segments, self.num_layers)
        if len(self.loads) != len(self.segments):
            raise ValueError("one load per stage required")

    @property
    def num_stages(self) -> int:
        return len(self.segments)

    @property
    def num_layers(self) -> int:
        return self.segments[-1][1]

    @property
    def stage_of(self) -> Tuple[int, ...]:
        """0-indexed sched layer -> stage index."""
        out = []
        for s, (lo, hi) in enumerate(self.segments):
            out.extend([s] * (hi - lo + 1))
        return tuple(out)

    def layers_of(self, stage: int) -> Tuple[int, ...]:
        """0-indexed sched layers owned by ``stage``."""
        lo, hi = self.segments[stage]
        return tuple(range(lo - 1, hi))

    @property
    def num_boundaries(self) -> int:
        return self.num_stages - 1

    def as_dict(self) -> dict:
        return {"segments": [list(s) for s in self.segments],
                "loads": list(self.loads),
                "bottleneck": self.bottleneck}


def partition_loads(loads: Sequence[float], num_stages: int) -> StagePartition:
    """Min-max contiguous partition of raw per-layer loads (DP-optimal)."""
    arr = np.asarray(loads, dtype=np.float64)
    res = dp_partition(arr, num_stages)
    pref = np.concatenate([[0.0], np.cumsum(arr)])
    stage_loads = tuple(float(pref[hi] - pref[lo - 1])
                        for lo, hi in res.segments)
    return StagePartition(segments=res.segments, loads=stage_loads,
                          bottleneck=res.bottleneck)


def partition_profiles(profiles: Sequence[LayerProfile], num_stages: int,
                       *, compute_flops_per_s: float = 1.0) -> StagePartition:
    """Balance stages by per-layer fc + bc derived from FLOP profiles.

    The load unit is seconds when ``compute_flops_per_s`` is a real rate;
    the *split* is rate-invariant (min-max argmin is scale-free), so the
    default of 1.0 partitions by raw FLOPs.
    """
    if num_stages > len(profiles):
        raise ValueError(
            f"cannot split {len(profiles)} sched layers into "
            f"{num_stages} non-empty stages")
    loads = [(p.flops_fwd + p.bwd) / compute_flops_per_s for p in profiles]
    return partition_loads(loads, num_stages)
