"""The DynaComm scheduler (paper Section IV) and the strategy registry.

``DynaCommScheduler`` wires profiling → DP → decision, with the overhead
minimizations of Section IV-C: decisions are recomputed once per epoch by
default (``reschedule_every`` iterations), and the forward scheduler for
iteration i+1 can run in the idle window after the last backward compute
(modelled by ``scheduling_overhead_hidden``).

``STRATEGIES`` exposes every competing method under a uniform interface so
benchmarks and the distributed trainer can switch with a string:
``sequential | lbl | ibatch | dynacomm | bruteforce``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core import baselines, bruteforce, dp, greedy
from repro.core.costmodel import (LayerCosts, Segment, TopologyCosts,
                                  backward_time, forward_time, iteration_time)

Decision = Tuple[Tuple[Segment, ...], Tuple[Segment, ...]]  # (forward, backward)


def _default_clock() -> float:
    """Wall clock used to *measure* scheduling overhead (Table I).  Both
    schedulers take it as an injectable ``clock=`` field so deterministic
    tests and resumed-vs-fresh runs can pin event timings.  Genuinely
    measuring here, hence the lint exemption.
    """
    return time.perf_counter()  # noqa: DET-WALL-CLOCK


def _seq(costs: LayerCosts) -> Decision:
    L = costs.num_layers
    return baselines.sequential_forward(L), baselines.sequential_backward(L)


def _lbl(costs: LayerCosts) -> Decision:
    L = costs.num_layers
    return baselines.lbl_forward(L), baselines.lbl_backward(L)


def _ibatch(costs: LayerCosts) -> Decision:
    (f, b), _ = greedy.ibatch_schedule(costs)
    return f, b


def _dynacomm(costs: LayerCosts) -> Decision:
    (f, b), _ = dp.dynacomm_schedule(costs)
    return f, b


def _bruteforce(costs: LayerCosts) -> Decision:
    f, _ = bruteforce.bruteforce_forward(costs)
    b, _ = bruteforce.bruteforce_backward(costs)
    return f, b


STRATEGIES: Dict[str, Callable[[LayerCosts], Decision]] = {
    "sequential": _seq,
    "lbl": _lbl,
    "ibatch": _ibatch,
    "dynacomm": _dynacomm,
    "bruteforce": _bruteforce,
}


def schedule(costs: LayerCosts, strategy: str) -> Decision:
    try:
        return STRATEGIES[strategy](costs)
    except KeyError:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"choose from {sorted(STRATEGIES)}") from None


def schedule_topology(topo: TopologyCosts, strategy: str
                      ) -> Tuple[Decision, ...]:
    """One independent decision per worker of a PS topology.

    This is the *asynchronous* planning mode: each edge worker overlaps its
    own link with its own compute, so the optimal decomposition differs per
    worker (a slow uplink wants few large pushes; a fast one wants
    layer-wise overlap)."""
    return tuple(schedule(c, strategy) for c in topo.workers)


def consensus_decision(topo: TopologyCosts, strategy: str
                       ) -> Tuple[Decision, float]:
    """One shared decision for synchronous-mode PS training.

    A bulk-synchronous step compiles a single program, so every worker must
    run the same segmentation; the iteration ends when the straggler
    finishes.  Each worker's individually-optimal decision is a candidate;
    the one minimizing the *synchronous makespan* (max over workers) wins.
    Returns ``(decision, makespan_seconds)``."""
    candidates = list(dict.fromkeys(schedule_topology(topo, strategy)))
    best = min(candidates, key=lambda d: topo.makespan(*d))
    return best, topo.makespan(*best)


def evaluate(costs: LayerCosts, decision: Decision) -> Dict[str, float]:
    f, b = decision
    return {
        "forward": forward_time(costs, f),
        "backward": backward_time(costs, b),
        "total": iteration_time(costs, f, b),
    }


@dataclasses.dataclass
class DynaCommScheduler:
    """Run-time scheduler with per-epoch decision caching (Section IV-C).

    ``planner=`` plugs a :class:`repro.core.planner.Planner` (or
    :class:`~repro.core.planner.AsyncPlanner`) in front of the strategy
    call — re-plans then go through the content-keyed memo cache (and,
    async, collect decisions pre-computed in the gt¹ idle window) while
    returning bit-identical decisions.  ``clock=`` injects the overhead
    stopwatch so tests and resumed runs can pin event timings.
    """

    strategy: str = "dynacomm"
    reschedule_every: int = 195       # paper: once per epoch (CIFAR-10, bs 256)
    planner: Optional[Any] = None     # duck-typed: .decide(costs, strategy)
    clock: Callable[[], float] = _default_clock

    _decision: Decision | None = None
    _iter_seen: int = 0
    last_scheduling_seconds: float = 0.0

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        if self.reschedule_every < 1:
            raise ValueError(f"reschedule_every must be >= 1, got "
                             f"{self.reschedule_every}")

    def decision_for_iteration(self, costs: LayerCosts) -> Decision:
        """Return the active decision, re-scheduling on the epoch boundary."""
        if self._decision is None or self._iter_seen % self.reschedule_every == 0:
            t0 = self.clock()
            if self.planner is not None:
                self._decision = self.planner.decide(costs, self.strategy)
            else:
                self._decision = schedule(costs, self.strategy)
            self.last_scheduling_seconds = self.clock() - t0
        self._iter_seen += 1
        return self._decision

    def scheduling_overhead_hidden(self, costs: LayerCosts) -> bool:
        """Idle-event-trigger check (Section IV-C / Table I): the forward
        scheduler for iteration i+1 fits in the window
        (Δt + gt_i^1) while the last gradient push is in flight."""
        return self.last_scheduling_seconds <= costs.idle_window

    def invalidate(self) -> None:
        """Drop the cached decision so the next iteration re-schedules
        (drift detected mid-epoch) without disturbing the iteration
        counter's epoch alignment."""
        self._decision = None

    def state_dict(self) -> Dict[str, object]:
        """Checkpointable loop state (decision in segment form)."""
        return {"strategy": self.strategy,
                "iter_seen": self._iter_seen,
                "decision": self._decision,
                "last_scheduling_seconds": self.last_scheduling_seconds}

    def load_state_dict(self, state: Dict[str, object]) -> None:
        saved = state.get("strategy", self.strategy)  # legacy: no strategy
        if saved != self.strategy:
            raise ValueError(
                f"checkpoint was written by a {saved!r}-strategy scheduler; "
                f"this scheduler runs {self.strategy!r}")
        self._iter_seen = int(state["iter_seen"])
        d = state["decision"]
        self._decision = None if d is None else (
            tuple(tuple(s) for s in d[0]), tuple(tuple(s) for s in d[1]))
        self.last_scheduling_seconds = float(
            state.get("last_scheduling_seconds", 0.0))

    def reset(self) -> None:
        self._decision = None
        self._iter_seen = 0
        self.last_scheduling_seconds = 0.0


@dataclasses.dataclass
class TopologyScheduler:
    """Per-topology-epoch scheduler for the parameter-server regime.

    The PS analogue of :class:`DynaCommScheduler`: decisions are derived
    from a whole :class:`TopologyCosts` — one consensus decision shared by
    every worker (``mode="consensus"``, synchronous execution) or one
    independent decision per worker (``mode="per-worker"``, asynchronous
    execution) — and cached until ``invalidate()`` or the next epoch
    boundary (``reschedule_every`` iterations).

    ``decision_for_iteration`` returns a ``Decision`` in consensus mode
    and a tuple of per-worker ``Decision``s in per-worker mode.

    ``planner=``/``clock=`` as on :class:`DynaCommScheduler`.  The
    planner seam is where the homogeneous-fleet collapse happens: W
    workers with identical costs become one DP solve plus W−1 cache
    hits instead of W independent O(L³) sweeps.
    """

    strategy: str = "dynacomm"
    reschedule_every: int = 195
    mode: str = "consensus"           # "consensus" | "per-worker"
    planner: Optional[Any] = None     # duck-typed planner seam
    clock: Callable[[], float] = _default_clock

    _decision: object = None
    _iter_seen: int = 0
    last_scheduling_seconds: float = 0.0
    last_makespan: float = 0.0        # consensus mode: straggler seconds

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"choose from {sorted(STRATEGIES)}")
        if self.reschedule_every < 1:
            raise ValueError(f"reschedule_every must be >= 1, got "
                             f"{self.reschedule_every}")
        if self.mode not in ("consensus", "per-worker"):
            raise ValueError(f"mode must be 'consensus' or 'per-worker', "
                             f"got {self.mode!r}")

    def decision_for_iteration(self, topo: TopologyCosts):
        """The active decision(s), re-scheduling on the epoch boundary."""
        if self._decision is None or \
                self._iter_seen % self.reschedule_every == 0:
            t0 = self.clock()
            if self.mode == "consensus":
                if self.planner is not None:
                    self._decision, self.last_makespan = \
                        self.planner.consensus(topo, self.strategy)
                else:
                    self._decision, self.last_makespan = \
                        consensus_decision(topo, self.strategy)
            elif self.planner is not None:
                self._decision = self.planner.decide_topology(
                    topo, self.strategy)
            else:
                self._decision = schedule_topology(topo, self.strategy)
            self.last_scheduling_seconds = self.clock() - t0
        self._iter_seen += 1
        return self._decision

    def scheduling_overhead_hidden(self, topo: TopologyCosts) -> bool:
        """Table I check against the *topology's* gt¹ idle window: the
        re-plan (run once, driver-side) must fit in every worker's
        Δt + gt¹ window, so the minimum over workers binds."""
        return self.last_scheduling_seconds <= topo.idle_window

    def invalidate(self) -> None:
        """Drop the cached decision without disturbing epoch alignment."""
        self._decision = None

    def _tuplize(self, d):
        """Rebuild tuple-typed decisions from JSON-roundtripped lists."""
        def one(dec):
            return (tuple(tuple(s) for s in dec[0]),
                    tuple(tuple(s) for s in dec[1]))
        return one(d) if self.mode == "consensus" \
            else tuple(one(w) for w in d)

    def state_dict(self) -> Dict[str, object]:
        """Checkpointable loop state (decision in segment form).

        ``mode`` and ``strategy`` are persisted so a restore into a
        differently-configured scheduler fails loudly: ``_tuplize``
        branches on ``self.mode``, so feeding a per-worker checkpoint to
        a consensus scheduler would otherwise silently rebuild garbage
        nested tuples."""
        return {"mode": self.mode,
                "strategy": self.strategy,
                "iter_seen": self._iter_seen,
                "decision": self._decision,
                "last_scheduling_seconds": self.last_scheduling_seconds,
                "last_makespan": self.last_makespan}

    def load_state_dict(self, state: Dict[str, object]) -> None:
        saved_mode = state.get("mode", self.mode)     # legacy: no mode
        if saved_mode != self.mode:
            raise ValueError(
                f"checkpoint was written by a {saved_mode!r}-mode scheduler; "
                f"this scheduler runs mode {self.mode!r}")
        saved = state.get("strategy", self.strategy)  # legacy: no strategy
        if saved != self.strategy:
            raise ValueError(
                f"checkpoint was written by a {saved!r}-strategy scheduler; "
                f"this scheduler runs {self.strategy!r}")
        self._iter_seen = int(state["iter_seen"])
        d = state["decision"]
        self._decision = None if d is None else self._tuplize(d)
        self.last_scheduling_seconds = float(
            state.get("last_scheduling_seconds", 0.0))
        self.last_makespan = float(state.get("last_makespan", 0.0))

    def reset(self) -> None:
        self._decision = None
        self._iter_seen = 0
        self.last_scheduling_seconds = 0.0
        self.last_makespan = 0.0
