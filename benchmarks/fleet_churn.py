"""Elastic-fleet churn benchmark (``repro.fleet``).

One bench, ``fleet_churn``: makespan and loss trajectory of the
deterministic event-queue engine as membership churn grows, at fleet
sizes W ∈ {8, 64, 512}.  Per fleet size the bench first runs a
churn-free baseline to measure the simulated makespan, then synthesizes
reproducible churn schedules (joins/leaves/failures at increasing event
rates) over that horizon and re-runs the same push budget — so the
``churn_per_s`` column is meaningful relative to the run's own
timescale, not an arbitrary wall-clock guess.

Each row carries the run's simulated makespan, the loss trajectory
(quartile samples of the accepted-push losses), the SSP staleness
watermark (must stay ≤ k under churn — the bound the engine enforces),
the re-plan count (one per membership event plus any measured-drift
triggers), and the server re-sharding traffic when ``workers_per_shard``
lets the shard count track the fleet.

The model is a deliberately tiny quadratic (4 layers, 64 weights each):
the object under test is the event engine, membership machinery, and
re-planning pipeline, not the gradient computation.  CI publishes this
bench as ``BENCH_fleet.json``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

FLEET_SIZES = (8, 64, 512)
#: target numbers of membership events per run, scaled into a churn rate
#: against the measured churn-free makespan
EVENT_TARGETS = (0, 4, 16)
LAYERS, WIDTH = 4, 64


def _toy_layers(seed: int = 0) -> List[Dict[str, jnp.ndarray]]:
    rng = np.random.default_rng(seed)
    return [{"w": jnp.asarray(rng.standard_normal(WIDTH), jnp.float32)}
            for _ in range(LAYERS)]


def _toy_loss(layer_list, batch):
    err = sum(jnp.sum((layer["w"] - batch["target"]) ** 2)
              for layer in layer_list)
    return err / len(layer_list)


def _batch_fn(worker: int, idx: int):
    del worker, idx
    return {"target": jnp.zeros((WIDTH,), jnp.float32)}


def _run(workers: int, pushes: int, schedule: Optional[object],
         workers_per_shard: int) -> Dict:
    import time

    from repro.core import schedule_topology
    from repro.fleet import FleetTrainer
    from repro.optim import sgd
    tr = FleetTrainer(
        init_layers=_toy_layers(), loss_fn=_toy_loss,
        optimizer=sgd(1e-2, 0.0), workers=workers, schedule=schedule,
        num_servers=2, workers_per_shard=workers_per_shard,
        staleness=max(2, workers // 64), throttle="wait")
    log = tr.run(pushes, _batch_fn)
    losses = [e.loss for e in log.accepted]
    q = [losses[max(0, int(len(losses) * f) - 1)]
         for f in (0.25, 0.5, 0.75, 1.0)]
    kinds = [e.kind for e in tr.membership_events]
    stats = tr.planner_stats
    sched_s = [e.scheduling_seconds for e in tr.replan_events]
    # uncached probe: the same W-worker DP solved raw, without the
    # planner — the "before" column for the homogeneous-fleet collapse
    # (W identical workers cost W full DPs here vs one through the cache)
    _, probe_costs = tr._worker_costs(tr._believed)
    t0 = time.perf_counter()
    schedule_topology(probe_costs, "dynacomm")
    uncached_s = time.perf_counter() - t0
    return {
        "makespan_s": round(log.makespan, 4),
        "final_loss": round(losses[-1], 5),
        "loss_q25": round(q[0], 5), "loss_q50": round(q[1], 5),
        "loss_q75": round(q[2], 5),
        "accepted": len(log.accepted),
        "rejected": log.num_rejected,
        "max_staleness": log.max_staleness,
        "staleness_bound": tr.staleness,
        "joins": kinds.count("join"),
        "leaves": kinds.count("leave"),
        "fails": kinds.count("crash") + kinds.count("stall") +
        kinds.count("stall-evict"),
        "replans": len(tr.replan_events),
        "sched_s_per_replan": round(sum(sched_s) / max(len(sched_s), 1), 6),
        "uncached_sched_s": round(uncached_s, 6),
        "plan_cache_hit_rate": round(stats["hit_rate"], 4),
        "plan_cache_hits": stats["hits"],
        "reshards": sum(1 for e in tr.replan_events if e.resharded),
        "migrated_bytes": sum(e.migrated_bytes for e in tr.replan_events),
        "final_workers": tr.membership.num_active,
    }


def fleet_churn() -> List[Dict]:
    """Makespan + loss trajectory vs. churn rate at W ∈ {8, 64, 512}."""
    from repro.fleet import FleetSchedule
    rows = []
    for W in FLEET_SIZES:
        pushes = max(64, 2 * W)
        shard_track = max(0, W // 16)       # shard count follows the fleet
        baseline = _run(W, pushes, None, shard_track)
        horizon = 0.8 * baseline["makespan_s"]
        for target in EVENT_TARGETS:
            if target == 0:
                row = dict(baseline)
                rate = 0.0
            else:
                rate = target / horizon
                schedule = FleetSchedule.synthesize(
                    range(W), churn=rate, horizon=horizon, seed=W + target)
                row = _run(W, pushes, schedule, shard_track)
            rows.append({"workers": W, "pushes": pushes,
                         "churn_per_s": round(rate, 4), **row})
    return rows


FLEET_BENCHES = {"fleet_churn": fleet_churn}
