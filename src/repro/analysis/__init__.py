"""``repro.analysis`` — static analysis for the DynaComm reproduction.

Two layers:

* **IR analyzers** (:mod:`repro.analysis.hlo`,
  :mod:`repro.analysis.conformance`) — a structured HLO-text walker and
  the schedule-conformance passes proving a compiled step contains
  exactly the collectives its ``BucketPlan`` prescribes, with operand
  and wire bytes matching the ``FlatSpec``/``Compressor`` byte math;
* **AST lints** (:mod:`repro.analysis.lints`) — repo-specific
  determinism hazards (unseeded RNG, wall-clock in event loops,
  order-sensitive param-tree walks, hard-coded Pallas ``interpret=``,
  deprecated import aliases).

CLI: ``python -m repro.analysis lint src/`` and
``python -m repro.analysis verify --config <runtime config>``.

This package deliberately imports neither jax nor numpy at the top
level (``repro.analysis.runtime_verify``, which drives a built runtime,
is imported lazily by the CLI), so lints and fixture-based conformance
stay usable in import-light contexts.
"""

from repro.analysis.conformance import (expected_ag_bytes,
                                        expected_rs_bytes,
                                        independent_wire_bytes,
                                        segment_wire_bytes, verify_cache,
                                        verify_fleet_membership,
                                        verify_no_collectives,
                                        verify_push_ledger,
                                        verify_schedule, verify_wire_model)
from repro.analysis.findings import (Finding, findings_to_json,
                                     render_findings)
from repro.analysis.hlo import (COLLECTIVES, DTYPE_BYTES, HloInstruction,
                                HloModule, collective_counts,
                                collective_summary, parse_hlo, type_bytes)
from repro.analysis.lints import (LINT_CODES, LintConfig, lint_file,
                                  lint_paths, lint_source)

__all__ = [
    "COLLECTIVES", "DTYPE_BYTES", "Finding", "HloInstruction", "HloModule",
    "LINT_CODES", "LintConfig", "collective_counts", "collective_summary",
    "expected_ag_bytes", "expected_rs_bytes", "findings_to_json",
    "independent_wire_bytes", "lint_file", "lint_paths", "lint_source",
    "parse_hlo", "render_findings", "segment_wire_bytes", "type_bytes",
    "verify_cache", "verify_fleet_membership", "verify_no_collectives",
    "verify_push_ledger", "verify_schedule", "verify_wire_model",
]
