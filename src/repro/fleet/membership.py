"""Fleet membership: who is in the worker set, and how it changes.

The elastic regime separates *what happens to the fleet* from *how the
trainer reacts*:

* :class:`FleetEvent` / :class:`FleetSchedule` — the scripted (or
  synthesized) timeline of membership changes: workers ``join`` with
  their own link/compute spec, ``leave`` gracefully, ``fail`` (mode
  ``"crash"``: the connection dies mid-push, pending segments are
  dropped server-side; mode ``"stall"``: the worker silently stops
  committing and must be *detected*), or ``drift`` (its real compute
  rate changes by a factor — also silent, left to measured drift
  detection rather than scripted re-planning);
* :class:`FleetMembership` — the live roster: which global worker ids
  are active, each one's :class:`WorkerSpec`, when it joined (time and
  server version — the conformance anchor for "a joined worker's pushes
  start at its join version") and when/why it departed.  It projects the
  active set onto a :class:`~repro.ps.topology.PSTopology` whose link
  order follows ascending worker id, so topology position ``i`` is
  always ``active[i]``.

``FleetSchedule.synthesize`` generates reproducible churn from a seeded
generator — the only randomness in the subsystem, and it happens at
*construction* time; the event loop itself stays RNG-free.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.ps.topology import PSTopology, asymmetric_link

FLEET_EVENT_KINDS = ("join", "leave", "fail", "drift")
FAIL_MODES = ("crash", "stall")


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """One worker's link bandwidths and compute rate."""

    down_bps: float = 10e9        # server → worker (parameter pulls)
    up_bps: float = 1e9           # worker → server (gradient pushes)
    flops: float = 1e10           # compute rate (FLOP/s)

    def __post_init__(self):
        for name in ("down_bps", "up_bps", "flops"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be positive, got "
                                 f"{getattr(self, name)}")


@dataclasses.dataclass(frozen=True)
class FleetEvent:
    """One membership/environment change at simulated ``time``.

    ``kind``:

    * ``"join"`` — ``worker`` (a fresh global id) enters with ``spec``;
    * ``"leave"`` — graceful departure: uncommitted work is discarded;
    * ``"fail"`` — ``mode="crash"`` kills the worker mid-push (segments
      already sent stay in the ledger, the pending set is dropped), while
      ``mode="stall"`` makes it silently stop committing — nothing
      observable happens until the stall detector evicts it;
    * ``"drift"`` — the worker's true iteration time scales by
      ``factor`` (> 1 slower).  Silent: the planner only learns about it
      through measured drift detection.
    """

    time: float
    kind: str
    worker: int
    mode: str = "crash"           # fail events only
    factor: float = 1.0           # drift events only
    spec: Optional[WorkerSpec] = None   # join events only

    def __post_init__(self):
        if self.time < 0:
            raise ValueError(f"event time must be >= 0, got {self.time}")
        if self.kind not in FLEET_EVENT_KINDS:
            raise ValueError(f"kind must be one of {FLEET_EVENT_KINDS}, "
                             f"got {self.kind!r}")
        if self.worker < 0:
            raise ValueError(f"worker id must be >= 0, got {self.worker}")
        if self.kind == "fail" and self.mode not in FAIL_MODES:
            raise ValueError(f"fail mode must be one of {FAIL_MODES}, got "
                             f"{self.mode!r}")
        if self.kind == "drift" and self.factor <= 0:
            raise ValueError(f"drift factor must be positive, got "
                             f"{self.factor}")
        if self.spec is not None and self.kind != "join":
            raise ValueError(f"only join events carry a spec "
                             f"(got kind={self.kind!r})")

    def to_dict(self) -> dict:
        d = {"time": self.time, "kind": self.kind, "worker": self.worker}
        if self.kind == "fail":
            d["mode"] = self.mode
        if self.kind == "drift":
            d["factor"] = self.factor
        if self.spec is not None:
            d["spec"] = dataclasses.asdict(self.spec)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "FleetEvent":
        d = dict(d)
        spec = d.pop("spec", None)
        if spec is not None and not isinstance(spec, WorkerSpec):
            spec = WorkerSpec(**spec)
        return cls(spec=spec, **d)


@dataclasses.dataclass(frozen=True)
class FleetSchedule:
    """A time-ordered script of :class:`FleetEvent`\\ s."""

    events: Tuple[FleetEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        times = [e.time for e in self.events]
        if times != sorted(times):
            raise ValueError("fleet events must be ordered by time")

    def __len__(self) -> int:
        return len(self.events)

    @property
    def horizon(self) -> float:
        return self.events[-1].time if self.events else 0.0

    def validate_against(self, initial_workers: Sequence[int]) -> None:
        """Check the script is coherent for a fleet starting as
        ``initial_workers``: joins introduce fresh ids, leaves/fails/
        drifts name a currently-active id."""
        active = set(initial_workers)
        ever = set(initial_workers)
        for e in self.events:
            if e.kind == "join":
                if e.worker in ever:
                    raise ValueError(f"t={e.time}: worker {e.worker} "
                                     f"joins but the id was already used")
                active.add(e.worker)
                ever.add(e.worker)
            else:
                if e.worker not in active:
                    raise ValueError(f"t={e.time}: {e.kind} names worker "
                                     f"{e.worker}, which is not active")
                if e.kind in ("leave", "fail"):
                    active.remove(e.worker)

    @classmethod
    def synthesize(cls, initial_workers: Sequence[int], *, churn: float,
                   horizon: float, seed: int = 0,
                   join_spec: WorkerSpec = WorkerSpec(),
                   kind_weights: Tuple[float, float, float] = (0.4, 0.3,
                                                              0.3),
                   fail_stall_fraction: float = 0.5,
                   min_fleet: Optional[int] = None) -> "FleetSchedule":
        """Reproducible churn: ~``churn * horizon`` events, uniform in
        time, kinds drawn as (join, leave, fail) per ``kind_weights``.
        Departures are skipped while the fleet is at ``min_fleet``
        (default: half the initial size, at least 1); join ids continue
        above the largest id ever seen.  Deterministic per ``seed``."""
        initial = sorted(initial_workers)
        if not initial:
            raise ValueError("need at least one initial worker")
        floor = max(1, len(initial) // 2) if min_fleet is None else min_fleet
        rng = np.random.default_rng(seed)
        n = int(rng.poisson(churn * horizon))
        times = sorted(float(t) for t in rng.uniform(0.0, horizon, size=n))
        weights = np.asarray(kind_weights, float)
        weights = weights / weights.sum()
        active = list(initial)
        next_id = max(initial) + 1
        events: List[FleetEvent] = []
        for t in times:
            kind = ("join", "leave", "fail")[
                int(rng.choice(3, p=weights))]
            if kind == "join":
                events.append(FleetEvent(time=t, kind="join",
                                         worker=next_id, spec=join_spec))
                active.append(next_id)
                next_id += 1
                continue
            if len(active) <= floor:
                continue              # departure would sink the fleet
            victim = active.pop(int(rng.integers(len(active))))
            if kind == "leave":
                events.append(FleetEvent(time=t, kind="leave",
                                         worker=victim))
            else:
                mode = "stall" if rng.random() < fail_stall_fraction \
                    else "crash"
                events.append(FleetEvent(time=t, kind="fail", worker=victim,
                                         mode=mode))
        return cls(tuple(events))


class FleetMembership:
    """The live worker roster, projectable onto a :class:`PSTopology`."""

    def __init__(self, specs: Mapping[int, WorkerSpec]):
        if not specs:
            raise ValueError("need at least one initial worker")
        self._specs: Dict[int, WorkerSpec] = dict(sorted(specs.items()))
        # (join time, server version at join); initial fleet joins at 0
        self.joined_at: Dict[int, Tuple[float, int]] = {
            w: (0.0, 0) for w in self._specs}
        # (departure time, reason) — reasons: leave | crash | stall
        self.departed: Dict[int, Tuple[float, str]] = {}

    # -- roster --------------------------------------------------------

    @property
    def active(self) -> Tuple[int, ...]:
        return tuple(sorted(self._specs))

    @property
    def num_active(self) -> int:
        return len(self._specs)

    def is_active(self, worker: int) -> bool:
        return worker in self._specs

    def spec(self, worker: int) -> WorkerSpec:
        return self._specs[worker]

    def index_of(self, worker: int) -> int:
        """Topology position of ``worker`` (link order = ascending id)."""
        return self.active.index(worker)

    def join(self, worker: int, spec: WorkerSpec, *, time: float,
             version: int) -> None:
        if worker in self._specs:
            raise ValueError(f"worker {worker} is already active")
        if worker in self.departed:
            raise ValueError(f"worker id {worker} was already used; "
                             f"joins need fresh ids")
        self._specs[worker] = spec
        self._specs = dict(sorted(self._specs.items()))
        self.joined_at[worker] = (time, version)

    def depart(self, worker: int, *, time: float, reason: str) -> None:
        if worker not in self._specs:
            raise ValueError(f"worker {worker} is not active")
        del self._specs[worker]
        self.departed[worker] = (time, reason)

    # -- projection ----------------------------------------------------

    def topology(self, num_servers: int, *,
                 flops_scale: Optional[Mapping[int, float]] = None
                 ) -> PSTopology:
        """The active fleet as a :class:`PSTopology` (links in ascending
        worker-id order).  ``flops_scale[w] = f`` divides ``w``'s compute
        rate by ``f`` — the planner's *believed* slowdown factors from
        drift detection."""
        scale = flops_scale or {}
        links = tuple(asymmetric_link(self._specs[w].down_bps,
                                      self._specs[w].up_bps)
                      for w in self.active)
        flops = tuple(self._specs[w].flops / float(scale.get(w, 1.0))
                      for w in self.active)
        return PSTopology(num_servers=num_servers, links=links,
                          worker_flops=flops)

    # -- serialization -------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "specs": {str(w): dataclasses.asdict(s)
                      for w, s in self._specs.items()},
            "joined_at": {str(w): list(v)
                          for w, v in self.joined_at.items()},
            "departed": {str(w): list(v)
                         for w, v in self.departed.items()},
        }

    @classmethod
    def from_state(cls, state: dict) -> "FleetMembership":
        m = cls({int(w): WorkerSpec(**s)
                 for w, s in state["specs"].items()})
        m.joined_at = {int(w): (float(t), int(v))
                       for w, (t, v) in state["joined_at"].items()}
        m.departed = {int(w): (float(t), str(r))
                      for w, (t, r) in state["departed"].items()}
        return m
