"""Canonical data x model GSPMD sharding rules.

``param_pspec`` maps a parameter's path + shape to a ``PartitionSpec`` under
the repo-wide convention:

* 2-D+ weights put their *output-feature* dimension on the model axis and
  their other contraction dimension on the data axis (FSDP / ZeRO-style
  weight sharding).  Projections that map *back* into the residual stream
  (``wo`` / ``down`` / ``out``) are transposed: model on the penultimate
  dimension, data on the last.
* 1-D scales/biases go on the data axis.
* A dimension that is not divisible by its axis size falls back to
  replicated (``None``) — never an invalid sharding.
* ``dim_offset`` skips leading stacking dimensions (the scan-over-layers
  parameter layout); extra leading dimensions such as MoE expert stacks are
  replicated unless ``moe_ep`` requests expert parallelism over the model
  axis.

``params_shardings`` / ``batch_shardings`` / ``cache_shardings`` apply the
rules over whole pytrees for the dry-run and trainer entry points.
"""

from __future__ import annotations

import math
from typing import Any, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# Projections back into the residual stream: (feature_in, d_model) — the
# model-parallel dimension is the *first* of the trailing two.
_MODEL_FIRST_NAMES = frozenset({"wo", "down", "out"})


def path_str(path: Sequence[Any]) -> str:
    """jax key-path → "a/0/b" string (matches the test-suite convention)."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _data_entry(data_axes: Tuple[str, ...]):
    """PartitionSpec entry for the data axes (None / name / axis tuple)."""
    if not data_axes:
        return None
    return data_axes[0] if len(data_axes) == 1 else tuple(data_axes)


def param_pspec(path: str, shape: Tuple[int, ...], *, model_axis=None,
                data_axes: Tuple[str, ...] = (), model_size: int = 1,
                data_size: int = 1, dim_offset: int = 0,
                moe_ep: bool = False) -> PartitionSpec:
    """PartitionSpec for one parameter leaf (see module docstring)."""
    entries: list = [None] * len(shape)
    eff = shape[dim_offset:]
    nd = len(eff)
    data_entry = _data_entry(data_axes)

    def put_model(i: int) -> None:
        if model_axis is not None and model_size > 0 \
                and eff[i] % model_size == 0:
            entries[dim_offset + i] = model_axis

    def put_data(i: int) -> None:
        if data_entry is not None and data_size > 0 \
                and eff[i] % data_size == 0:
            entries[dim_offset + i] = data_entry

    name = path.split("/")[-1]
    if nd == 1:
        put_data(0)
    elif nd >= 2:
        if name in _MODEL_FIRST_NAMES:
            model_dim, data_dim = nd - 2, nd - 1
        else:
            model_dim, data_dim = nd - 1, nd - 2
        expert_parallel = (moe_ep and "moe" in path.split("/") and nd >= 3
                           and model_axis is not None
                           and eff[0] % max(model_size, 1) == 0)
        if expert_parallel:
            entries[dim_offset] = model_axis   # experts over the model axis
            put_data(data_dim)
        else:
            put_model(model_dim)
            put_data(data_dim)
    return PartitionSpec(*entries)


def _mesh_axes(mesh: Mesh, *, fsdp: bool = True):
    model_axis = "model" if "model" in mesh.axis_names else None
    model_size = int(mesh.shape[model_axis]) if model_axis else 1
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    data_size = int(math.prod(mesh.shape[a] for a in data_axes)) \
        if data_axes else 1
    if not fsdp:
        data_axes, data_size = (), 1
    return model_axis, model_size, data_axes, data_size


def params_shardings(cfg, params: Any, mesh: Mesh, *, fsdp: bool = True,
                     moe_ep: bool = False) -> Any:
    """NamedShardings for a parameter (or optimizer-state) pytree.

    Accepts both the per-layer layout (``layers/<i>/...``) and the stacked
    scan layout (``stack/<j>/...`` — the leading group dimension is kept
    replicated via ``dim_offset=1``).
    """
    del cfg  # rules are shape/path driven; kept for call-site symmetry
    model_axis, model_size, data_axes, data_size = _mesh_axes(mesh, fsdp=fsdp)

    def rule(path, leaf):
        ps = path_str(path)
        offset = 1 if "stack" in ps.split("/") else 0
        spec = param_pspec(ps, tuple(leaf.shape), model_axis=model_axis,
                           data_axes=data_axes, model_size=model_size,
                           data_size=data_size, dim_offset=offset,
                           moe_ep=moe_ep)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    """Shard every batch leaf's leading (global-batch) dim over the data
    axes; replicate when indivisible (e.g. batch-1 long-context decode)."""
    _, _, data_axes, data_size = _mesh_axes(mesh)
    data_entry = _data_entry(data_axes)

    def rule(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, PartitionSpec())
        lead = data_entry if (data_entry is not None
                              and leaf.shape[0] % data_size == 0) else None
        return NamedSharding(mesh,
                             PartitionSpec(lead, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map(rule, batch)


def cache_shardings(caches: Any, mesh: Mesh, *, batch: int,
                    seq_over_model: bool = False) -> Any:
    """Decode-cache shardings: batch dim over data; for (B, S, H, hd) KV
    leaves the kv-head dim goes over model when divisible, or the sequence
    dim instead with ``seq_over_model=True`` (few-kv-head models)."""
    model_axis, model_size, data_axes, data_size = _mesh_axes(mesh)
    data_entry = _data_entry(data_axes)

    def rule(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, PartitionSpec())
        entries: list = [None] * leaf.ndim
        if data_entry is not None and leaf.shape[0] == batch \
                and batch % data_size == 0:
            entries[0] = data_entry
        if leaf.ndim == 4 and model_axis is not None:
            if seq_over_model and leaf.shape[1] % model_size == 0:
                entries[1] = model_axis
            elif leaf.shape[2] % model_size == 0:
                entries[2] = model_axis
        return NamedSharding(mesh, PartitionSpec(*entries))

    return jax.tree_util.tree_map(rule, caches)
