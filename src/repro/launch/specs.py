"""ShapeDtypeStruct stand-ins for every model input (dry-run, no allocation)."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import model as model_lib

S = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for train/prefill modes."""
    b, t = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        batch = {"frames": S((b, t, cfg.d_model), dtype)}
    elif cfg.frontend == "vision":
        nv = min(cfg.num_vision_tokens, t - 1)
        batch = {"tokens": S((b, t - nv), jnp.int32),
                 "vision_embeds": S((b, nv, cfg.d_model), dtype)}
    else:
        batch = {"tokens": S((b, t), jnp.int32)}
    if shape.mode == "train":
        lab_t = batch["tokens"].shape[1] if "tokens" in batch else t
        batch["labels"] = S((b, lab_t), jnp.int32)
    return batch


def state_specs(cfg: ArchConfig, optimizer, dtype=jnp.bfloat16
                ) -> Tuple[Any, Any]:
    """(params, opt_state) ShapeDtypeStructs via eval_shape."""
    params = jax.eval_shape(
        lambda k: model_lib.init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    opt_state = jax.eval_shape(optimizer.init, params)
    return params, opt_state


def cache_specs(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16) -> Any:
    """Per-layer decode cache structs sized for shape.seq_len."""
    return jax.eval_shape(
        lambda: model_lib.init_caches(cfg, shape.global_batch, shape.seq_len,
                                      dtype))


def decode_specs(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16):
    token = S((shape.global_batch, 1), jnp.int32)
    return token, cache_specs(cfg, shape, dtype)
