"""AST lints for repo-specific determinism hazards (layer 2).

Five rules, each motivated by a class of bug this codebase has to stay
immune to (bit-identical losses across strategies, deterministic
discrete-event replay, TPU/CPU kernel parity):

* ``DET-RANDOM`` — draws from the *global* ``random`` / legacy
  ``numpy.random`` state, or ``default_rng()``/``Random()`` constructed
  without a seed.  All randomness must flow from an explicit seed
  (``np.random.default_rng(seed)`` / ``jax.random.PRNGKey``).
* ``DET-WALL-CLOCK`` — wall-clock reads (``time.time``,
  ``perf_counter``, ``datetime.now``, ...) inside the deterministic
  modules (the async event loop, the PS server, the simulator), whose
  replay guarantees break the moment real time leaks in.  Timing code
  elsewhere (profilers, schedulers measuring DP wall time) is
  legitimate and not linted.
* ``DET-DICT-ORDER`` — iteration over ``.items()/.keys()/.values()`` of
  param-tree-shaped dicts without ``sorted(...)``: flatten order must
  not depend on insertion history.
* ``KERNEL-INTERPRET`` — literal ``interpret=True/False`` defaults or
  call arguments in Pallas kernel modules; backend routing must go
  through ``repro._compat.pallas.default_interpret``/
  ``resolve_interpret`` so the same code runs fused on TPU and
  interpreted elsewhere.
* ``DEPRECATED-IMPORT`` — importing names that moved to
  ``repro.runtime.replan`` from the ``repro.dist.dynamic`` /
  ``repro.ps.dynamic`` alias paths.

Suppression: append ``# noqa`` (all codes) or ``# noqa: DET-RANDOM``
(specific codes, comma-separated) to the flagged line.

Stdlib ``ast`` only — no new dependencies.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding

__all__ = ["LintConfig", "LINT_CODES", "lint_source", "lint_file",
           "lint_paths"]

LINT_CODES = ("DET-RANDOM", "DET-WALL-CLOCK", "DET-DICT-ORDER",
              "KERNEL-INTERPRET", "DEPRECATED-IMPORT")

#: Names whose canonical home is ``repro.runtime.replan``.
MOVED_REPLAN_NAMES = frozenset({
    "PlanStepCache", "RescheduleEvent", "hlo_collective_counts",
    "sequential_plan", "ReplanMixin"})
DEPRECATED_ALIAS_MODULES = ("repro.dist.dynamic", "repro.ps.dynamic")

# numpy.random attributes that are explicit-seed constructions, not
# draws from the hidden global state.
_NP_RANDOM_SAFE = frozenset({
    "default_rng", "Generator", "SeedSequence", "RandomState", "PCG64",
    "MT19937", "Philox", "SFC64", "BitGenerator"})
# stdlib random attributes that construct an independent RNG object.
_PY_RANDOM_SAFE = frozenset({"Random", "SystemRandom"})
# zero-arg constructors that fall back to OS entropy (unseeded).
_SEEDED_CTORS = frozenset({"default_rng", "Random", "RandomState"})

_WALL_CLOCK_TIME = frozenset({
    "time", "time_ns", "perf_counter", "perf_counter_ns", "monotonic",
    "monotonic_ns", "process_time", "process_time_ns"})
_WALL_CLOCK_DATETIME = frozenset({"now", "utcnow", "today"})

_PARAM_TREE_NAME = re.compile(
    r"(param|grad|tree|layer|leav|weight)", re.IGNORECASE)

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[\w\-,\s]+))?", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Where each path-scoped rule applies (suffix / substring match on
    ``/``-normalized paths)."""

    deterministic_modules: Tuple[str, ...] = (
        "core/simulator.py", "core/scheduler.py", "core/planner.py",
        "ps/async_mode.py", "ps/server.py",
        "fleet/engine.py", "fleet/membership.py", "fleet/drift.py",
        "fleet/trainer.py",
        "pipeline/partition.py", "pipeline/schedule.py",
        "pipeline/transfer.py", "pipeline/trainer.py")
    kernel_dirs: Tuple[str, ...] = ("kernels",)


DEFAULT_CONFIG = LintConfig()


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _in_deterministic_module(path: str, config: LintConfig) -> bool:
    p = _norm(path)
    return any(p.endswith(m) for m in config.deterministic_modules)


def _in_kernel_dir(path: str, config: LintConfig) -> bool:
    parts = _norm(path).split("/")
    return any(d in parts for d in config.kernel_dirs)


def _root_name(node: ast.AST) -> Optional[str]:
    """Leftmost name of a dotted access (``a.b.c`` → ``a``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` as a string, or None for non-trivial expressions."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


class _Linter(ast.NodeVisitor):

    def __init__(self, path: str, config: LintConfig):
        self.path = path
        self.config = config
        self.findings: List[Finding] = []
        # module-alias maps built from the file's imports
        self.py_random: Set[str] = set()      # aliases of stdlib `random`
        self.np_aliases: Set[str] = set()     # aliases of `numpy`
        self.np_random: Set[str] = set()      # aliases of `numpy.random`
        self.time_aliases: Set[str] = set()   # aliases of `time`
        self.dt_modules: Set[str] = set()     # aliases of `datetime` module
        self.dt_classes: Set[str] = set()     # `datetime`/`date` classes
        self.unseeded_ctor_aliases: Set[str] = set()  # from-imported ctors
        self.lint_clock = _in_deterministic_module(path, config)
        self.lint_kernel = _in_kernel_dir(path, config)

    def flag(self, code: str, node: ast.AST, message: str, **detail) -> None:
        self.findings.append(Finding(
            code=code, message=message, path=self.path,
            line=getattr(node, "lineno", None), detail=detail))

    # -- imports --------------------------------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            asname = alias.asname or alias.name.split(".")[0]
            if alias.name == "random":
                self.py_random.add(asname)
            elif alias.name == "numpy":
                self.np_aliases.add(asname)
            elif alias.name == "numpy.random":
                self.np_random.add(alias.asname or "numpy")
                if alias.asname is None:
                    self.np_aliases.add("numpy")
            elif alias.name == "time":
                self.time_aliases.add(asname)
            elif alias.name == "datetime":
                self.dt_modules.add(asname)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        names = {a.name: (a.asname or a.name) for a in node.names}
        if mod in DEPRECATED_ALIAS_MODULES:
            moved = sorted(set(names) & MOVED_REPLAN_NAMES)
            if moved:
                self.flag(
                    "DEPRECATED-IMPORT", node,
                    f"{', '.join(moved)} moved to repro.runtime.replan; "
                    f"the {mod} alias path is a deprecation shim",
                    module=mod, names=moved)
        if mod == "random":
            drawn = sorted(n for n in names if n not in _PY_RANDOM_SAFE)
            if drawn:
                self.flag(
                    "DET-RANDOM", node,
                    f"from random import {', '.join(drawn)} draws from "
                    f"the global RNG state; use a seeded "
                    f"np.random.default_rng / random.Random instance",
                    names=drawn)
            for n, asname in names.items():
                if n in _SEEDED_CTORS:
                    self.unseeded_ctor_aliases.add(asname)
        elif mod in ("numpy.random", "numpy"):
            if mod == "numpy.random":
                drawn = sorted(n for n in names if n not in _NP_RANDOM_SAFE)
                if drawn:
                    self.flag(
                        "DET-RANDOM", node,
                        f"from numpy.random import {', '.join(drawn)} "
                        f"draws from the legacy global RNG state; use a "
                        f"seeded np.random.default_rng instance",
                        names=drawn)
            if "random" in names and mod == "numpy":
                self.np_random.add(names["random"])
            for n, asname in names.items():
                if n in _SEEDED_CTORS:
                    self.unseeded_ctor_aliases.add(asname)
        elif mod == "time" and self.lint_clock:
            clocks = sorted(set(names) & _WALL_CLOCK_TIME)
            if clocks:
                self.flag(
                    "DET-WALL-CLOCK", node,
                    f"from time import {', '.join(clocks)} inside a "
                    f"deterministic module — event loops must run on "
                    f"simulated time only", names=clocks)
        elif mod == "datetime":
            self.dt_classes.update(
                asname for n, asname in names.items()
                if n in ("datetime", "date"))
        self.generic_visit(node)

    # -- calls ----------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        self._check_random(node)
        if self.lint_clock:
            self._check_wall_clock(node)
        if self.lint_kernel:
            self._check_interpret_call(node)
        self.generic_visit(node)

    def _is_unseeded(self, node: ast.Call) -> bool:
        return not node.args and not any(
            kw.arg in ("seed", "x") or kw.arg is None for kw in node.keywords)

    def _check_random(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Name):
            if fn.id in self.unseeded_ctor_aliases and self._is_unseeded(node):
                self.flag("DET-RANDOM", node,
                          f"{fn.id}() without a seed falls back to OS "
                          f"entropy; pass an explicit seed")
            return
        if not isinstance(fn, ast.Attribute):
            return
        attr = fn.attr
        base = _dotted(fn.value)
        if base is None:
            return
        is_np_random = base in self.np_random or any(
            base == f"{np}.random" for np in self.np_aliases)
        if base in self.py_random:
            if attr in _SEEDED_CTORS:
                if self._is_unseeded(node):
                    self.flag("DET-RANDOM", node,
                              f"{base}.{attr}() without a seed falls back "
                              f"to OS entropy; pass an explicit seed")
            elif attr not in _PY_RANDOM_SAFE:
                self.flag("DET-RANDOM", node,
                          f"{base}.{attr}() draws from the global RNG "
                          f"state; use a seeded random.Random / "
                          f"np.random.default_rng instance")
        elif is_np_random:
            if attr in _SEEDED_CTORS:
                if self._is_unseeded(node):
                    self.flag("DET-RANDOM", node,
                              f"{base}.{attr}() without a seed falls back "
                              f"to OS entropy; pass an explicit seed")
            elif attr not in _NP_RANDOM_SAFE:
                self.flag("DET-RANDOM", node,
                          f"{base}.{attr}() draws from the legacy global "
                          f"numpy RNG state; use a seeded "
                          f"np.random.default_rng instance")

    def _check_wall_clock(self, node: ast.Call) -> None:
        fn = node.func
        if not isinstance(fn, ast.Attribute):
            return
        base = _dotted(fn.value)
        if base in self.time_aliases and fn.attr in _WALL_CLOCK_TIME:
            self.flag("DET-WALL-CLOCK", node,
                      f"{base}.{fn.attr}() reads the wall clock inside a "
                      f"deterministic module — event loops must run on "
                      f"simulated time only")
        elif fn.attr in _WALL_CLOCK_DATETIME:
            root = _root_name(fn.value)
            if base in self.dt_classes or root in self.dt_modules:
                self.flag("DET-WALL-CLOCK", node,
                          f"{base}.{fn.attr}() reads the wall clock "
                          f"inside a deterministic module")

    def _check_interpret_call(self, node: ast.Call) -> None:
        for kw in node.keywords:
            if kw.arg == "interpret" and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, bool):
                self.flag(
                    "KERNEL-INTERPRET", kw.value,
                    f"hard-coded interpret={kw.value.value} pins the "
                    f"Pallas backend; route through "
                    f"repro._compat.pallas.resolve_interpret (None = "
                    f"auto-detect)")

    # -- function defaults ----------------------------------------------

    def _check_interpret_default(self, node) -> None:
        args = node.args
        pairs = list(zip(args.args[len(args.args) - len(args.defaults):],
                         args.defaults))
        pairs += [(a, d) for a, d in zip(args.kwonlyargs, args.kw_defaults)
                  if d is not None]
        for arg, default in pairs:
            if arg.arg == "interpret" and isinstance(default, ast.Constant) \
                    and isinstance(default.value, bool):
                self.flag(
                    "KERNEL-INTERPRET", default,
                    f"parameter default interpret={default.value} pins "
                    f"the Pallas backend; default to None and resolve "
                    f"via repro._compat.pallas.resolve_interpret")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        if self.lint_kernel:
            self._check_interpret_default(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        if self.lint_kernel:
            self._check_interpret_default(node)
        self.generic_visit(node)

    # -- dict-order walks -----------------------------------------------

    def _dict_walk_target(self, it: ast.AST) -> Optional[str]:
        """Name of a param-tree-ish dict iterated via
        ``.items()/.keys()/.values()`` (None if the iterable is not such
        a walk, or is wrapped in ``sorted``)."""
        if not (isinstance(it, ast.Call) and isinstance(it.func, ast.Attribute)
                and it.func.attr in ("items", "keys", "values")):
            return None
        base = it.func.value
        name = base.attr if isinstance(base, ast.Attribute) \
            else base.id if isinstance(base, ast.Name) else None
        if name is None or not _PARAM_TREE_NAME.search(name):
            return None
        return f"{name}.{it.func.attr}()"

    def _check_dict_walk(self, iter_node: ast.AST, stmt: ast.AST) -> None:
        target = self._dict_walk_target(iter_node)
        if target:
            self.flag(
                "DET-DICT-ORDER", stmt,
                f"iteration over {target} depends on dict insertion "
                f"order; wrap in sorted(...) so the param-tree walk "
                f"order is canonical")

    def visit_For(self, node: ast.For) -> None:
        self._check_dict_walk(node.iter, node)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_dict_walk(gen.iter, node)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp


def _apply_noqa(findings: List[Finding], source: str) -> List[Finding]:
    lines = source.splitlines()
    kept = []
    for f in findings:
        if f.line is not None and 1 <= f.line <= len(lines):
            m = _NOQA_RE.search(lines[f.line - 1])
            if m:
                codes = m.group("codes")
                if codes is None:
                    continue
                suppressed = {c.strip().upper() for c in codes.split(",")}
                if f.code.upper() in suppressed:
                    continue
        kept.append(f)
    return kept


def lint_source(source: str, path: str,
                config: Optional[LintConfig] = None) -> List[Finding]:
    """Lint one module's source text; ``path`` scopes the path-dependent
    rules and labels the findings."""
    config = config or DEFAULT_CONFIG
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(code="PARSE-ERROR", message=str(e.msg), path=path,
                        line=e.lineno or 0)]
    linter = _Linter(path, config)
    linter.visit(tree)
    return _apply_noqa(linter.findings, source)


def lint_file(path: str, config: Optional[LintConfig] = None
              ) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        return lint_source(f.read(), path, config)


def lint_paths(paths: Iterable[str],
               config: Optional[LintConfig] = None) -> List[Finding]:
    """Lint files and/or directory trees (``*.py``), findings in
    path-sorted order."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                files.extend(os.path.join(root, n)
                             for n in sorted(names) if n.endswith(".py"))
        else:
            files.append(p)
    findings: List[Finding] = []
    for path in files:
        findings.extend(lint_file(path, config))
    return findings
