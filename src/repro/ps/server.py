"""Server-side parameter state: versioned partitions, segmented push/pull.

``PSServer`` is the authoritative copy of the model in the PS execution
subsystem.  Parameters live as one padded flat float32 buffer per sched
layer (the same ``FlatSpec`` layout the dist layer uses, so worker-side
code can reuse ``flatten_tree``/``unflatten_tree`` unchanged), grouped by
owning server shard per :class:`repro.ps.topology.PSTopology`.

Protocol (one message per DynaComm transmission segment):

* **pull** — ``pull_bucket(bucket, version=v)`` serves the segment's layer
  buffers from the *versioned snapshot* ``v``, so a worker whose
  segmented pull is interleaved with other workers' pushes still
  assembles a consistent parameter set (all segments from one version);
* **push** — ``push_bucket(worker, version, bucket, grads)`` accumulates
  the segment's gradients; when the last segment of the plan arrives the
  push *commits*: the bounded-staleness rule (``server.version − v ≤ k``)
  accepts or rejects it atomically, an accepted commit runs the server
  optimizer and bumps the version.

The server keeps the last ``staleness_bound + 1`` snapshots; pulling an
evicted version raises :class:`StaleVersion` — the worker must re-pull at
the head version (exactly what a real PS returns ``ERR_STALE`` for).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.dist.collectives import FLAT_DTYPE, FlatSpec, bucket_bytes
from repro.optim import Optimizer
from repro.ps.topology import PSTopology


class StaleVersion(RuntimeError):
    """Requested snapshot version has been evicted (staleness window)."""


@dataclasses.dataclass(frozen=True)
class PushResult:
    """Outcome of a committed (fully pushed) gradient set."""

    worker: int
    accepted: bool
    staleness: int            # server.version − compute version, at commit
    version: int              # server version after the commit


@dataclasses.dataclass
class TransferLedger:
    """Per-worker byte/message accounting, split by direction.

    Tracks *logical* bytes (the fp32 payload the training step produced)
    and *wire* bytes (what actually crossed the link after compression)
    separately; without a compressor the two coincide.
    """

    pulled_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    pushed_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    pulled_wire_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    pushed_wire_bytes: Dict[int, int] = dataclasses.field(default_factory=dict)
    num_pulls: int = 0
    num_pushes: int = 0
    rejected_pushes: int = 0
    waited_pushes: int = 0        # SSP wait-throttle: commits that blocked
    migrated_bytes: int = 0       # re-sharding: params + opt state moved
    num_reshards: int = 0

    def record_migration(self, nbytes: int) -> None:
        """Account one re-shard's server-to-server state movement."""
        self.migrated_bytes += nbytes
        self.num_reshards += 1

    def record_pull(self, worker: int, nbytes: int,
                    wire_bytes: Optional[int] = None) -> None:
        wire = nbytes if wire_bytes is None else wire_bytes
        self.pulled_bytes[worker] = self.pulled_bytes.get(worker, 0) + nbytes
        self.pulled_wire_bytes[worker] = \
            self.pulled_wire_bytes.get(worker, 0) + wire
        self.num_pulls += 1

    def record_push(self, worker: int, nbytes: int,
                    wire_bytes: Optional[int] = None) -> None:
        wire = nbytes if wire_bytes is None else wire_bytes
        self.pushed_bytes[worker] = self.pushed_bytes.get(worker, 0) + nbytes
        self.pushed_wire_bytes[worker] = \
            self.pushed_wire_bytes.get(worker, 0) + wire
        self.num_pushes += 1

    def compression_ratio(self, direction: str = "push",
                          worker: Optional[int] = None) -> float:
        """logical/wire byte ratio (>1 means smaller on the wire) for one
        direction, fleet-wide or for a single worker; 1.0 with no traffic."""
        if direction == "push":
            logical, wire = self.pushed_bytes, self.pushed_wire_bytes
        elif direction == "pull":
            logical, wire = self.pulled_bytes, self.pulled_wire_bytes
        else:
            raise ValueError(f"direction must be 'push' or 'pull', got "
                             f"{direction!r}")
        workers = logical.keys() if worker is None else [worker]
        num = sum(logical.get(w, 0) for w in workers)
        den = sum(wire.get(w, 0) for w in workers)
        return num / den if den else 1.0


class PSServer:
    """Sharded, versioned parameter store with a bounded-staleness gate."""

    def __init__(self, specs: Sequence[FlatSpec], topology: PSTopology,
                 optimizer: Optimizer, init_flats: Sequence[jnp.ndarray], *,
                 staleness_bound: int = 0, compressor=None):
        if compressor is not None and compressor.scheme == "none":
            compressor = None
        if staleness_bound < 0:
            raise ValueError(f"staleness_bound must be >= 0, got "
                             f"{staleness_bound}")
        if len(init_flats) != len(specs):
            raise ValueError(f"{len(init_flats)} buffers for "
                             f"{len(specs)} specs")
        for l, (flat, spec) in enumerate(zip(init_flats, specs)):
            if flat.shape != (spec.padded,):
                raise ValueError(f"layer {l} buffer shape {flat.shape} != "
                                 f"({spec.padded},)")
        self.specs = tuple(specs)
        self.topology = topology
        self.optimizer = optimizer
        self.staleness_bound = staleness_bound
        self.compressor = compressor
        self._flats: List[jnp.ndarray] = [jnp.asarray(f, FLAT_DTYPE)
                                          for f in init_flats]
        self._opt_state = optimizer.init(self._flats)
        self.version = 0
        self._snapshots: Dict[int, Tuple[jnp.ndarray, ...]] = {
            0: tuple(self._flats)}
        # pending segmented pushes: (worker, version) → {layer: grad flat}
        self._pending: Dict[Tuple[int, int], Dict[int, jnp.ndarray]] = {}
        self.ledger = TransferLedger()

    @property
    def num_layers(self) -> int:
        return len(self.specs)

    # ------------------------------------------------------------------
    # pull: parameters down, one message per segment
    # ------------------------------------------------------------------

    def segment_bytes(self, bucket: Sequence[int]) -> int:
        """Payload of one segment message (unpadded f32 bytes)."""
        return bucket_bytes(self.specs, bucket)

    def push_wire_bytes(self, bucket: Sequence[int]) -> int:
        """Bytes one segment's push puts on the uplink: per-layer
        compressed payloads plus the per-segment header; equals
        ``segment_bytes`` without a compressor."""
        if self.compressor is None:
            return self.segment_bytes(bucket)
        wire = sum(float(self.compressor.wire_bytes(self.specs[l].total * 4))
                   for l in bucket)
        return int(round(wire + self.compressor.segment_overhead_bytes))

    def pull_bucket(self, bucket: Sequence[int], *,
                    version: Optional[int] = None,
                    worker: Optional[int] = None
                    ) -> Tuple[int, Dict[int, jnp.ndarray]]:
        """Serve one segment from snapshot ``version`` (default: head).

        Returns ``(version, {layer: flat buffer})``.  Workers pin the
        version of their first segment and pass it for the rest of the
        plan, getting a consistent parameter set under concurrent pushes.
        """
        if not bucket:
            raise ValueError("cannot pull an empty segment")
        v = self.version if version is None else version
        if v not in self._snapshots:
            raise StaleVersion(
                f"version {v} evicted (head {self.version}, window "
                f"{self.staleness_bound}); re-pull at the head version")
        snap = self._snapshots[v]
        out = {l: snap[l] for l in bucket}
        if worker is not None:
            self.ledger.record_pull(worker, self.segment_bytes(bucket))
        return v, out

    # ------------------------------------------------------------------
    # push: gradients up, one message per segment, commit on the last
    # ------------------------------------------------------------------

    def push_bucket(self, worker: int, version: int, bucket: Sequence[int],
                    grads: Dict[int, jnp.ndarray]
                    ) -> Optional[PushResult]:
        """Accumulate one segment's gradients; commit when complete.

        Returns ``None`` while segments are outstanding, a
        :class:`PushResult` once all ``num_layers`` gradients arrived —
        rejected pushes (staleness beyond the bound at commit time)
        discard the pending set without touching the parameters.
        """
        missing = [l for l in bucket if l not in grads]
        if missing:
            raise ValueError(f"push of bucket {tuple(bucket)} lacks grads "
                             f"for layers {missing}")
        key = (worker, version)
        pending = self._pending.setdefault(key, {})
        for l in bucket:
            if l in pending:
                raise ValueError(f"layer {l} pushed twice by worker "
                                 f"{worker} at version {version}")
            pending[l] = jnp.asarray(grads[l], FLAT_DTYPE)
        self.ledger.record_push(worker, self.segment_bytes(bucket),
                                wire_bytes=self.push_wire_bytes(bucket))
        if len(pending) < self.num_layers:
            return None
        del self._pending[key]
        staleness = self.version - version
        if staleness > self.staleness_bound:
            self.ledger.rejected_pushes += 1
            return PushResult(worker=worker, accepted=False,
                              staleness=staleness, version=self.version)
        grad_list = [pending[l] for l in range(self.num_layers)]
        self._flats, self._opt_state = self.optimizer.update(
            grad_list, self._opt_state, self._flats)
        self.version += 1
        self._snapshots[self.version] = tuple(self._flats)
        self._evict()
        return PushResult(worker=worker, accepted=True, staleness=staleness,
                          version=self.version)

    def push_aggregated(self, pushes: Sequence[
            Tuple[int, int, Dict[int, jnp.ndarray]]]) -> List[PushResult]:
        """Commit several *same-version* complete gradient sets as ONE
        optimizer step (the SSP wait throttle's BSP aggregation mode).

        ``pushes`` is a sequence of ``(worker, version, {layer: grad
        flat})`` entries, every one covering all ``num_layers`` layers and
        pinned at the same version.  The bounded-staleness gate applies to
        the shared version once; an accepted group applies the *mean* of
        the gradients — k=0 with every worker in the group is exactly
        bulk-synchronous data parallelism — and bumps the version once.
        Returns one :class:`PushResult` per entry, in order.
        """
        if not pushes:
            raise ValueError("cannot aggregate an empty push group")
        versions = {v for _, v, _ in pushes}
        if len(versions) != 1:
            raise ValueError(f"aggregated pushes must share one version, "
                             f"got {sorted(versions)}")
        (version,) = versions
        for worker, _, grads in pushes:
            missing = [l for l in range(self.num_layers) if l not in grads]
            if missing:
                raise ValueError(f"worker {worker}'s aggregated push lacks "
                                 f"grads for layers {missing}")
        staleness = self.version - version
        if staleness > self.staleness_bound:
            self.ledger.rejected_pushes += len(pushes)
            return [PushResult(worker=w, accepted=False,
                               staleness=staleness, version=self.version)
                    for w, _, _ in pushes]
        n = len(pushes)
        mean: List[jnp.ndarray] = []
        for l in range(self.num_layers):
            acc = jnp.asarray(pushes[0][2][l], FLAT_DTYPE)
            for _, _, grads in pushes[1:]:
                acc = acc + jnp.asarray(grads[l], FLAT_DTYPE)
            mean.append(acc / n)
        self._flats, self._opt_state = self.optimizer.update(
            mean, self._opt_state, self._flats)
        self.version += 1
        self._snapshots[self.version] = tuple(self._flats)
        self._evict()
        return [PushResult(worker=w, accepted=True, staleness=staleness,
                           version=self.version) for w, _, _ in pushes]

    def _evict(self) -> None:
        floor = self.version - self.staleness_bound
        for v in [v for v in self._snapshots if v < floor]:
            del self._snapshots[v]

    def head_distance(self, version: int) -> int:
        """Staleness a push computed at ``version`` would have if it
        committed *now* (the quantity the bounded-staleness gate compares
        against ``staleness_bound``)."""
        return self.version - version

    def drop_pending(self, worker: int) -> int:
        """Discard every uncommitted segmented push of ``worker`` (crash /
        departure cleanup); returns how many pending sets were dropped.
        Segment bytes already on the wire stay in the ledger — a crashed
        worker's partial push cost real uplink traffic."""
        keys = [k for k in self._pending if k[0] == worker]
        for k in keys:
            del self._pending[k]
        return len(keys)

    # ------------------------------------------------------------------
    # elastic re-sharding
    # ------------------------------------------------------------------

    def reshard(self, topology: PSTopology) -> Dict[str, int]:
        """Re-partition the layers across ``topology``'s server shards
        **without losing versioned state**.

        Shard ownership is a pure view over the per-layer buffers
        (:meth:`shard_view`), so splitting or merging shards moves layer
        state between servers but never rewrites it: the head parameters,
        every retained snapshot, the optimizer moments, and the version
        counter are all bit-identical across the call — a pull pinned at
        a pre-migration version returns the exact pre-migration bytes.
        What *does* cost something is the migration itself: every layer
        whose owning shard changed ships its parameters plus its
        optimizer moment slots server-to-server, accounted in
        ``ledger.migrated_bytes``.

        Returns ``{"moved_layers": n, "migrated_bytes": b,
        "num_servers": S}``.  The new topology may also change the worker
        set — shard routing only depends on ``num_servers``.
        """
        old_owner = {l: self.topology.shard_of_layer(l, self.num_layers)
                     for l in range(self.num_layers)}
        self.topology = topology
        moved = [l for l in range(self.num_layers)
                 if topology.shard_of_layer(l, self.num_layers)
                 != old_owner[l]]
        # per-layer moment slots present under this optimizer (SGD: 0,
        # momentum: 1, AdamW: 2) — each is parameter-sized fp32
        slots = sum(1 for m in (self._opt_state.mu, self._opt_state.nu)
                    if m is not None)
        migrated = sum(self.specs[l].total * 4 for l in moved) * (1 + slots)
        self.ledger.record_migration(migrated)
        return {"moved_layers": len(moved), "migrated_bytes": migrated,
                "num_servers": topology.num_servers}

    # ------------------------------------------------------------------
    # checkpointing (``repro.runtime`` save_state/restore_state)
    # ------------------------------------------------------------------

    def state_dict(self) -> Dict[str, object]:
        """Head parameters + optimizer state as a checkpointable pytree.

        Pending segmented pushes and evicted snapshots are deliberately
        excluded: checkpoint between event-loop runs, when the server is
        quiescent."""
        return {"flats": list(self._flats), "opt": self._opt_state,
                "version": np.asarray(self.version, np.int64)}

    def load_state_dict(self, state: Dict[str, object]) -> None:
        flats = [jnp.asarray(f, FLAT_DTYPE) for f in state["flats"]]
        if len(flats) != len(self.specs):
            raise ValueError(f"{len(flats)} buffers for "
                             f"{len(self.specs)} specs")
        for l, (flat, spec) in enumerate(zip(flats, self.specs)):
            if flat.shape != (spec.padded,):
                raise ValueError(f"layer {l} buffer shape {flat.shape} != "
                                 f"({spec.padded},)")
        self._flats = flats
        self._opt_state = state["opt"]
        self.version = int(state["version"])
        self._snapshots = {self.version: tuple(self._flats)}
        self._pending = {}

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------

    @property
    def snapshot_versions(self) -> Tuple[int, ...]:
        return tuple(sorted(self._snapshots))

    def flats(self) -> List[jnp.ndarray]:
        """The head-version parameter buffers."""
        return list(self._flats)

    def shard_view(self) -> Dict[int, Tuple[int, ...]]:
        """{shard: owned layer ids} under the topology's partition."""
        return {s: self.topology.layers_of_shard(s, self.num_layers)
                for s in range(self.topology.num_servers)}

    def shard_bytes(self) -> Dict[int, int]:
        """Unpadded parameter bytes resident per server shard."""
        return {s: sum(self.specs[l].total * 4 for l in layers)
                for s, layers in self.shard_view().items()}
