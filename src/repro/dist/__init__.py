"""Distribution layer: DynaComm-bucketed collectives, sharding rules, and
the ZeRO trainer (paper pull/push procedures as real ring collectives)."""

from repro.dist.collectives import (FlatSpec, flatten_tree, gather_bucket,
                                    make_flat_spec, reduce_scatter_bucket,
                                    unflatten_tree)
from repro.dist.dynamic import DynamicTrainer
from repro.runtime.replan import (RescheduleEvent, hlo_collective_counts,
                                  sequential_plan)
from repro.dist.sharding import (batch_shardings, cache_shardings,
                                 param_pspec, params_shardings)
from repro.dist.zero import ZeroTrainer

__all__ = [
    "FlatSpec", "make_flat_spec", "flatten_tree", "unflatten_tree",
    "gather_bucket", "reduce_scatter_bucket",
    "param_pspec", "params_shardings", "batch_shardings", "cache_shardings",
    "ZeroTrainer",
    "DynamicTrainer", "RescheduleEvent", "hlo_collective_counts",
    "sequential_plan",
]
