"""DynaComm's DP-based scheduling algorithms (paper Algorithms 3 and 4).

Forward Bellman equation (paper eq. 13)::

    F[m][n] = min_{0<=k<m} { max(F[k][n-1], n*Δt + Σ_{1<=l<=m} pt_l)
                             + Σ_{k+1<=l<=m} fc_l }          1<=n<=m<=L

``F[m][n]`` is the earliest completion time of the first ``m`` layers'
forward compute given ``n`` transmission mini-procedures cover their
parameters.  The n-th transmission ends at ``n*Δt + Σ pt_{1..m}`` because
transmissions are serialized back-to-back on the link.

Backward Bellman equation (paper eq. 14)::

    B[m][n] = min_{0<=k<m} { max(B[k][n-1], Σ_{L-m+1<=l<=L} bc_l)
                             + Δt + Σ_{L-m+1<=l<=L-k} gt_l }  1<=n<=m<=L

``B[m][n]`` is the earliest completion time of the *gradient transmissions*
of the last ``m`` layers using ``n`` mini-procedures; backward compute runs
stall-free from layer L downwards.

Both run in O(L^3) time / O(L^2) space (paper Section IV-B4).  The inner
minimization is vectorized with numpy so the Fig. 12 complexity benchmark is
tractable at hundreds of layers.

Warm re-planning (``repro.core.planner``): both DPs accept an
``incumbent=`` upper bound — typically a previously-optimal decision's
O(L) evaluation under the *new* costs — and prune the column sweep via a
monotone per-column lower bound.  Pruned solves return *exactly* the
same segments/time/num_transmissions as a full solve: the prune carries
a small relative slack (``_PRUNE_SLACK``) because the incumbent's O(L)
summation order differs from the DP's prefix-sum arithmetic by a few
ULP, and slack only ever *adds* columns to the sweep — smallest-``n``
argmin tie-breaks are preserved.  Only the untouched table columns stay
at ``inf``.  ``fc_pref=``/``bc_pref=`` let a caller reuse compute-side
prefix sums when only bandwidth/Δt scalars changed between plans.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.costmodel import (LayerCosts, Segment, backward_time,
                                  forward_time)

_INF = np.inf

#: relative slack on the incumbent prune: a feasible plan's O(L)
#: evaluation can undershoot the DP's prefix-sum value of the *same*
#: plan by a few ULP (different summation order), so a strict bound
#: could prune the optimal column.  Slack never removes columns a full
#: sweep would keep — it only computes extra ones — so pruned results
#: stay exactly equal to full solves.
_PRUNE_SLACK = 1e-9


@dataclasses.dataclass(frozen=True)
class DPResult:
    segments: Tuple[Segment, ...]
    time: float                  # optimal phase time (== f_m of segments)
    table: np.ndarray            # F or B, shape (L+1, L+1)
    num_transmissions: int


def _traceback(path: np.ndarray, L: int, n_star: int) -> Tuple[int, ...]:
    """Recover the k-chain 0 = k_0 < k_1 < ... < k_{n*} = L from Path."""
    bounds = [L]
    m, n = L, n_star
    while n > 0:
        k = int(path[m, n])
        if k < 0:
            raise RuntimeError("broken DP path")
        bounds.append(k)
        m, n = k, n - 1
    if bounds[-1] != 0:
        raise RuntimeError("DP path did not terminate at 0")
    return tuple(reversed(bounds))


def dp_forward(costs: LayerCosts, *, incumbent: Optional[float] = None,
               fc_pref: Optional[np.ndarray] = None) -> DPResult:
    """Algorithm 3 — optimal parameter-transmission segmentation.

    ``incumbent`` is an upper bound on the optimum (any feasible
    segmentation's ``forward_time``); columns whose lower bound strictly
    exceeds the best value seen are skipped.  ``fc_pref`` reuses a
    precomputed compute prefix-sum vector (length L+1, leading 0)."""
    L = costs.num_layers
    pt_pref = np.concatenate([[0.0], np.cumsum(costs.pt)])   # Σ pt_{1..m}
    if fc_pref is None:
        fc_pref = np.concatenate([[0.0], np.cumsum(costs.fc)])  # Σ fc_{1..m}

    F = np.full((L + 1, L + 1), _INF)
    path = np.full((L + 1, L + 1), -1, dtype=np.int64)
    F[0, 0] = 0.0

    # best value of F[L, ·] seen so far; the incumbent seeds the pruning
    best = _INF if incumbent is None else float(incumbent)
    ms = np.arange(L + 1)
    for n in range(1, L + 1):
        # Every n-column value at m = L pays all n serialized
        # transmissions plus at least the last layer's compute after the
        # last one: lb is monotone increasing in n, so once it clears
        # the best finished value (plus FP slack) no later column can
        # win, and the smallest-n argmin tie-break stays identical to a
        # full sweep.
        lb = n * costs.dt + pt_pref[L] + float(costs.fc[-1])
        if lb > best + _PRUNE_SLACK * max(1.0, abs(best)):
            break
        prev = F[:, n - 1]                       # F[k][n-1], k = 0..L
        # arrive[m]: when the n-th transmission (ending at layer m) completes
        arrive = n * costs.dt + pt_pref
        # cand[m, k] = max(prev[k], arrive[m]) + (fc_pref[m] - fc_pref[k])
        cand = np.maximum(prev[None, :], arrive[:, None]) \
            + fc_pref[:, None] - fc_pref[None, :]
        cand[ms[:, None] <= ms[None, :]] = _INF  # require k < m
        ks = np.argmin(cand, axis=1)
        vals = cand[ms, ks]
        valid = ms >= n
        F[valid, n] = vals[valid]
        path[valid, n] = ks[valid]
        best = min(best, float(F[L, n]))

    n_star = int(np.argmin(F[L, 1:]) + 1)
    t_star = float(F[L, n_star])
    bounds = _traceback(path, L, n_star)
    segments = tuple((bounds[i] + 1, bounds[i + 1]) for i in range(len(bounds) - 1))
    # Sanity: the DP objective must equal the O(L) cost function.
    assert abs(forward_time(costs, segments) - t_star) <= 1e-9 * max(1.0, t_star)
    return DPResult(segments=segments, time=t_star, table=F,
                    num_transmissions=n_star)


def dp_backward(costs: LayerCosts, *, incumbent: Optional[float] = None,
                bc_pref: Optional[np.ndarray] = None) -> DPResult:
    """Algorithm 4 — optimal gradient-transmission segmentation.

    ``incumbent``/``bc_pref`` as in :func:`dp_forward` (``bc_pref`` is
    the prefix sum of the *reversed* backward compute costs)."""
    L = costs.num_layers
    # Reversed views: position j (1-indexed) = original layer L+1-j.
    bc_rev = costs.bc[::-1]
    gt_rev = costs.gt[::-1]
    if bc_pref is None:
        bc_pref = np.concatenate([[0.0], np.cumsum(bc_rev)])  # Σ bc last-m
    gt_pref = np.concatenate([[0.0], np.cumsum(gt_rev)])     # Σ gt last-m layers

    B = np.full((L + 1, L + 1), _INF)
    path = np.full((L + 1, L + 1), -1, dtype=np.int64)
    B[0, 0] = 0.0

    best = _INF if incumbent is None else float(incumbent)
    ms = np.arange(L + 1)
    for n in range(1, L + 1):
        # By induction B[m][n] >= n*Δt + Σ gt_{1..m} (each of the n
        # pushes pays its own Δt and the gt ranges tile [1, m]), so
        # B[L][n] >= n*Δt + gt_pref[L] — monotone in n.  Same FP slack
        # as the forward sweep.
        lb = n * costs.dt_push + gt_pref[L]
        if lb > best + _PRUNE_SLACK * max(1.0, abs(best)):
            break
        prev = B[:, n - 1]
        ready = bc_pref                              # compute-done time per m
        # cand[m, k] = max(prev[k], ready[m]) + Δt + (gt_pref[m] - gt_pref[k])
        cand = np.maximum(prev[None, :], ready[:, None]) + costs.dt_push \
            + gt_pref[:, None] - gt_pref[None, :]
        cand[ms[:, None] <= ms[None, :]] = _INF
        ks = np.argmin(cand, axis=1)
        vals = cand[ms, ks]
        valid = ms >= n
        B[valid, n] = vals[valid]
        path[valid, n] = ks[valid]
        best = min(best, float(B[L, n]))

    n_star = int(np.argmin(B[L, 1:]) + 1)
    t_star = float(B[L, n_star])
    bounds = _traceback(path, L, n_star)
    # bounds are in reversed coordinates: reversed position j covers original
    # layer L+1-j; chain segment (k, m] reversed = original layers
    # [L-m+1 .. L-k], transmitted top-down.
    segments = tuple((L - bounds[i + 1] + 1, L - bounds[i])
                     for i in range(len(bounds) - 1))
    assert abs(backward_time(costs, segments) - t_star) <= 1e-9 * max(1.0, t_star)
    return DPResult(segments=segments, time=t_star, table=B,
                    num_transmissions=n_star)


def dynacomm_schedule(costs: LayerCosts):
    """Both directions; returns ((fwd_segments, bwd_segments), total_time)."""
    f = dp_forward(costs)
    b = dp_backward(costs)
    return (f.segments, b.segments), f.time + b.time


@dataclasses.dataclass(frozen=True)
class PartitionResult:
    """Optimal contiguous min-max partition (pipeline stage split)."""

    segments: Tuple[Segment, ...]   # 1-indexed inclusive, tiles 1..L
    bottleneck: float               # max per-segment load (the objective)
    table: np.ndarray               # P, shape (L+1, S+1)


def dp_partition(loads, num_parts: int) -> PartitionResult:
    """Split ``loads`` into ``num_parts`` contiguous pieces minimizing the
    maximum piece sum (the pipeline *bottleneck stage*).

    The Bellman recurrence mirrors the transmission DPs above, with
    ``max`` replacing the comm/compute coupling::

        P[m][s] = min_{s-1<=k<m} max(P[k][s-1], Σ_{k+1<=l<=m} load_l)

    O(S·L²) time via the same vectorized candidate matrix; ties break to
    the smallest split point ``k`` (``np.argmin`` keeps the first
    minimum), so results are deterministic.  Every piece is non-empty:
    ``1 <= num_parts <= len(loads)`` is required.
    """
    loads = np.asarray(loads, dtype=np.float64)
    if loads.ndim != 1 or loads.size == 0:
        raise ValueError("loads must be a non-empty 1-D array")
    if np.any(loads < 0):
        raise ValueError("loads must be non-negative")
    L = int(loads.size)
    S = int(num_parts)
    if not 1 <= S <= L:
        raise ValueError(f"num_parts must be in [1, {L}], got {num_parts}")

    pref = np.concatenate([[0.0], np.cumsum(loads)])
    P = np.full((L + 1, S + 1), _INF)
    path = np.full((L + 1, S + 1), -1, dtype=np.int64)
    P[0, 0] = 0.0

    ms = np.arange(L + 1)
    for s in range(1, S + 1):
        prev = P[:, s - 1]                       # P[k][s-1], k = 0..L
        # cand[m, k] = max(prev[k], pref[m] - pref[k])
        cand = np.maximum(prev[None, :], pref[:, None] - pref[None, :])
        cand[ms[:, None] <= ms[None, :]] = _INF  # require k < m
        ks = np.argmin(cand, axis=1)
        vals = cand[ms, ks]
        valid = ms >= s
        P[valid, s] = vals[valid]
        path[valid, s] = ks[valid]

    t_star = float(P[L, S])
    bounds = _traceback(path, L, S)
    segments = tuple((bounds[i] + 1, bounds[i + 1])
                     for i in range(len(bounds) - 1))
    sums = tuple(float(pref[hi] - pref[lo - 1]) for lo, hi in segments)
    assert abs(max(sums) - t_star) <= 1e-9 * max(1.0, t_star)
    return PartitionResult(segments=segments, bottleneck=t_star, table=P)
