"""Measured per-sched-layer fc/bc timings (the mxnet.profiler analogue).

Hoisted out of ``repro.dist.dynamic`` so both dynamic drivers share one
implementation: each sched layer's forward apply and VJP is jitted and
timed standalone against a :class:`repro.core.profiler.LayerTimingHook`.
The ZeRO and PS trainers share the flat-buffer state layout, so the same
routine measures either — the PS driver additionally rescales the host
timings to each worker's compute rate
(:meth:`repro.ps.topology.PSTopology.topology_costs_measured`).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_lib


def measurement_due(fc_bc: Optional[Tuple], measured_epoch: int,
                    epoch: int, remeasure_every: int, *,
                    force: bool = False) -> bool:
    """The shared re-measurement rule of both dynamic drivers: measure
    when nothing is cached, when forced (a drift detector fired), or when
    the cache is ``remeasure_every`` re-plan epochs old
    (``remeasure_every == 0`` ⇒ measure once and keep it)."""
    stale = (remeasure_every > 0 and
             epoch - measured_epoch >= remeasure_every)
    return fc_bc is None or stale or force


def measure_layer_times(zero, hook, state, batch, *, iters: int) -> None:
    """Record ``hook.warmup + iters`` fc/bc wall-time samples per sched
    layer into ``hook`` (resetting it first).

    ``zero`` is a :class:`repro.dist.zero.ZeroTrainer` (the PS trainer's
    contained one qualifies): its per-layer applies are jitted standalone
    — one compilation per distinct layer kind, since same-kind layers
    share shapes — and timed on this host's devices.
    """
    tr = zero
    Ls, kinds = tr.num_layers, tr._kinds
    calls = hook.warmup + iters
    trees = jax.device_get(
        model_lib.sched_layer_trees(tr.params_from_state(state)))
    hook.reset()

    one = jnp.ones((), jnp.float32)
    aux_ct = jnp.asarray(tr.aux_weight, jnp.float32)

    embed_fwd = jax.jit(lambda p, b: tr._apply_embed(p, b))
    h0 = jax.block_until_ready(embed_fwd(trees[0], batch))
    ct_h = jnp.ones_like(h0)
    timed = hook.timed("fc", 0, embed_fwd)
    for _ in range(calls):
        timed(trees[0], batch)
    embed_bwd = jax.jit(lambda p, b, ct: jax.vjp(
        lambda pp: tr._apply_embed(pp, b), p)[1](ct))
    timed = hook.timed("bc", 0, embed_bwd)
    for _ in range(calls):
        timed(trees[0], batch, ct_h)

    # one jitted fwd/bwd per distinct layer kind — layers of the same
    # kind share the compilation (their shapes match)
    blk_fwd = {k: jax.jit(lambda p, x, _k=k: tr._apply_block(p, x, _k))
               for k in set(kinds)}
    blk_bwd = {k: jax.jit(lambda p, x, ct, a, _k=k: jax.vjp(
                   lambda pp, xx: tr._apply_block(pp, xx, _k), p, x
               )[1]((ct, a)))
               for k in set(kinds)}
    for l in range(1, Ls - 1):
        kind = kinds[l - 1]
        timed = hook.timed("fc", l, blk_fwd[kind])
        for _ in range(calls):
            timed(trees[l], h0)
        timed = hook.timed("bc", l, blk_bwd[kind])
        for _ in range(calls):
            timed(trees[l], h0, ct_h, aux_ct)

    fin_fwd = jax.jit(lambda pf, pe, x, b: tr._apply_final(pf, pe, x, b))
    timed = hook.timed("fc", Ls - 1, fin_fwd)
    for _ in range(calls):
        timed(trees[Ls - 1], trees[0], h0, batch)
    fin_bwd = jax.jit(lambda pf, pe, x, b, ct: jax.vjp(
        lambda a, c, d: tr._apply_final(a, c, d, b), pf, pe, x)[1](ct))
    timed = hook.timed("bc", Ls - 1, fin_bwd)
    for _ in range(calls):
        timed(trees[Ls - 1], trees[0], h0, batch, one)
