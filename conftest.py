# Ensures `import benchmarks` works from pytest (adds repo root to sys.path).
