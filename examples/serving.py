"""Serve a small model with batched requests: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serving.py --arch gemma2-2b --tokens 32
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serve.decode import batched_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    t0 = time.perf_counter()
    out = batched_generate(cfg, params, prompts,
                           max_new_tokens=args.tokens,
                           greedy=False, key=jax.random.PRNGKey(2))
    dt = time.perf_counter() - t0
    total = args.batch * args.tokens
    print(f"arch {cfg.name}: generated {total} tokens "
          f"({args.batch} requests x {args.tokens}) in {dt:.2f}s "
          f"= {total / dt:.1f} tok/s")
    print("sample continuation token ids:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
