"""Inter-stage activation transfers as DynaComm-scheduled segments.

Each pipeline boundary b (stage b → stage b+1) moves M micro-batch
activation tensors forward and M activation-gradient tensors backward.
The transfer problem is *isomorphic* to the paper's push/pull problem:
chunks of the boundary tensor play the role of layers, the receiving
stage's compute plays the role of layer compute, and
``dp_forward``/``dp_backward`` decide which chunks batch into one
message (amortizing Δt) versus segment to overlap with stage compute.

The virtual :class:`~repro.core.costmodel.LayerCosts` for boundary b has
``M * chunks`` entries, one per (micro-batch, chunk):

* ``pt``/``gt`` — per-chunk activation / activation-grad wire time;
* ``fc`` — the receiving stage's per-micro-batch forward compute,
  carried by each micro-batch's *last* chunk (compute can only start
  once the whole micro-batch has arrived);
* ``bc`` — the producing stage's per-micro-batch backward compute,
  carried by each micro-batch's *first* chunk (the grad is ready once
  that compute finishes).

The *whole-tensor* baseline is a single message covering every chunk —
no overlap, one Δt — which is what a naive pipeline does.  Solves ride
the PR 9 :class:`~repro.core.planner.Planner` seam, so repeated
boundaries (homogeneous stages) collapse to cache hits and re-plans
warm-start.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.costmodel import (LayerCosts, backward_time, forward_time)
from repro.core.scheduler import Decision, schedule

#: virtual-layer count guard: chunks * microbatches is the DP's L
_MAX_VIRTUAL_LAYERS = 4096


def boundary_costs(activation_bytes: float, microbatches: int, *, net,
                   stage_fwd_s: float, stage_bwd_s: float,
                   chunks: int = 1) -> LayerCosts:
    """Virtual LayerCosts for one stage boundary (see module docstring).

    ``activation_bytes`` is one micro-batch's boundary tensor;
    ``stage_fwd_s`` / ``stage_bwd_s`` are the receiving stage's forward
    and producing stage's backward per-micro-batch compute seconds.
    """
    if microbatches < 1 or chunks < 1:
        raise ValueError("microbatches and chunks must be >= 1")
    n = microbatches * chunks
    if n > _MAX_VIRTUAL_LAYERS:
        raise ValueError(f"microbatches*chunks = {n} exceeds "
                         f"{_MAX_VIRTUAL_LAYERS} virtual layers")
    chunk_time = float(net.transfer_time(
        np.asarray(activation_bytes / chunks)))
    pt = np.full(n, chunk_time)
    fc = np.zeros(n)
    bc = np.zeros(n)
    fc[chunks - 1::chunks] = float(stage_fwd_s)   # last chunk of each mb
    bc[0::chunks] = float(stage_bwd_s)            # first chunk of each mb
    return LayerCosts(pt=pt, fc=fc, bc=bc, gt=pt.copy(), dt=float(net.dt))


def whole_tensor_decision(costs: LayerCosts) -> Decision:
    """The unsegmented baseline: one message per direction, no overlap."""
    L = costs.num_layers
    return ((1, L),), ((1, L),)


@dataclasses.dataclass(frozen=True)
class TransferPlan:
    """One boundary's planned transfers, segmented vs whole-tensor."""

    boundary: int
    decision: Decision          # over virtual (micro-batch, chunk) layers
    fwd_time: float             # makespan of segmented forward transfers
    bwd_time: float
    whole_fwd_time: float       # single-message baseline
    whole_bwd_time: float
    fwd_compute_s: float        # Σ fc: the no-transfer compute floor
    bwd_compute_s: float        # Σ bc
    microbatches: int
    chunks: int

    @property
    def speedup(self) -> float:
        """whole / segmented makespan (>= 1 when segmentation wins)."""
        seg = self.fwd_time + self.bwd_time
        whole = self.whole_fwd_time + self.whole_bwd_time
        return whole / seg if seg > 0 else 1.0

    @property
    def effective_waits(self) -> Tuple[float, float]:
        """Per-micro-batch effective (fwd, bwd) boundary wait seconds.

        The segmented makespan minus the pure-compute floor, amortized
        over micro-batches — what :func:`repro.pipeline.schedule.simulate`
        should charge per boundary crossing."""
        fwd = max(0.0, self.fwd_time - self.fwd_compute_s) / self.microbatches
        bwd = max(0.0, self.bwd_time - self.bwd_compute_s) / self.microbatches
        return fwd, bwd

    @property
    def whole_waits(self) -> Tuple[float, float]:
        """Per-micro-batch waits under the whole-tensor baseline."""
        fwd = max(0.0, self.whole_fwd_time - self.fwd_compute_s) \
            / self.microbatches
        bwd = max(0.0, self.whole_bwd_time - self.bwd_compute_s) \
            / self.microbatches
        return fwd, bwd


def plan_boundary(boundary: int, costs: LayerCosts, *,
                  planner: Optional[object] = None,
                  strategy: str = "dynacomm",
                  microbatches: int, chunks: int = 1) -> TransferPlan:
    """Plan one boundary's transfers; ``planner=`` rides the memo/warm
    seams so homogeneous boundaries are one DP solve + cache hits."""
    if planner is not None:
        decision = planner.decide(costs, strategy)
    else:
        decision = schedule(costs, strategy)
    f_seg, b_seg = decision
    wf, wb = whole_tensor_decision(costs)
    return TransferPlan(
        boundary=boundary,
        decision=decision,
        fwd_time=forward_time(costs, f_seg),
        bwd_time=backward_time(costs, b_seg),
        whole_fwd_time=forward_time(costs, wf),
        whole_bwd_time=backward_time(costs, wb),
        fwd_compute_s=float(np.sum(costs.fc)),
        bwd_compute_s=float(np.sum(costs.bc)),
        microbatches=microbatches,
        chunks=chunks,
    )
