"""Pallas API compatibility aliases (jax renamed these across versions)."""

from typing import Optional

import jax
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 exposes this as TPUCompilerParams, newer jax as CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def default_interpret() -> bool:
    """Whether Pallas kernels should run in interpret mode by default.

    Compiled Pallas requires a real TPU backend; everywhere else (CPU CI,
    GPU hosts) the kernels must fall back to interpret mode.  All kernel
    entry points take ``interpret=None`` and resolve it here so the choice
    lives in exactly one place.
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` → backend auto-detect; explicit booleans pass through."""
    return default_interpret() if interpret is None else bool(interpret)
