"""Measured drift detection over the fleet.

``core.profiler.EwmaDriftDetector`` watches ONE scalar stream (a link
bandwidth) and asks the dynamic trainers to re-plan when it shifts; this
module is its fleet-scale successor: the same EWMA / relative-shift /
patience discipline, but keyed **per worker** and fed the quantity the
event engine actually observes — each worker's commit gap (admission to
commit, simulated seconds).  Nothing here is scripted: a worker that
silently slows down (a ``drift`` fleet event, thermal throttling, a
congested uplink) changes its observed gaps, the detector's per-worker
baseline breaches for ``patience`` consecutive commits, and the trainer
re-plans with that worker's *believed* compute rate scaled to match the
measurement.

The detector is plain data (no wall clock, no RNG) and round-trips
through ``state_dict``/``load_state_dict`` so resumed runs detect
bit-identically.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass
class _WorkerStream:
    """EWMA state of one worker's observed commit gaps."""

    ewma: float = 0.0
    baseline: float = 0.0
    breaches: int = 0
    samples: int = 0


class FleetDriftDetector:
    """Per-worker EWMA drift detection on observed commit gaps.

    Parameters mirror :class:`repro.core.profiler.EwmaDriftDetector`:
    ``alpha`` smooths each worker's gap stream, the first ``warmup``
    observations seed its baseline, and a relative shift
    ``|ewma − baseline| / baseline ≥ threshold`` sustained for
    ``patience`` consecutive observations triggers (re-seeding the
    baseline so the next drift is measured against the new regime).
    """

    def __init__(self, *, alpha: float = 0.2, threshold: float = 0.3,
                 patience: int = 3, warmup: int = 2):
        if not 0 < alpha <= 1:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if threshold <= 0:
            raise ValueError(f"threshold must be positive, got {threshold}")
        if patience < 1 or warmup < 1:
            raise ValueError("patience and warmup must be >= 1")
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.warmup = warmup
        self._streams: Dict[int, _WorkerStream] = {}

    def observe(self, worker: int, gap: float) -> bool:
        """Feed one commit gap; True when ``worker``'s stream drifted."""
        if gap <= 0:
            raise ValueError(f"commit gap must be positive, got {gap}")
        st = self._streams.get(worker)
        if st is None:
            st = self._streams[worker] = _WorkerStream()
        st.samples += 1
        st.ewma = gap if st.samples == 1 else \
            self.alpha * gap + (1 - self.alpha) * st.ewma
        if st.samples <= self.warmup:
            st.baseline = st.ewma
            return False
        rel = abs(st.ewma - st.baseline) / st.baseline
        st.breaches = st.breaches + 1 if rel >= self.threshold else 0
        if st.breaches >= self.patience:
            st.baseline = st.ewma
            st.breaches = 0
            return True
        return False

    def observed_gap(self, worker: int) -> Optional[float]:
        """``worker``'s current EWMA commit gap (None before any)."""
        st = self._streams.get(worker)
        return st.ewma if st is not None and st.samples else None

    def forget(self, worker: int) -> None:
        """Drop a departed worker's stream."""
        self._streams.pop(worker, None)

    # -- serialization -------------------------------------------------

    def state_dict(self) -> dict:
        return {str(w): [st.ewma, st.baseline, st.breaches, st.samples]
                for w, st in self._streams.items()}

    def load_state_dict(self, state: dict) -> None:
        self._streams = {
            int(w): _WorkerStream(ewma=float(e), baseline=float(b),
                                  breaches=int(br), samples=int(s))
            for w, (e, b, br, s) in state.items()}
