"""Dynamic parameter-server demo: re-planning over a drifting topology,
SSP wait-at-barrier vs stale-push rejection, and BSP push aggregation.

Three acts:

1. **run-time re-planning** — every worker's uplink degrades mid-training
   (``--up-factor``× slower at ``--shift-epoch``).  The ``dynamic-ps``
   runtime — one ``RuntimeConfig`` literal through ``build_runtime`` —
   re-projects the topology's costs on each epoch boundary, re-runs the
   straggler-minimizing consensus decision, and swaps the compiled
   pull/push step from its plan-keyed AOT cache;
2. **SSP throttling** — a 4x-slower edge worker at staleness k=1: the
   `reject` throttle starves it (every push arrives > k versions stale
   and is evicted), the `wait` throttle blocks the fast workers at the
   barrier instead, so the slow worker contributes every cycle and the
   staleness bound still holds;
3. **BSP aggregation** — `wait` + `aggregate` at k=0: same-version
   pushes commit as ONE mean-gradient optimizer step, so the round is
   true bulk-synchronous data parallelism (one version bump per round of
   W pushes) instead of W serialized commits.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/dynamic_ps.py
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import small_cnn_init, small_cnn_loss
from repro.optim import sgd
from repro.ps import AsyncPSTrainer, PSTopology, asymmetric_link
from repro.runtime import (RuntimeConfig, ScheduleConfig, TopologyConfig,
                           build_runtime)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--steps-per-epoch", type=int, default=4)
    ap.add_argument("--shift-epoch", type=int, default=1)
    ap.add_argument("--up-factor", type=float, default=10.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--async-pushes", type=int, default=16)
    args = ap.parse_args()

    # --- 1. re-planning across an uplink degradation -------------------
    n_dev = len(jax.devices())
    config = RuntimeConfig(
        runtime="dynamic-ps", arch=args.arch, batch=args.batch,
        seq=args.seq, optimizer="adamw", lr=1e-3,
        schedule=ScheduleConfig(
            reschedule_every=args.steps_per_epoch,
            topology=TopologyConfig(
                servers=args.servers, down_gbps=10.0, up_gbps=10.0,
                worker_flops=1e10, up_shift_factor=args.up_factor,
                shift_epoch=args.shift_epoch)))
    print(f"topology: {args.servers} shards x {n_dev} workers; every "
          f"uplink {args.up_factor:g}x slower from epoch "
          f"{args.shift_epoch}")
    rt = build_runtime(config)
    done = 0
    while done < args.steps:
        losses = rt.fit(min(4, args.steps - done))
        done += len(losses)
        print(f"  step {done:4d}  epoch {rt.trainer.epoch}  "
              f"loss {losses[-1]:.4f}")
    for e in rt.events:
        ag, rs = rt.trainer.hlo_counts(e.plan)
        print(f"  epoch {e.epoch}: {len(e.plan.forward)} pull / "
              f"{len(e.plan.backward)} push segments (hlo {ag} ag/{rs} rs) "
              f"{'re-segmented' if e.plan_changed else 'unchanged'}, "
              f"sched {e.scheduling_seconds * 1e3:.2f} ms, "
              f"hidden={e.overhead_hidden}")
    print(f"  traces {rt.trainer.traces} (one per distinct plan), cache "
          f"hits {rt.trainer.cache_hits}\n")

    # --- 2+3. throttles on the smoke CNN (library API: the factory is
    # arch-scoped; the CNN demos drive AsyncPSTrainer directly) ---------
    from repro.core import plan_from_decision
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    cnn_plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)
    topo = PSTopology(
        num_servers=args.servers,
        links=tuple(asymmetric_link(10e9, 1e9) for _ in range(4)),
        worker_flops=(4e10, 4e10, 4e10, 1e10))       # worker 3: 4x slower

    def loss_fn(layers, batch):
        return small_cnn_loss({"layers": layers}, batch["images"],
                              batch["labels"])

    def batch_fn(w, i):
        r = np.random.default_rng(100003 * w + i)
        return {"images": jnp.asarray(r.normal(size=(args.batch, 32, 32, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, 10, size=(args.batch,)),
                                      jnp.int32)}

    print(f"async smoke CNN, 4 workers (worker 3 is 4x slower), "
          f"k={args.staleness}:")
    for throttle in ("reject", "wait"):
        tr = AsyncPSTrainer(init_layers=params["layers"], loss_fn=loss_fn,
                            optimizer=sgd(0.05, 0.9), topology=topo,
                            plan=cnn_plan, staleness=args.staleness,
                            throttle=throttle)
        log = tr.run(args.async_pushes, batch_fn)
        by_worker = {w: log.accepted_by_worker().get(w, 0)
                     for w in range(topo.num_workers)}
        print(f"  {throttle:6s}: accepted per worker {by_worker}, "
              f"{log.num_rejected} rejected, "
              f"{log.total_wait_s:.2f}s waited at the barrier, "
              f"max staleness {log.max_staleness} <= k")
    print("  -> `wait` blocks fast workers at the SSP barrier instead of "
          "evicting the slow worker's pushes: everyone contributes and "
          "the bound still holds")

    print("\nBSP aggregation (wait + aggregate, k=0): same-version pushes "
          "commit as one mean-gradient step")
    tr = AsyncPSTrainer(init_layers=params["layers"], loss_fn=loss_fn,
                        optimizer=sgd(0.05, 0.9), topology=topo,
                        plan=cnn_plan, staleness=0, throttle="wait",
                        aggregate=True)
    log = tr.run(args.async_pushes, batch_fn)
    heads = [e.result.version for e in log.events]
    rounds = len(set(heads))
    by_worker = {w: log.accepted_by_worker().get(w, 0)
                 for w in range(topo.num_workers)}
    print(f"  {len(log.accepted)} pushes in {rounds} BSP rounds "
          f"(one version bump per round of {topo.num_workers}), accepted "
          f"per worker {by_worker}, max staleness {log.max_staleness}")


if __name__ == "__main__":
    main()
