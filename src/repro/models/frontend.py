"""Modality frontend STUBS (the one sanctioned carve-out).

Per the brief, [audio] and [vlm] architectures specify the transformer
backbone only; the ViT/SigLIP tower and the mel/conv feature extractor are
stubs that emit deterministic embeddings of the right shape.  ``input_specs``
in launch/dryrun.py uses the same shapes as ShapeDtypeStructs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def vision_embeddings(cfg: ArchConfig, batch: int, *, seed: int = 0,
                      dtype=jnp.float32) -> jnp.ndarray:
    """Stub anyres patch embeddings: (B, num_vision_tokens, d_model)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(
        key, (batch, cfg.num_vision_tokens, cfg.d_model)).astype(dtype) * 0.02


def audio_frames(cfg: ArchConfig, batch: int, num_frames: int, *,
                 seed: int = 0, dtype=jnp.float32) -> jnp.ndarray:
    """Stub conv-extracted frame embeddings: (B, T, d_model)."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (batch, num_frames, cfg.d_model)
                             ).astype(dtype) * 0.02
