"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_zero_mesh(*, multi_pod: bool = False):
    """All chips on the ZeRO/data axis — the DynaComm bucketed-trainer mesh
    (the PS analogue: pure data parallelism, paper Section III)."""
    if multi_pod:
        return jax.make_mesh((2, 256), ("pod", "data"))
    return jax.make_mesh((256,), ("data",))


def make_host_mesh(num_devices: int | None = None, axes=("data",)):
    """Small CPU mesh for tests/examples (uses whatever devices exist)."""
    import numpy as np
    devs = jax.devices()
    n = num_devices or len(devs)
    shape = (n,) if len(axes) == 1 else None
    if shape is None:
        raise ValueError("provide 1-D axes or build your own mesh")
    from jax.sharding import Mesh
    return Mesh(np.array(devs[:n]).reshape(shape), axes)
