"""Public jit'd wrappers + the shared top-k index selection helper."""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.compress.compress import (TILE, aligned,
                                             densify_pallas,
                                             dequantize_unpack_pallas,
                                             quantize_pack_pallas,
                                             sparsify_pallas)

__all__ = ["TILE", "aligned", "quantize_pack", "dequantize_unpack",
           "topk_indices", "sparsify", "densify"]


@functools.partial(jax.jit, static_argnames=("aligned_lengths", "interpret"))
def quantize_pack(segments: jnp.ndarray, aligned_lengths: tuple, *,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return quantize_pack_pallas(segments, aligned_lengths,
                                interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("aligned_lengths", "lmax", "interpret"))
def dequantize_unpack(payload: jnp.ndarray, scales: jnp.ndarray,
                      aligned_lengths: tuple, lmax: int, *,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    return dequantize_unpack_pallas(payload, scales, aligned_lengths, lmax,
                                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "lengths"))
def topk_indices(segments: jnp.ndarray, lengths: tuple,
                 k: int) -> jnp.ndarray:
    """Per-row magnitude top-k positions, deterministically.

    Ties break toward the lower index (stable sort on descending |v|);
    positions past the row's true ``lengths[i]`` never win; rows with
    fewer than ``k`` valid positions pad with -1.  Returned ascending per
    row with the -1 padding sorted to the front.  Shared by the Pallas
    path and the pure-jnp oracle so both select identical coordinates.
    """
    k_count, lmax = segments.shape
    if len(lengths) != k_count:
        raise ValueError(f"got {len(lengths)} lengths for {k_count} rows")
    if not 1 <= k <= lmax:
        raise ValueError(f"k={k} out of range for row length {lmax}")
    pos = jnp.arange(lmax)[None, :]
    valid = pos < jnp.asarray(lengths, jnp.int32)[:, None]
    mag = jnp.where(valid, jnp.abs(segments), -1.0)
    order = jnp.argsort(-mag, axis=1, stable=True)[:, :k]
    chosen_valid = jnp.take_along_axis(mag, order, axis=1) >= 0
    idx = jnp.where(chosen_valid, order, -1)
    return jnp.sort(idx, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify(segments: jnp.ndarray, indices: jnp.ndarray, *,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    return sparsify_pallas(segments, indices, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("lmax", "interpret"))
def densify(values: jnp.ndarray, indices: jnp.ndarray, lmax: int, *,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    return densify_pallas(values, indices, lmax, interpret=interpret)
