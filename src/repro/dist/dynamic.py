"""Run-time dynamic re-scheduling for the bucketed ZeRO trainer.

This module closes the paper's run-time loop (Section IV): profiling →
DP decision → bucket plan → *live* plan swap, once per epoch.  PR 1 built
the two halves — ``repro.core`` decides, ``repro.dist.zero`` executes — and
``DynamicTrainer`` is the driver that connects them during training:

* per-sched-layer ``fc``/``bc`` come from *measured* wall-clock timings of
  the jitted per-layer applies (``repro.runtime.measure``, the
  mxnet.profiler analogue) or from the analytic profiles (deterministic;
  the default);
* ``pt``/``gt``/``Δt`` come from the *active* network model — a
  ``NetworkSchedule`` makes the network condition time-varying (e.g. the
  uplink dropping 10 Gbps → 1 Gbps at epoch k), which is what makes
  re-scheduling visible;
* on every epoch boundary the ``DynaCommScheduler`` re-plans; when the
  decision changes, the plan is converted with ``plan_from_decision`` and a
  new compiled step is swapped in.  The compiled-step cache, the
  ``RescheduleEvent`` bookkeeping, and the Table I idle-window check live
  in :class:`repro.runtime.replan.ReplanMixin`, shared with the PS-regime
  driver (``repro.ps.dynamic``).

Because the ZeRO state layout (one ``FlatSpec`` flat buffer per sched
layer) is plan-independent, states carry across plan swaps unchanged, and
the loss trajectory of a dynamic run is bit-identical to running the same
plan sequence statically (asserted by ``tests/test_dynamic.py``).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.core.buckets import plan_from_decision
from repro.core.costmodel import LayerCosts
from repro.core.netmodel import NetworkSchedule, as_schedule
from repro.core.planner import AsyncPlanner, Planner
from repro.core.profiler import LayerTimingHook, costs_from_profiles
from repro.core.scheduler import Decision, DynaCommScheduler
from repro.dist.zero import ZeroTrainer
from repro.models import model as model_lib
from repro.models.profiles import layer_profiles
from repro.optim import Optimizer
from repro.runtime.measure import measure_layer_times, measurement_due
from repro.runtime.replan import ReplanMixin
from repro.runtime.replan import sequential_plan as _sequential_plan

__all__ = ["DynamicTrainer"]

_MOVED = ("PlanStepCache", "RescheduleEvent", "hlo_collective_counts",
          "sequential_plan")


def __getattr__(name: str):
    # deprecation shims for the re-planning machinery that moved to
    # repro.runtime.replan (one home instead of a dist copy reused by ps)
    if name in _MOVED:
        warnings.warn(
            f"repro.dist.dynamic.{name} moved to repro.runtime.replan; "
            f"this alias will be removed",
            DeprecationWarning, stacklevel=2)
        from repro.runtime import replan
        return getattr(replan, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class DynamicTrainer(ReplanMixin):
    """Epoch-boundary re-scheduling driver around :class:`ZeroTrainer`.

    ``network`` may be a static model or a :class:`NetworkSchedule`;
    ``cost_source`` picks deterministic analytic profiles (default) or
    measured per-layer wall-clock timings for fc/bc.
    """

    cfg: ArchConfig
    mesh: Any
    optimizer: Optimizer
    network: Any
    steps_per_epoch: int
    strategy: str = "dynacomm"
    cost_source: str = "analytic"          # "analytic" | "measured"
    input_shape: Optional[InputShape] = None
    compute_flops_per_s: Optional[float] = 1e12
    measure_iters: int = 3
    measure_warmup: int = 1
    remeasure_every: int = 1      # epochs between fc/bc re-measurements;
                                  # 0 = measure once (pre-PR-3 behavior)
    drift_detector: Optional[Any] = None   # e.g. core.EwmaDriftDetector
    zero3: bool = False
    axis_name: str = "data"
    aux_weight: float = 0.01
    async_planning: bool = False  # pre-plan epoch e+1 in e's idle window
    plan_cache_size: int = 256    # memoized decisions kept (LRU)

    def __post_init__(self):
        if self.steps_per_epoch < 1:
            raise ValueError(f"steps_per_epoch must be >= 1, got "
                             f"{self.steps_per_epoch}")
        if self.cost_source not in ("analytic", "measured"):
            raise ValueError(f"cost_source must be 'analytic' or 'measured', "
                             f"got {self.cost_source!r}")
        if self.remeasure_every < 0:
            raise ValueError(f"remeasure_every must be >= 0, got "
                             f"{self.remeasure_every}")
        self.network: NetworkSchedule = as_schedule(self.network)
        planner_cls = AsyncPlanner if self.async_planning else Planner
        self.planner = planner_cls(cache_size=self.plan_cache_size)
        self.scheduler = DynaCommScheduler(strategy=self.strategy,
                                           reschedule_every=self.steps_per_epoch,
                                           planner=self.planner)
        self.hook = LayerTimingHook(warmup=self.measure_warmup)
        Ls = model_lib.num_sched_layers(self.cfg)
        self.base = ZeroTrainer(cfg=self.cfg, mesh=self.mesh,
                                plan=_sequential_plan(Ls),
                                optimizer=self.optimizer, zero3=self.zero3,
                                axis_name=self.axis_name,
                                aux_weight=self.aux_weight)
        self._init_replan()
        self._step_idx = 0
        self._decision: Optional[Decision] = None
        self._costs: Optional[LayerCosts] = None
        self._measured_fc_bc: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._measured_epoch = -1
        self._drift_pending = False

    # ------------------------------------------------------------------
    # state / introspection
    # ------------------------------------------------------------------

    def init_state(self, key):
        return self.base.init_state(key)

    @property
    def step_index(self) -> int:
        return self._step_idx

    @property
    def epoch(self) -> int:
        return self._step_idx // self.steps_per_epoch

    @property
    def planner_stats(self) -> Dict[str, float]:
        """Memo-cache / async-planning counters (``PlannerStats``)."""
        return self.planner.stats.as_dict()

    def timeline(self):
        """Per-phase timeline of the active plan against the most recent
        cost vectors (``None`` before the first step)."""
        from repro.core.buckets import decision_from_plan
        from repro.core.simulator import simulate_iteration
        if self._plan is None or self._costs is None:
            return None
        return simulate_iteration(self._costs,
                                  *decision_from_plan(self._plan))

    # ------------------------------------------------------------------
    # cost vectors
    # ------------------------------------------------------------------

    def _input_shape_for(self, batch) -> InputShape:
        if self.input_shape is not None:
            return self.input_shape
        if "tokens" not in batch:
            raise ValueError("cannot derive an InputShape from a batch "
                             "without 'tokens'; pass input_shape= explicitly")
        B, T = batch["tokens"].shape
        return InputShape("dynamic", int(T), int(B), "train")

    def costs_for_epoch(self, epoch: int, state, batch, *,
                        remeasure: bool = False) -> LayerCosts:
        """fc/bc from the configured source; pt/gt/Δt from the epoch's
        network model.

        With ``cost_source="measured"``, fc/bc are re-measured every
        ``remeasure_every`` re-schedule epochs (so *compute* drift — a
        thermally throttled edge device, a contended CPU — is seen, not
        just network drift); ``remeasure=True`` forces a fresh measurement
        (the drift-detector path).
        """
        net = self.network.model_at(epoch)
        if self.cost_source == "analytic":
            return costs_from_profiles(
                layer_profiles(self.cfg, self._input_shape_for(batch)),
                net=net, compute_flops_per_s=self.compute_flops_per_s)
        if measurement_due(self._measured_fc_bc, self._measured_epoch,
                           epoch, self.remeasure_every, force=remeasure):
            measured = self.measure_costs(state, batch, net=net)
            self._measured_fc_bc = (measured.fc, measured.bc)
            self._measured_epoch = epoch
            return measured
        fc, bc = self._measured_fc_bc
        pb = np.asarray(model_lib.sched_layer_bytes(self.cfg), np.float64)
        return LayerCosts(pt=net.transfer_time(pb), fc=fc, bc=bc,
                          gt=net.transfer_time(pb), dt=net.dt)

    def measure_costs(self, state, batch, *, net=None,
                      iters: Optional[int] = None) -> LayerCosts:
        """Measured per-sched-layer fc/bc via
        :func:`repro.runtime.measure.measure_layer_times`; pt/gt/Δt stay
        analytic from ``net``."""
        net = self.network.model_at(self.epoch) if net is None else net
        iters = self.measure_iters if iters is None else iters
        measure_layer_times(self.base, self.hook, state, batch, iters=iters)
        pb = np.asarray(model_lib.sched_layer_bytes(self.cfg), np.float64)
        return self.hook.costs(param_bytes=pb, net=net)

    # ------------------------------------------------------------------
    # the dynamic loop
    # ------------------------------------------------------------------

    def _maybe_reschedule(self, i: int, state, batch) -> None:
        drift = self._drift_pending
        self._drift_pending = False
        boundary = i % self.steps_per_epoch == 0 or drift
        if boundary:
            self._costs = self.costs_for_epoch(i // self.steps_per_epoch,
                                               state, batch, remeasure=drift)
            if drift:
                self.scheduler.invalidate()
        decision = self.scheduler.decision_for_iteration(self._costs)
        changed = decision != self._decision
        # (``_step_fn is None`` off-boundary ⇒ loop state was just restored
        # from a checkpoint: recompile the active plan, no scheduling event)
        if not boundary and not changed and self._step_fn is not None:
            return
        plan = plan_from_decision(*decision, self.base.num_layers)
        prev, retraced = self._activate_plan(
            plan, lambda: self.base.with_plan(plan).build_train_step(),
            state, batch)
        self._decision = decision
        if boundary or changed:
            self._record_reschedule(
                step=i, epoch=i // self.steps_per_epoch, plan=plan,
                prev=prev, retraced=retraced, scheduler=self.scheduler,
                costs=self._costs, trigger="drift" if drift else "epoch")
        if boundary and self.async_planning and \
                self.cost_source == "analytic":
            # Phase one of the async protocol: the analytic cost point of
            # epoch e+1 is a pure function of the epoch, so its DP can run
            # now, in this epoch's Δt + gt¹ idle window (Table I), and be
            # collected at the next boundary.  Measured costs aren't
            # predictable ahead of time — they solve inline (the planner's
            # sync fallback) exactly as before.
            nxt = i // self.steps_per_epoch + 1
            self.planner.submit(self.costs_for_epoch(nxt, state, batch),
                                self.strategy)

    def step(self, state, batch):
        """One training step; re-plans on epoch boundaries — and, when a
        ``drift_detector`` is attached, whenever *observed* step times
        shift persistently (the detector's verdict applies from the next
        step).  Returns ``(new_state, mean_loss)``."""
        self._maybe_reschedule(self._step_idx, state, batch)
        if self.drift_detector is None:
            new_state, loss = self._step_fn(state, batch)
        else:
            t0 = time.perf_counter()
            new_state, loss = self._step_fn(state, batch)
            jax.block_until_ready(loss)
            if self.drift_detector.update(time.perf_counter() - t0):
                self._drift_pending = True
        self._step_idx += 1
        return new_state, loss

    # ------------------------------------------------------------------
    # loop-state checkpointing — the shared body lives in ReplanMixin;
    # this driver adds the drift-detector extras
    # ------------------------------------------------------------------

    def loop_state(self) -> Dict[str, np.ndarray]:
        """The dynamic-loop bookkeeping as a checkpointable pytree."""
        return super().loop_state(extra_meta={
            "drift_pending": self._drift_pending,
            "drift_detector": (self.drift_detector.state_dict()
                               if self.drift_detector is not None and
                               hasattr(self.drift_detector, "state_dict")
                               else None)})

    def restore_loop_state(self, path: str) -> None:
        meta = self._restore_loop_common(path)
        self._decision = self.scheduler._decision
        self._drift_pending = bool(meta.get("drift_pending", False))
        det_state = meta.get("drift_detector")
        if det_state is not None and self.drift_detector is not None and \
                hasattr(self.drift_detector, "load_state_dict"):
            self.drift_detector.load_state_dict(det_state)

    def run(self, state, batch_fn: Callable[[int], Any], num_steps: int, *,
            log_every: int = 0):
        """Drive ``num_steps`` steps with ``batch_fn(i) -> batch``.

        Returns ``(state, losses)`` with one float loss per step."""
        losses: List[float] = []
        for i in range(num_steps):
            state, loss = self.step(state, batch_fn(i))
            losses.append(float(loss))
            if log_every and (i + 1) % log_every == 0:
                f, b = (len(self._plan.forward), len(self._plan.backward))
                print(f"step {i + 1:4d}  epoch {self.epoch}  "
                      f"loss {losses[-1]:.4f}  buckets {f}/{b}")
        return state, losses
