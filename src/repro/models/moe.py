"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

Top-k routing → position-in-expert via cumulative counts → scatter tokens
into an ``(E, C, d)`` dispatch buffer → batched per-expert (gated) FFN →
gather + weighted combine.  FLOPs are proportional to *active* parameters
(E·C ≈ tokens·top_k·capacity_factor), not total experts, so the roofline's
MODEL_FLOPS = 6·N_active·D comparison is honest.

Tokens beyond an expert's capacity are dropped (their combine weight is
zero) — standard capacity-factor semantics.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import activation_fn, dense, init_dense


def expert_capacity(num_tokens: int, cfg: ArchConfig) -> int:
    cap = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(cap, cfg.top_k)


def init_moe_params(key, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    def expert_stack(k, din, dout):
        keys = jax.random.split(k, e)
        return jnp.stack([init_dense(keys[i], din, dout, dtype) for i in range(e)])
    p = {
        "router": init_dense(ks[0], d, e, dtype),
        "up": expert_stack(ks[1], d, f),
        "down": expert_stack(ks[2], f, d),
    }
    if cfg.gated_mlp:
        p["gate"] = expert_stack(ks[3], d, f)
    return p


def router_load_balance_loss(probs: jnp.ndarray, expert_idx: jnp.ndarray,
                             num_experts: int) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * Σ_e fraction_e · mean_prob_e."""
    counts = jnp.sum(jax.nn.one_hot(expert_idx, num_experts), axis=(0, 1))
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    mean_prob = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(frac * mean_prob)


def apply_moe(params, x: jnp.ndarray, cfg: ArchConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) → (output, aux_loss)."""
    b, t, d = x.shape
    n = b * t
    e, k = cfg.num_experts, cfg.top_k
    cap = expert_capacity(n, cfg)
    xf = x.reshape(n, d)

    logits = dense(xf, params["router"]).astype(jnp.float32)       # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                          # (N, k)
    top_p = (top_p / jnp.sum(top_p, axis=-1, keepdims=True)).astype(x.dtype)

    # position within each expert, assignment-major order
    flat_e = top_e.reshape(-1)                                      # (N*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)             # (N*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot                  # exclusive
    pos = jnp.sum(pos_in_e * onehot, axis=1)                        # (N*k,)
    keep = pos < cap
    slot = flat_e * cap + jnp.where(keep, pos, 0)                   # (N*k,)

    # scatter tokens into the dispatch buffer (dropped tokens write nowhere)
    buf = jnp.zeros((e * cap, d), x.dtype)
    src = jnp.repeat(xf, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], src, 0.0))
    buf = buf.reshape(e, cap, d)

    # batched per-expert gated FFN
    act = activation_fn(cfg.activation)
    up = jnp.einsum("ecd,edf->ecf", buf, params["up"].astype(x.dtype))
    if "gate" in params:
        up = act(jnp.einsum("ecd,edf->ecf", buf,
                            params["gate"].astype(x.dtype))) * up
    else:
        up = act(up)
    out_buf = jnp.einsum("ecf,efd->ecd", up, params["down"].astype(x.dtype))
    out_buf = out_buf.reshape(e * cap, d)

    # gather back and combine with routing weights (dropped → weight 0)
    gathered = out_buf[slot]                                        # (N*k, d)
    w = top_p.reshape(-1) * keep.astype(x.dtype)
    combined = jnp.sum((gathered * w[:, None]).reshape(n, k, d), axis=1)

    aux = router_load_balance_loss(probs, top_e, e)
    return combined.reshape(b, t, d), aux
