"""Training launcher: ``--arch <id>`` + input shape + strategy.

Three runtimes:

* ``--runtime local`` (default) — single-process jit training on whatever
  devices exist; reduced configs runnable on CPU.
* ``--runtime zero`` — the DynaComm-bucketed ZeRO trainer over a 1-D data
  mesh (all local devices), schedule chosen by ``--strategy``; the plan is
  decided once at startup.
* ``--runtime dynamic`` — the run-time loop (paper Section IV-C): the
  scheduler re-plans every ``--steps-per-epoch`` steps against the active
  network model and swaps compiled steps when the decision changes.  Pair
  with ``--bw-shift-gbps`` to script a bandwidth drift and watch the
  schedule re-segment mid-training.

Examples::

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --reduced --runtime zero --strategy dynacomm --steps 50
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --runtime dynamic --steps 60 --steps-per-epoch 20 \
        --bw-gbps 10 --bw-shift-gbps 1 --shift-epoch 1
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import ARCHITECTURES, get_config
from repro.configs.base import InputShape
from repro.core import (EdgeNetworkModel, costs_from_profiles,
                        DynaCommScheduler, plan_from_decision)
from repro.data.pipeline import SyntheticText
from repro.models import num_sched_layers
from repro.models.profiles import layer_profiles
from repro.optim import adamw, sgd
from repro.train.loop import TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHITECTURES), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--runtime", choices=("local", "zero", "dynamic"),
                    default="local")
    ap.add_argument("--strategy", default="dynacomm",
                    choices=("sequential", "lbl", "ibatch", "dynacomm"))
    # scheduling knobs (zero + dynamic runtimes)
    ap.add_argument("--steps-per-epoch", type=int, default=20,
                    help="re-scheduling interval of the dynamic runtime")
    ap.add_argument("--bw-gbps", type=float, default=10.0,
                    help="edge uplink bandwidth (Gbit/s)")
    ap.add_argument("--bw-shift-gbps", type=float, default=None,
                    help="drift the uplink to this bandwidth at --shift-epoch")
    ap.add_argument("--shift-epoch", type=int, default=1)
    ap.add_argument("--cost-source", choices=("analytic", "measured"),
                    default="analytic")
    ap.add_argument("--worker-flops", type=float, default=1e10,
                    help="edge-worker compute rate fed to the profiler")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=("adamw", "sgd"), default="adamw")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend != "none":
        raise SystemExit("train.py drives text archs; stubbed-modality "
                         "archs are exercised via the dry-run and tests")

    opt = adamw(args.lr) if args.optimizer == "adamw" else sgd(args.lr, 0.9)
    pipe = SyntheticText(cfg.vocab_size, args.seq, args.batch, seed=0)

    if args.runtime == "local":
        loop = TrainLoop(cfg=cfg, optimizer=opt, log_every=10,
                         checkpoint_path=args.checkpoint,
                         checkpoint_every=50 if args.checkpoint else 0)
        loop.run(jax.random.PRNGKey(0), iter(pipe), num_steps=args.steps)
        return

    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(len(devs),), ("data",))
    shape = InputShape("cli", args.seq, args.batch, "train")

    if args.runtime == "dynamic":
        # run-time loop: re-profile + re-plan every epoch, swap compiled
        # steps when the decision changes
        from repro.core import bandwidth_shift
        from repro.dist.dynamic import DynamicTrainer
        if args.bw_shift_gbps is not None:
            net = bandwidth_shift(args.bw_gbps * 1e9,
                                  args.bw_shift_gbps * 1e9,
                                  at_epoch=args.shift_epoch)
        else:
            net = EdgeNetworkModel(bandwidth_bps=args.bw_gbps * 1e9)
        dyn = DynamicTrainer(cfg=cfg, mesh=mesh, optimizer=opt, network=net,
                             steps_per_epoch=args.steps_per_epoch,
                             strategy=args.strategy, input_shape=shape,
                             cost_source=args.cost_source,
                             compute_flops_per_s=args.worker_flops)
        print(f"[dynamic] {len(devs)} devices; strategy {args.strategy}, "
              f"re-plan every {args.steps_per_epoch} steps")
        state = dyn.init_state(jax.random.PRNGKey(0))
        dyn.run(state, pipe.batch, args.steps, log_every=10)
        for e in dyn.events:
            ag, rs = dyn.hlo_counts(e.plan)
            print(f"epoch {e.epoch:3d} step {e.step:4d}: "
                  f"{len(e.plan.forward)} pull / {len(e.plan.backward)} push "
                  f"buckets (hlo {ag} ag / {rs} rs)  "
                  f"{'re-segmented' if e.plan_changed else 'unchanged'}"
                  f"{' [cache hit]' if e.plan_changed and not e.retraced else ''}"
                  f"  sched {e.scheduling_seconds * 1e3:.2f} ms "
                  f"hidden={e.overhead_hidden}")
        print(f"[dynamic] traces {dyn.traces}, cache hits {dyn.cache_hits}")
        return

    # zero runtime: profile → schedule → bucketed trainer
    from repro.dist.zero import ZeroTrainer
    costs = costs_from_profiles(
        layer_profiles(cfg, shape),
        net=EdgeNetworkModel(bandwidth_bps=args.bw_gbps * 1e9),
        compute_flops_per_s=args.worker_flops)
    sched = DynaCommScheduler(strategy=args.strategy)
    decision = sched.decision_for_iteration(costs)
    plan = plan_from_decision(*decision, num_sched_layers(cfg))
    print(f"[zero] {len(devs)} devices; {args.strategy}: "
          f"{len(plan.forward)} pull / {len(plan.backward)} push buckets")
    trainer = ZeroTrainer(cfg=cfg, mesh=mesh, plan=plan, optimizer=opt)
    state = trainer.init_state(jax.random.PRNGKey(0))
    step = jax.jit(trainer.build_train_step())
    t0 = time.perf_counter()
    for i in range(args.steps):
        state, loss = step(state, pipe.batch(i))
        if (i + 1) % 10 == 0:
            print(f"step {i + 1:4d}  loss {float(loss):.4f}  "
                  f"{(time.perf_counter() - t0) / (i + 1):.3f}s/step")


if __name__ == "__main__":
    main()
