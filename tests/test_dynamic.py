"""Dynamic re-scheduling: time-varying networks, the per-layer timing hook,
and the DynamicTrainer loop.

Quick tests run single-device at the cost-model level; the multi-device
trainer claims (plan swap, step-cache hit counts, bit-identical losses,
HLO collective counts) run in a 4-forged-device subprocess.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import (EdgeNetworkModel, LayerTimingHook, NetworkSchedule,
                        TPUSystemModel, as_schedule, bandwidth_shift,
                        costs_from_profiles, schedule)
from repro.models.profiles import layer_profiles

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestNetworkSchedule:
    def test_piecewise_selection(self):
        hi, lo = EdgeNetworkModel(bandwidth_bps=10e9), \
            EdgeNetworkModel(bandwidth_bps=1e9)
        sched = NetworkSchedule(knots=((0, hi), (3, lo)))
        assert sched.model_at(0) is hi
        assert sched.model_at(2) is hi
        assert sched.model_at(3) is lo
        assert sched.model_at(100) is lo

    def test_validation(self):
        m = EdgeNetworkModel()
        with pytest.raises(ValueError):
            NetworkSchedule(knots=())
        with pytest.raises(ValueError):
            NetworkSchedule(knots=((1, m),))          # must start at 0
        with pytest.raises(ValueError):
            NetworkSchedule(knots=((0, m), (0, m)))   # strictly increasing
        with pytest.raises(ValueError):
            NetworkSchedule(knots=((0, m),)).model_at(-1)

    def test_as_schedule_idempotent(self):
        m = TPUSystemModel()
        s = as_schedule(m)
        assert s.model_at(7) is m
        assert as_schedule(s) is s

    def test_bandwidth_shift(self):
        s = bandwidth_shift(10e9, 1e9, at_epoch=2)
        assert s.model_at(1).bandwidth_bps == 10e9
        assert s.model_at(2).bandwidth_bps == 1e9
        # RTT (and hence Δt) unchanged across the shift
        assert s.model_at(0).dt == s.model_at(2).dt
        with pytest.raises(ValueError):
            bandwidth_shift(10e9, 1e9, at_epoch=0)


class TestNetworkScheduleEdgeCases:
    """Epochs exactly on shift boundaries, degenerate knot lists,
    non-monotone epochs (ISSUE 3 satellite)."""

    def _models(self, n):
        return [EdgeNetworkModel(bandwidth_bps=(i + 1) * 1e9)
                for i in range(n)]

    def test_epoch_exactly_on_every_boundary(self):
        """model_at at a knot's start epoch returns the *new* model — the
        shift applies to the boundary epoch itself, for every knot."""
        m = self._models(3)
        sched = NetworkSchedule(knots=((0, m[0]), (2, m[1]), (5, m[2])))
        assert sched.model_at(0) is m[0]
        assert sched.model_at(1) is m[0]
        assert sched.model_at(2) is m[1]          # exactly on the boundary
        assert sched.model_at(4) is m[1]
        assert sched.model_at(5) is m[2]          # exactly on the boundary
        assert sched.model_at(10 ** 9) is m[2]    # far past the last knot

    def test_empty_schedule_rejected(self):
        with pytest.raises(ValueError, match="at least one knot"):
            NetworkSchedule(knots=())

    def test_non_monotone_epochs_rejected(self):
        m = self._models(3)
        with pytest.raises(ValueError, match="strictly increasing"):
            NetworkSchedule(knots=((0, m[0]), (3, m[1]), (2, m[2])))
        with pytest.raises(ValueError, match="strictly increasing"):
            NetworkSchedule(knots=((0, m[0]), (2, m[1]), (2, m[2])))

    def test_first_knot_must_anchor_epoch_zero(self):
        (m,) = self._models(1)
        with pytest.raises(ValueError, match="epoch 0"):
            NetworkSchedule(knots=((3, m),))

    def test_single_knot_covers_all_epochs(self):
        (m,) = self._models(1)
        sched = NetworkSchedule(knots=((0, m),))
        assert sched.num_knots == 1
        for e in (0, 1, 7, 12345):
            assert sched.model_at(e) is m

    def test_negative_epoch_rejected(self):
        (m,) = self._models(1)
        with pytest.raises(ValueError, match=">= 0"):
            NetworkSchedule(knots=((0, m),)).model_at(-3)

    def test_float_like_epochs_coerced(self):
        """Knot epochs are coerced to int on construction."""
        m = self._models(2)
        sched = NetworkSchedule(knots=((0.0, m[0]), (2.0, m[1])))
        assert sched.knots[1][0] == 2
        assert sched.model_at(2) is m[1]


class TestTopologySchedule:
    """Time-varying PS topologies: the NetworkSchedule edge-case contract
    applied to whole fabrics (ISSUE 4 satellite)."""

    def _topos(self, n, workers=2):
        from repro.ps import PSTopology
        return [PSTopology.uniform(1, workers, up_bps=(i + 1) * 1e9)
                for i in range(n)]

    def test_epoch_exactly_on_every_boundary(self):
        """topology_at at a knot's start epoch returns the *new* topology
        — the shift applies to the boundary epoch itself, for every
        knot."""
        from repro.ps import TopologySchedule
        t = self._topos(3)
        sched = TopologySchedule(knots=((0, t[0]), (2, t[1]), (5, t[2])))
        assert sched.topology_at(0) is t[0]
        assert sched.topology_at(1) is t[0]
        assert sched.topology_at(2) is t[1]       # exactly on the boundary
        assert sched.topology_at(4) is t[1]
        assert sched.topology_at(5) is t[2]       # exactly on the boundary
        assert sched.topology_at(10 ** 9) is t[2]
        assert sched.shift_epochs() == (2, 5)

    def test_zero_length_epochs_rejected(self):
        """Two knots at the same epoch would make a zero-length epoch."""
        from repro.ps import TopologySchedule
        t = self._topos(3)
        with pytest.raises(ValueError, match="strictly increasing"):
            TopologySchedule(knots=((0, t[0]), (2, t[1]), (2, t[2])))
        with pytest.raises(ValueError, match="strictly increasing"):
            TopologySchedule(knots=((0, t[0]), (3, t[1]), (2, t[2])))

    def test_empty_and_unanchored_rejected(self):
        from repro.ps import TopologySchedule
        (t,) = self._topos(1)
        with pytest.raises(ValueError, match="at least one knot"):
            TopologySchedule(knots=())
        with pytest.raises(ValueError, match="epoch 0"):
            TopologySchedule(knots=((3, t),))

    def test_negative_epoch_rejected(self):
        from repro.ps import TopologySchedule
        (t,) = self._topos(1)
        with pytest.raises(ValueError, match=">= 0"):
            TopologySchedule(knots=((0, t),)).topology_at(-1)

    def test_worker_count_must_stay_fixed(self):
        """Workers map onto devices/actors and cannot join or leave."""
        from repro.ps import TopologySchedule, PSTopology
        a = PSTopology.uniform(1, 2)
        b = PSTopology.uniform(1, 3)
        with pytest.raises(ValueError, match="num_workers"):
            TopologySchedule(knots=((0, a), (2, b)))

    def test_non_topology_knot_rejected(self):
        from repro.ps import TopologySchedule
        with pytest.raises(TypeError, match="not PSTopology"):
            TopologySchedule(knots=((0, EdgeNetworkModel()),))

    def test_as_topology_schedule_idempotent(self):
        from repro.ps import PSTopology, as_topology_schedule
        topo = PSTopology.uniform(2, 2)
        s = as_topology_schedule(topo)
        assert s.topology_at(7) is topo
        assert as_topology_schedule(s) is s

    def test_float_like_epochs_coerced(self):
        from repro.ps import TopologySchedule
        t = self._topos(2)
        sched = TopologySchedule(knots=((0.0, t[0]), (2.0, t[1])))
        assert sched.knots[1][0] == 2
        assert sched.topology_at(2) is t[1]

    def test_uplink_degradation_helper(self):
        from repro.ps import PSTopology, uplink_degradation
        base = PSTopology.uniform(2, 3, down_bps=10e9, up_bps=4e9)
        sched = uplink_degradation(base, factor=4, at_epoch=2)
        assert sched.topology_at(1) is base
        after = sched.topology_at(2)
        for before_l, after_l in zip(base.links, after.links):
            assert after_l.up.bandwidth_bps == \
                pytest.approx(before_l.up.bandwidth_bps / 4)
            assert after_l.down is before_l.down       # downlinks untouched
        assert after.worker_flops == base.worker_flops
        with pytest.raises(ValueError, match="at_epoch"):
            uplink_degradation(base, factor=4, at_epoch=0)
        with pytest.raises(ValueError, match="factor"):
            uplink_degradation(base, factor=0.0, at_epoch=1)


class TestTopologyScheduler:
    """Epoch-cached consensus / per-worker planning (core plumbing)."""

    def _costs(self):
        from repro.core import random_costs
        from repro.core.costmodel import TopologyCosts
        return TopologyCosts(workers=(
            random_costs(6, seed=0),
            random_costs(6, seed=0, comp_scale=5.0, comm_scale=2.0)))

    def test_consensus_mode_caches_until_boundary(self):
        from repro.core import TopologyScheduler, consensus_decision
        topo = self._costs()
        sched = TopologyScheduler(reschedule_every=3)
        d0 = sched.decision_for_iteration(topo)
        assert d0 == consensus_decision(topo, "dynacomm")[0]
        assert sched.last_makespan == pytest.approx(topo.makespan(*d0))
        t0 = sched.last_scheduling_seconds
        assert sched.decision_for_iteration(topo) == d0    # cached
        assert sched.last_scheduling_seconds == t0         # no re-plan
        sched.decision_for_iteration(topo)                 # iter 3
        sched.decision_for_iteration(topo)                 # boundary: re-plan
        assert sched._iter_seen == 4

    def test_per_worker_mode(self):
        from repro.core import TopologyScheduler, schedule_topology
        topo = self._costs()
        sched = TopologyScheduler(mode="per-worker")
        decisions = sched.decision_for_iteration(topo)
        assert decisions == schedule_topology(topo, "dynacomm")
        assert len(decisions) == topo.num_workers

    def test_overhead_hidden_uses_min_idle_window(self):
        from repro.core import TopologyScheduler
        topo = self._costs()
        sched = TopologyScheduler()
        sched.decision_for_iteration(topo)
        assert topo.idle_window == \
            min(c.dt_push + float(c.gt[0]) for c in topo.workers)
        sched.last_scheduling_seconds = topo.idle_window * 0.5
        assert sched.scheduling_overhead_hidden(topo)
        sched.last_scheduling_seconds = topo.idle_window * 2.0
        assert not sched.scheduling_overhead_hidden(topo)

    def test_validation(self):
        from repro.core import TopologyScheduler
        with pytest.raises(ValueError, match="strategy"):
            TopologyScheduler(strategy="psychic")
        with pytest.raises(ValueError, match="reschedule_every"):
            TopologyScheduler(reschedule_every=0)
        with pytest.raises(ValueError, match="mode"):
            TopologyScheduler(mode="vote")


class TestPSReplanTimeline:
    def test_stale_plan_penalty(self):
        """Freezing the epoch-0 plan across a drift can only lose to
        re-planning (per epoch, the re-plan minimizes over a candidate
        set containing the frozen plan's per-worker optima)."""
        from repro.core import (TopologyScheduler, simulate_ps_replan)
        from repro.core.costmodel import TopologyCosts
        from repro.core import random_costs
        base = TopologyCosts(workers=(
            random_costs(6, seed=1), random_costs(6, seed=2)))
        epoch_costs = [base, base.scaled(comm=4.0), base.scaled(comm=16.0)]
        sched = TopologyScheduler(reschedule_every=1)
        decisions = []
        for c in epoch_costs:
            sched.invalidate()
            decisions.append(sched.decision_for_iteration(c))
        tl = simulate_ps_replan(epoch_costs, decisions)
        assert tl.num_epochs == 3
        assert tl.stale_plan_penalty(0) == pytest.approx(0.0)
        for e in range(3):
            # consensus evaluates the frozen decision among its candidates
            # only at epoch 0; later epochs may not, so only assert the
            # simulated numbers are consistent, not a universal sign
            assert tl.makespans[e] == \
                pytest.approx(tl.replanned[e].makespan)
            assert tl.frozen_makespans[e] == \
                pytest.approx(tl.frozen[e].makespan)

    def test_validation(self):
        from repro.core import simulate_ps_replan, PSReplanTimeline
        from repro.core.costmodel import TopologyCosts
        from repro.core import random_costs
        topo = TopologyCosts(workers=(random_costs(4, seed=0),))
        d = (((1, 4),), ((4, 1),))
        with pytest.raises(ValueError, match="epoch costs"):
            simulate_ps_replan([topo, topo], [d])
        with pytest.raises(ValueError, match="at least one epoch"):
            PSReplanTimeline(replanned=(), frozen=())


class TestLayerTimingHook:
    def test_medians_drop_warmup(self):
        hook = LayerTimingHook(warmup=1)
        for l, (first, rest) in enumerate([(9.0, 1.0), (9.0, 2.0)]):
            hook.record("fc", l, first)      # compile-tainted sample
            hook.record("fc", l, rest)
            hook.record("fc", l, rest)
        np.testing.assert_allclose(hook.median("fc", 2), [1.0, 2.0])

    def test_missing_layer_raises(self):
        hook = LayerTimingHook(warmup=0)
        hook.record("fc", 0, 1.0)
        with pytest.raises(ValueError, match="layer 1"):
            hook.median("fc", 2)

    def test_timed_wrapper_records(self):
        hook = LayerTimingHook(warmup=0)
        fn = hook.timed("bc", 3, lambda x: x + 1)
        assert fn(41) == 42
        assert hook.num_samples("bc", 3) == 1

    def test_costs_assembly(self):
        hook = LayerTimingHook(warmup=0)
        for l in range(3):
            hook.record("fc", l, 1e-3 * (l + 1))
            hook.record("bc", l, 2e-3 * (l + 1))
        net = EdgeNetworkModel(bandwidth_bps=1e9)
        costs = hook.costs(param_bytes=[1e6, 2e6, 3e6], net=net)
        assert costs.num_layers == 3
        np.testing.assert_allclose(costs.fc, [1e-3, 2e-3, 3e-3])
        np.testing.assert_allclose(costs.bc, [2e-3, 4e-3, 6e-3])
        np.testing.assert_allclose(costs.pt, costs.gt)
        assert costs.dt == net.dt
        hook.reset()
        with pytest.raises(ValueError):
            hook.median("fc", 1)


class TestDriftChangesDecision:
    def test_dp_resegment_across_bandwidth_drop(self):
        """The scenario the trainer test exercises, at the cost-model level:
        dynacomm's decision differs between 10 Gbps and 1 Gbps."""
        cfg = get_config("granite-3-2b").reduced()
        profs = layer_profiles(cfg, InputShape("dyn", 32, 8, "train"))
        decisions = []
        for bw in (10e9, 1e9):
            costs = costs_from_profiles(
                profs, net=EdgeNetworkModel(bandwidth_bps=bw),
                compute_flops_per_s=1e10)
            decisions.append(schedule(costs, "dynacomm"))
        assert decisions[0] != decisions[1]


class TestDynamicTrainerSingleDevice:
    def test_constructor_validation(self):
        import jax
        from jax.sharding import Mesh
        from repro.dist.dynamic import DynamicTrainer
        from repro.optim import sgd

        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        kw = dict(cfg=cfg, mesh=mesh, optimizer=sgd(1e-2, 0.9),
                  network=EdgeNetworkModel())
        with pytest.raises(ValueError, match="steps_per_epoch"):
            DynamicTrainer(steps_per_epoch=0, **kw)
        with pytest.raises(ValueError, match="cost_source"):
            DynamicTrainer(steps_per_epoch=5, cost_source="psychic", **kw)

    def test_sequential_plan_shape(self):
        from repro.runtime.replan import sequential_plan
        p = sequential_plan(4)
        assert p.forward == ((0, 1, 2, 3),)
        assert p.backward == ((3, 2, 1, 0),)

    def test_hlo_collective_counts(self):
        from repro.runtime.replan import hlo_collective_counts
        hlo = (
            "  %a = f32[4,16]{1,0} all-gather(f32[1,16]{1,0} %x), "
            "dimensions={0}\n"
            "  %b = f32[1,4]{1,0} reduce-scatter(f32[4,4]{1,0} %y), "
            "dimensions={0}\n"
            "  %c = (f32[8]{0}, f32[32]{0}) all-gather-start(f32[8]{0} %z), "
            "dimensions={0}\n")
        assert hlo_collective_counts(hlo) == (2, 1)


class TestEwmaDriftDetector:
    def test_validation(self):
        from repro.core import EwmaDriftDetector
        for kw in ({"alpha": 0.0}, {"alpha": 1.5}, {"threshold": 0.0},
                   {"patience": 0}, {"warmup": -1}):
            with pytest.raises(ValueError):
                EwmaDriftDetector(**kw)
        with pytest.raises(ValueError):
            EwmaDriftDetector().update(-1.0)

    def test_persistent_shift_triggers_once(self):
        from repro.core import EwmaDriftDetector
        det = EwmaDriftDetector(warmup=2, patience=2, threshold=0.3)
        out = [det.update(t) for t in [1.0] * 5 + [2.0] * 6]
        assert sum(out) == 1                      # one trigger per shift
        assert out[6]                             # fires on the 2nd drifted
        assert det.num_triggers == 1
        # after re-seeding at 2.0, a shift back down re-triggers
        out2 = [det.update(t) for t in [1.0] * 3]
        assert sum(out2) == 1

    def test_blip_absorbed_by_patience(self):
        from repro.core import EwmaDriftDetector
        det = EwmaDriftDetector(warmup=1, patience=3, threshold=0.3)
        out = [det.update(t) for t in [1.0, 1.0, 1.0, 5.0, 1.0, 1.0, 5.0,
                                       5.0, 1.0, 1.0]]
        assert not any(out)                       # isolated spikes never fire
        assert det.baseline == pytest.approx(1.0, rel=0.05)

    def test_warmup_never_triggers(self):
        from repro.core import EwmaDriftDetector
        det = EwmaDriftDetector(warmup=5, patience=1, threshold=0.1)
        assert not any(det.update(t) for t in [1.0, 9.0, 1.0, 9.0, 1.0])

    def test_reset(self):
        from repro.core import EwmaDriftDetector
        det = EwmaDriftDetector(warmup=0, patience=1, threshold=0.1)
        det.update(1.0)
        det.reset()
        assert det.baseline is None and det.num_triggers == 0

    def test_state_dict_roundtrip(self):
        """A restored detector continues from the saved baseline instead of
        re-entering warmup (the dynamic loop checkpoints this)."""
        from repro.core import EwmaDriftDetector
        a = EwmaDriftDetector(warmup=2, patience=2, threshold=0.3)
        for t in [1.0, 1.0, 1.0, 2.0]:       # mid-streak: one drifted sample
            a.update(t)
        b = EwmaDriftDetector(warmup=2, patience=2, threshold=0.3)
        b.load_state_dict(a.state_dict())
        assert b.baseline == a.baseline
        assert b.update(2.0)                 # 2nd drifted sample: fires now
        assert not a.state_dict() == b.state_dict()  # b re-seeded at 2.0


class TestCheckpointTextLeaves:
    def test_string_leaf_roundtrip(self, tmp_path):
        """repro.checkpoint carries variable-width text leaves (the
        dynamic loop stores JSON metadata this way)."""
        from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
        tree = {"meta": np.asarray('{"plan": [1, 2, 3]}'),
                "x": np.arange(4, dtype=np.float32)}
        path = str(tmp_path / "ck.npz")
        save_checkpoint(path, tree, step=7)
        template = {"meta": np.asarray(""), "x": np.zeros(4, np.float32)}
        restored, step = load_checkpoint(path, template)
        assert step == 7
        assert str(restored["meta"]) == '{"plan": [1, 2, 3]}'
        np.testing.assert_array_equal(restored["x"], tree["x"])

    def test_numeric_shape_still_checked(self, tmp_path):
        from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
        path = str(tmp_path / "ck.npz")
        save_checkpoint(path, {"x": np.zeros(4)})
        with pytest.raises(ValueError, match="shape mismatch"):
            load_checkpoint(path, {"x": np.zeros(5)})


class TestDynamicLoopStateSingleDevice:
    """Checkpoint/restore of the dynamic loop + drift-detector wiring,
    on a 1-device mesh (collectives over a size-1 axis are valid)."""

    @pytest.fixture(scope="class")
    def setup(self):
        import jax
        from jax.sharding import Mesh
        from repro.data.pipeline import SyntheticText
        from repro.optim import adamw

        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        pipe = SyntheticText(cfg.vocab_size, 32, 4, seed=0)
        kw = dict(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                  network=bandwidth_shift(10e9, 1e9, at_epoch=2),
                  steps_per_epoch=2, compute_flops_per_s=1e10)
        return kw, pipe

    def test_resume_is_bit_identical(self, setup, tmp_path):
        import jax
        from repro.dist.dynamic import DynamicTrainer
        kw, pipe = setup

        ref = DynamicTrainer(**kw)
        state = ref.init_state(jax.random.PRNGKey(0))
        state, ref_losses = ref.run(state, pipe.batch, 6)

        a = DynamicTrainer(**kw)
        sa = a.init_state(jax.random.PRNGKey(0))
        losses = []
        for i in range(3):                        # stop mid-epoch
            sa, l = a.step(sa, pipe.batch(i))
            losses.append(float(l))
        path = str(tmp_path / "loop.npz")
        a.save_loop_state(path)

        b = DynamicTrainer(**kw)                  # fresh trainer, no memory
        b.restore_loop_state(path)
        assert b.step_index == 3
        assert b.plan == a.plan
        assert [e.step for e in b.events] == [e.step for e in a.events]
        for i in range(3, 6):
            sa, l = b.step(sa, pipe.batch(i))
            losses.append(float(l))
        assert losses == ref_losses
        # resume replays the same re-schedule history as the straight run
        assert [(e.step, e.epoch, e.plan) for e in b.events] == \
            [(e.step, e.epoch, e.plan) for e in ref.events]
        # the mid-epoch recompile is not recorded as a scheduling event
        assert len(b.events) == len(ref.events)

    def test_drift_detector_forces_reschedule(self, setup):
        import jax
        from repro.dist.dynamic import DynamicTrainer
        kw, pipe = setup

        class FireOnce:
            calls = 0

            def update(self, seconds):
                self.calls += 1
                return self.calls == 2            # fires after step 2

        dyn = DynamicTrainer(drift_detector=FireOnce(),
                             **{**kw, "steps_per_epoch": 100})
        state = dyn.init_state(jax.random.PRNGKey(0))
        for i in range(4):
            state, _ = dyn.step(state, pipe.batch(i))
        triggers = [(e.step, e.trigger) for e in dyn.events]
        assert triggers[0] == (0, "epoch")
        assert (2, "drift") in triggers           # detector-forced re-plan
        assert dyn.scheduler._iter_seen == 4      # epoch alignment intact


class TestDynamicPSTrainerSingleDevice:
    """The dynamic-PS loop on a 1-device mesh: plan swap exactly on the
    topology-epoch boundary, compiled-step cache, and sync losses
    bit-identical to statically running each epoch's plan (the ISSUE 4
    acceptance criterion; the 4-forged-device version runs in the slow
    subprocess check)."""

    STEPS_PER_EPOCH = 2

    @pytest.fixture(scope="class")
    def run(self):
        import jax
        from jax.sharding import Mesh
        from repro.data.pipeline import SyntheticText
        from repro.optim import adamw
        from repro.ps import (DynamicPSTrainer, PSTopology,
                              uplink_degradation)

        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        base = PSTopology.uniform(2, 1, down_bps=10e9, up_bps=10e9,
                                  flops=1e10)
        sched = uplink_degradation(base, factor=10, at_epoch=1)
        shape = InputShape("dyn-ps", 32, 4, "train")
        pipe = SyntheticText(cfg.vocab_size, 32, 4, seed=0)
        dyn = DynamicPSTrainer(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                               topology=sched,
                               steps_per_epoch=self.STEPS_PER_EPOCH,
                               input_shape=shape)
        state = dyn.init_state(jax.random.PRNGKey(0))
        state, losses = dyn.run(state, pipe.batch, 3 * self.STEPS_PER_EPOCH)
        return dyn, sched, pipe, losses

    def test_plan_swaps_exactly_on_boundary_steps(self, run):
        dyn, _, _, _ = run
        assert [e.step for e in dyn.events] == \
            [i * self.STEPS_PER_EPOCH for i in range(3)]
        assert not dyn.events[0].plan_changed
        assert dyn.events[1].plan_changed, \
            "the 10x uplink degradation must re-segment the push plan"
        assert dyn.events[1].step == self.STEPS_PER_EPOCH
        # the degraded uplink wants fewer, larger pushes... or at least a
        # different decomposition; sanity: backward segmentation moved
        assert dyn.events[1].plan.backward != dyn.events[0].plan.backward

    def test_one_trace_per_distinct_plan(self, run):
        dyn, _, _, _ = run
        assert dyn.traces == len(dyn.plans_seen) == 2
        assert not dyn.events[2].retraced          # epoch 2 keeps the plan
        for plan in dyn.plans_seen:
            ag, rs = dyn.hlo_counts(plan)
            assert (ag, rs) == (len(plan.forward), len(plan.backward))

    def test_losses_bit_identical_to_static_plan_sequence(self, run):
        import jax
        from repro.core import consensus_decision
        from repro.models.profiles import layer_profiles
        from repro.models import num_sched_layers
        from repro.core import plan_from_decision
        from repro.optim import adamw
        from repro.ps import PSTrainer

        dyn, sched, pipe, losses = run
        cfg = get_config("granite-3-2b").reduced()
        shape = InputShape("dyn-ps", 32, 4, "train")
        profs = layer_profiles(cfg, shape)
        base = PSTrainer(cfg=cfg, mesh=dyn.mesh, plan=dyn.plans_seen[0],
                         optimizer=adamw(1e-3),
                         topology=sched.topology_at(0))
        state = base.init_state(jax.random.PRNGKey(0))
        ref, fns = [], {}
        for epoch in range(3):
            costs = sched.topology_at(epoch).topology_costs(profs)
            d, _ = consensus_decision(costs, "dynacomm")
            plan = plan_from_decision(*d, num_sched_layers(cfg))
            if plan not in fns:
                fns[plan] = jax.jit(base.with_plan(plan).build_train_step())
            for i in range(epoch * self.STEPS_PER_EPOCH,
                           (epoch + 1) * self.STEPS_PER_EPOCH):
                state, loss = fns[plan](state, pipe.batch(i))
                ref.append(float(loss))
        assert losses == ref

    def test_overhead_hidden_against_topology_window(self, run):
        """`overhead_hidden` must be exactly the Table I predicate
        against the topology's min Δt + gt¹ window.  (Asserting the flag
        is *True* would be a wall-clock assertion — flaky under CPU
        contention — so the quick suite pins the relationship; the slow
        subprocess check asserts truth on an otherwise-idle run.)"""
        dyn, _, _, _ = run
        for e in dyn.events:
            window = dyn.costs_for_epoch(e.epoch).idle_window
            assert e.overhead_hidden == (e.scheduling_seconds <= window)
            assert e.scheduling_seconds >= 0

    def test_timeline_and_replan_views(self, run):
        """The driver's simulator views: per-epoch timelines of the
        active plan, and the re-planned-vs-frozen stale-plan penalty."""
        dyn, _, _, _ = run
        tl = dyn.timeline()
        assert tl.num_workers == 1
        assert tl.makespan > 0
        rp = dyn.replan_timeline()
        assert rp.num_epochs == 3
        assert rp.stale_plan_penalty(0) == pytest.approx(0.0)
        # under the degraded uplink the re-planned decomposition must be
        # at least as good as freezing the epoch-0 plan
        for e in range(1, 3):
            assert rp.makespans[e] <= rp.frozen_makespans[e] + 1e-12

    def test_constructor_validation(self):
        import jax
        from jax.sharding import Mesh
        from repro.optim import adamw
        from repro.ps import DynamicPSTrainer, PSTopology
        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        with pytest.raises(ValueError, match="steps_per_epoch"):
            DynamicPSTrainer(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                             topology=PSTopology.uniform(1, 1),
                             steps_per_epoch=0,
                             input_shape=InputShape("x", 32, 4, "train"))
        with pytest.raises(ValueError, match="workers"):
            # 4-worker schedule on a 1-device mesh
            DynamicPSTrainer(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                             topology=PSTopology.uniform(1, 4),
                             steps_per_epoch=2,
                             input_shape=InputShape("x", 32, 4, "train"))


def _run_helper(name):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "helpers", name)],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
class TestDynamicTrainerMultiDevice:
    @pytest.fixture(scope="class")
    def result(self):
        return _run_helper("dynamic_trainer_check.py")

    def test_plan_changes_on_bandwidth_drop(self, result):
        ev = result["events"]
        assert len(ev) == 3                      # one per epoch boundary
        assert [e["step"] for e in ev] == [0, 3, 6]
        assert not ev[0]["changed"]              # first plan isn't a "change"
        assert ev[1]["changed"], "10→1 Gbps drop must re-segment the plan"
        assert (ev[1]["fwd"], ev[1]["bwd"]) != (ev[0]["fwd"], ev[0]["bwd"])

    def test_revisited_plan_hits_step_cache(self, result):
        """Exactly one new trace per distinct plan; the revisit re-traces
        nothing."""
        ev = result["events"]
        assert ev[2]["changed"] and not ev[2]["retraced"]
        assert (ev[2]["fwd"], ev[2]["bwd"]) == (ev[0]["fwd"], ev[0]["bwd"])
        assert result["traces"] == len(result["plans"]) == 2
        assert result["cache_hits"] == 1

    def test_hlo_counts_match_plans(self, result):
        for p in result["plans"]:
            assert p["ag"] == p["fwd"], p
            assert p["rs"] == p["bwd"], p

    def test_losses_bit_identical_to_static_sequence(self, result):
        assert result["losses_dyn"] == result["losses_static"]

    def test_scheduling_overhead_hidden(self, result):
        for e in result["events"]:
            assert e["sched_s"] >= 0
        # The epoch-0 pass has no in-flight gradient push to hide behind
        # (and pays one-time warmup), so Table I's claim is asserted for the
        # steady-state re-schedules only.
        for e in result["events"][1:]:
            assert e["hidden"], "DP must fit in the Δt + gt¹ idle window"


@pytest.mark.slow
class TestDynamicPSTrainerMultiDevice:
    """4-forged-device dynamic-PS run: degrade-then-recover uplinks, plan
    swap + cache revisit + bit-identity vs the static plan sequence (the
    ISSUE 4 acceptance criterion at deployment scale)."""

    @pytest.fixture(scope="class")
    def result(self):
        return _run_helper("dynamic_ps_check.py")

    def test_plan_changes_on_uplink_degradation_and_recovers(self, result):
        ev = result["events"]
        assert len(ev) == 3
        assert [e["step"] for e in ev] == [0, 3, 6]
        assert not ev[0]["changed"]
        assert ev[1]["changed"], \
            "10x slower uplinks must re-segment the consensus plan"
        assert ev[2]["changed"]                   # recovery swaps back
        assert (ev[2]["fwd"], ev[2]["bwd"]) == (ev[0]["fwd"], ev[0]["bwd"])

    def test_revisited_plan_hits_step_cache(self, result):
        assert result["traces"] == len(result["plans"]) == 2
        assert result["cache_hits"] == 1
        assert not result["events"][2]["retraced"]

    def test_hlo_one_pull_one_push_per_segment(self, result):
        for p in result["plans"]:
            assert p["ag"] == p["fwd"], p
            assert p["rs"] == p["bwd"], p

    def test_losses_bit_identical_to_static_sequence(self, result):
        assert result["losses_dyn"] == result["losses_static"]

    def test_scheduling_overhead_hidden(self, result):
        for e in result["events"][1:]:
            assert e["hidden"], \
                "DP must fit the topology's min Δt + gt¹ idle window"
