"""xlstm-350m [arXiv:2405.04517] — sLSTM + mLSTM blocks (7:1), attention-free."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    citation="arXiv:2405.04517",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                   # xLSTM blocks carry their own up/down projections
    vocab_size=50304,
    layer_pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_proj_factor=2.0,
    supports_long_context=True,   # O(1)-state recurrent decode
)
