"""DynaComm's DP-based scheduling algorithms (paper Algorithms 3 and 4).

Forward Bellman equation (paper eq. 13)::

    F[m][n] = min_{0<=k<m} { max(F[k][n-1], n*Δt + Σ_{1<=l<=m} pt_l)
                             + Σ_{k+1<=l<=m} fc_l }          1<=n<=m<=L

``F[m][n]`` is the earliest completion time of the first ``m`` layers'
forward compute given ``n`` transmission mini-procedures cover their
parameters.  The n-th transmission ends at ``n*Δt + Σ pt_{1..m}`` because
transmissions are serialized back-to-back on the link.

Backward Bellman equation (paper eq. 14)::

    B[m][n] = min_{0<=k<m} { max(B[k][n-1], Σ_{L-m+1<=l<=L} bc_l)
                             + Δt + Σ_{L-m+1<=l<=L-k} gt_l }  1<=n<=m<=L

``B[m][n]`` is the earliest completion time of the *gradient transmissions*
of the last ``m`` layers using ``n`` mini-procedures; backward compute runs
stall-free from layer L downwards.

Both run in O(L^3) time / O(L^2) space (paper Section IV-B4).  The inner
minimization is vectorized with numpy so the Fig. 12 complexity benchmark is
tractable at hundreds of layers.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.costmodel import (LayerCosts, Segment, backward_time,
                                  forward_time)

_INF = np.inf


@dataclasses.dataclass(frozen=True)
class DPResult:
    segments: Tuple[Segment, ...]
    time: float                  # optimal phase time (== f_m of segments)
    table: np.ndarray            # F or B, shape (L+1, L+1)
    num_transmissions: int


def _traceback(path: np.ndarray, L: int, n_star: int) -> Tuple[int, ...]:
    """Recover the k-chain 0 = k_0 < k_1 < ... < k_{n*} = L from Path."""
    bounds = [L]
    m, n = L, n_star
    while n > 0:
        k = int(path[m, n])
        if k < 0:
            raise RuntimeError("broken DP path")
        bounds.append(k)
        m, n = k, n - 1
    if bounds[-1] != 0:
        raise RuntimeError("DP path did not terminate at 0")
    return tuple(reversed(bounds))


def dp_forward(costs: LayerCosts) -> DPResult:
    """Algorithm 3 — optimal parameter-transmission segmentation."""
    L = costs.num_layers
    pt_pref = np.concatenate([[0.0], np.cumsum(costs.pt)])   # Σ pt_{1..m}
    fc_pref = np.concatenate([[0.0], np.cumsum(costs.fc)])   # Σ fc_{1..m}

    F = np.full((L + 1, L + 1), _INF)
    path = np.full((L + 1, L + 1), -1, dtype=np.int64)
    F[0, 0] = 0.0

    ms = np.arange(L + 1)
    for n in range(1, L + 1):
        prev = F[:, n - 1]                       # F[k][n-1], k = 0..L
        # arrive[m]: when the n-th transmission (ending at layer m) completes
        arrive = n * costs.dt + pt_pref
        # cand[m, k] = max(prev[k], arrive[m]) + (fc_pref[m] - fc_pref[k])
        cand = np.maximum(prev[None, :], arrive[:, None]) \
            + fc_pref[:, None] - fc_pref[None, :]
        cand[ms[:, None] <= ms[None, :]] = _INF  # require k < m
        ks = np.argmin(cand, axis=1)
        vals = cand[ms, ks]
        valid = ms >= n
        F[valid, n] = vals[valid]
        path[valid, n] = ks[valid]

    n_star = int(np.argmin(F[L, 1:]) + 1)
    t_star = float(F[L, n_star])
    bounds = _traceback(path, L, n_star)
    segments = tuple((bounds[i] + 1, bounds[i + 1]) for i in range(len(bounds) - 1))
    # Sanity: the DP objective must equal the O(L) cost function.
    assert abs(forward_time(costs, segments) - t_star) <= 1e-9 * max(1.0, t_star)
    return DPResult(segments=segments, time=t_star, table=F,
                    num_transmissions=n_star)


def dp_backward(costs: LayerCosts) -> DPResult:
    """Algorithm 4 — optimal gradient-transmission segmentation."""
    L = costs.num_layers
    # Reversed views: position j (1-indexed) = original layer L+1-j.
    bc_rev = costs.bc[::-1]
    gt_rev = costs.gt[::-1]
    bc_pref = np.concatenate([[0.0], np.cumsum(bc_rev)])     # Σ bc last-m layers
    gt_pref = np.concatenate([[0.0], np.cumsum(gt_rev)])     # Σ gt last-m layers

    B = np.full((L + 1, L + 1), _INF)
    path = np.full((L + 1, L + 1), -1, dtype=np.int64)
    B[0, 0] = 0.0

    ms = np.arange(L + 1)
    for n in range(1, L + 1):
        prev = B[:, n - 1]
        ready = bc_pref                              # compute-done time per m
        # cand[m, k] = max(prev[k], ready[m]) + Δt + (gt_pref[m] - gt_pref[k])
        cand = np.maximum(prev[None, :], ready[:, None]) + costs.dt_push \
            + gt_pref[:, None] - gt_pref[None, :]
        cand[ms[:, None] <= ms[None, :]] = _INF
        ks = np.argmin(cand, axis=1)
        vals = cand[ms, ks]
        valid = ms >= n
        B[valid, n] = vals[valid]
        path[valid, n] = ks[valid]

    n_star = int(np.argmin(B[L, 1:]) + 1)
    t_star = float(B[L, n_star])
    bounds = _traceback(path, L, n_star)
    # bounds are in reversed coordinates: reversed position j covers original
    # layer L+1-j; chain segment (k, m] reversed = original layers
    # [L-m+1 .. L-k], transmitted top-down.
    segments = tuple((L - bounds[i + 1] + 1, L - bounds[i])
                     for i in range(len(bounds) - 1))
    assert abs(backward_time(costs, segments) - t_star) <= 1e-9 * max(1.0, t_star)
    return DPResult(segments=segments, time=t_star, table=B,
                    num_transmissions=n_star)


def dynacomm_schedule(costs: LayerCosts):
    """Both directions; returns ((fwd_segments, bwd_segments), total_time)."""
    f = dp_forward(costs)
    b = dp_backward(costs)
    return (f.segments, b.segments), f.time + b.time
