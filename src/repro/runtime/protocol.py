"""The composable ``Trainer`` protocol every runtime implements.

One interface over all execution regimes — bucketed ZeRO, synchronous PS,
bounded-staleness async PS, and their dynamic (re-planning) variants — so
launchers, examples, and benchmarks drive any of them identically:

* ``fit(steps, eval_fn=..., eval_every=...)`` — run ``steps`` units of
  progress (training steps for the synchronous regimes, accepted
  gradient pushes for the asynchronous ones) against the configured data
  source; returns one loss per unit.  With an ``eval_fn`` (a zero-arg
  callable returning a scalar loss), the runtime calls it every
  ``eval_every`` units and records an :class:`EvalEvent` into
  ``events``; with ``checkpoint_every``/``checkpoint_path``, it calls
  ``save_state`` at every ``checkpoint_every``-unit boundary, so a
  killed run restarts from the last periodic checkpoint bit-identically;
* ``step(batch)`` — one unit of progress on an explicit batch (async
  regimes feed ``batch`` to every worker attempt until the next push
  commits);
* ``events`` — the ``RescheduleEvent`` history (empty for static
  regimes) plus any ``EvalEvent`` records from ``fit(eval_fn=...)``;
* ``timeline()`` — the regime's simulator view of the active plan
  (``IterationTimeline`` / ``PSTimeline`` for synchronous regimes, the
  cumulative ``AsyncRunLog`` for asynchronous ones; ``None`` where no
  plan exists, e.g. the local regime);
* ``ledger`` — cumulative transfer accounting as a plain dict
  (``pull_bytes``/``push_bytes``/``num_pulls``/``num_pushes`` + regime
  extras), uniform across the mesh-collective and server-mediated paths;
* ``save_state(path)`` / ``restore_state(path)`` — checkpoint the model
  (and, for dynamic regimes, the re-planning loop bookkeeping) through
  ``repro.checkpoint``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, \
    runtime_checkable


@dataclasses.dataclass(frozen=True)
class EvalEvent:
    """One evaluation recorded by ``fit(eval_fn=...)``."""

    unit: int        # units of progress consumed when the eval ran
    loss: float


@runtime_checkable
class Trainer(Protocol):
    """Uniform driver interface over every registered runtime."""

    def fit(self, steps: int, *, log_every: int = 0,
            eval_fn: Optional[Callable[[], float]] = None,
            eval_every: int = 0, checkpoint_every: int = 0,
            checkpoint_path: Optional[str] = None) -> List[float]:
        """Run ``steps`` units of progress; one loss per unit."""
        ...

    def step(self, batch: Any) -> float:
        """One unit of progress on an explicit batch."""
        ...

    @property
    def events(self) -> Sequence[Any]:
        """Re-scheduling history (empty for static regimes)."""
        ...

    def timeline(self) -> Optional[Any]:
        """The regime's simulator/log view of the active plan."""
        ...

    @property
    def ledger(self) -> Dict[str, Any]:
        """Cumulative transfer accounting."""
        ...

    def save_state(self, path: str) -> None:
        ...

    def restore_state(self, path: str) -> None:
        ...
