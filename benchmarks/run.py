"""Benchmark harness: one function per paper table/figure + roofline table.

``python -m benchmarks.run`` prints, per bench, a CSV block
(``name,us_per_call,derived``-style: each row carries the bench name, the
wall time of producing it, and the derived metrics as key=value pairs).
"""

from __future__ import annotations

import argparse
import time


def _print_block(name: str, rows, elapsed_s: float) -> None:
    us = 1e6 * elapsed_s / max(len(rows), 1)
    for row in rows:
        derived = ";".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{us:.1f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run a single bench by name")
    ap.add_argument("--skip-roofline", action="store_true")
    args, _ = ap.parse_known_args()

    from benchmarks.paper_figures import ALL_BENCHES
    benches = dict(ALL_BENCHES)

    if not args.skip_roofline:
        from benchmarks.roofline_report import roofline_rows
        benches["roofline_single_pod"] = \
            lambda: roofline_rows("dryrun_single_pod.jsonl")
        benches["roofline_multi_pod"] = \
            lambda: roofline_rows("dryrun_multi_pod.jsonl")

    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        rows = fn()
        _print_block(name, rows, time.perf_counter() - t0)


if __name__ == "__main__":
    main()
