"""Dynamic parameter-server demo: re-planning over a drifting topology,
and SSP wait-at-barrier vs stale-push rejection.

Two acts:

1. **run-time re-planning** — every worker's uplink degrades mid-training
   (``--up-factor``× slower at ``--shift-epoch``).  `DynamicPSTrainer`
   re-projects the topology's costs on each epoch boundary, re-runs the
   straggler-minimizing consensus decision, and swaps the compiled
   pull/push step from its plan-keyed AOT cache — watch the push
   segmentation change while the loss trajectory stays seamless;
2. **SSP throttling** — a 4x-slower edge worker at staleness k=1: the
   `reject` throttle starves it (every push arrives > k versions stale
   and is evicted), the `wait` throttle blocks the fast workers at the
   barrier instead, so the slow worker contributes every cycle and the
   staleness bound still holds.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/dynamic_ps.py
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.pipeline import SyntheticText
from repro.models.cnn import small_cnn_init, small_cnn_loss
from repro.optim import adamw, sgd
from repro.ps import (AsyncPSTrainer, DynamicPSTrainer, PSTopology,
                      asymmetric_link, uplink_degradation)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--steps-per-epoch", type=int, default=4)
    ap.add_argument("--shift-epoch", type=int, default=1)
    ap.add_argument("--up-factor", type=float, default=10.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--async-pushes", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(len(devs),), ("data",))
    shape = InputShape("dynamic-ps", args.seq, args.batch, "train")

    # --- 1. re-planning across an uplink degradation -------------------
    base = PSTopology.uniform(args.servers, len(devs), down_bps=10e9,
                              up_bps=10e9, flops=1e10)
    sched = uplink_degradation(base, factor=args.up_factor,
                               at_epoch=args.shift_epoch)
    print(f"topology: {args.servers} shards x {len(devs)} workers; every "
          f"uplink {args.up_factor:g}x slower from epoch "
          f"{args.shift_epoch}")
    dyn = DynamicPSTrainer(cfg=cfg, mesh=mesh, optimizer=adamw(1e-3),
                           topology=sched,
                           steps_per_epoch=args.steps_per_epoch,
                           input_shape=shape)
    pipe = SyntheticText(cfg.vocab_size, args.seq, args.batch, seed=0)
    state = dyn.init_state(jax.random.PRNGKey(0))
    state, _ = dyn.run(state, pipe.batch, args.steps, log_every=4)
    for e in dyn.events:
        ag, rs = dyn.hlo_counts(e.plan)
        print(f"  epoch {e.epoch}: {len(e.plan.forward)} pull / "
              f"{len(e.plan.backward)} push segments (hlo {ag} ag/{rs} rs) "
              f"{'re-segmented' if e.plan_changed else 'unchanged'}, "
              f"sched {e.scheduling_seconds * 1e3:.2f} ms, "
              f"hidden={e.overhead_hidden}")
    print(f"  traces {dyn.traces} (one per distinct plan), cache hits "
          f"{dyn.cache_hits}\n")

    # --- 2. SSP wait-at-barrier vs rejection on the smoke CNN ----------
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    from repro.core import plan_from_decision
    cnn_plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)
    topo = PSTopology(
        num_servers=args.servers,
        links=tuple(asymmetric_link(10e9, 1e9) for _ in range(4)),
        worker_flops=(4e10, 4e10, 4e10, 1e10))       # worker 3: 4x slower

    def loss_fn(layers, batch):
        return small_cnn_loss({"layers": layers}, batch["images"],
                              batch["labels"])

    def batch_fn(w, i):
        r = np.random.default_rng(100003 * w + i)
        return {"images": jnp.asarray(r.normal(size=(args.batch, 32, 32, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, 10, size=(args.batch,)),
                                      jnp.int32)}

    print(f"async smoke CNN, 4 workers (worker 3 is 4x slower), "
          f"k={args.staleness}:")
    for throttle in ("reject", "wait"):
        tr = AsyncPSTrainer(init_layers=params["layers"], loss_fn=loss_fn,
                            optimizer=sgd(0.05, 0.9), topology=topo,
                            plan=cnn_plan, staleness=args.staleness,
                            throttle=throttle)
        log = tr.run(args.async_pushes, batch_fn)
        by_worker = {w: log.accepted_by_worker().get(w, 0)
                     for w in range(topo.num_workers)}
        print(f"  {throttle:6s}: accepted per worker {by_worker}, "
              f"{log.num_rejected} rejected, "
              f"{log.total_wait_s:.2f}s waited at the barrier, "
              f"max staleness {log.max_staleness} <= k")
    print("  -> `wait` blocks fast workers at the SSP barrier instead of "
          "evicting the slow worker's pushes: everyone contributes and "
          "the bound still holds")


if __name__ == "__main__":
    main()
