"""Optimizers built from scratch (optax is not installed in this container).

Interface mirrors the usual gradient-transformation style::

    opt = adamw(lr=1e-3)
    state = opt.init(params)
    params, state = opt.update(grads, state, params)
"""

from repro.optim.optimizers import OptState, Optimizer, adamw, sgd

__all__ = ["Optimizer", "OptState", "sgd", "adamw"]
