"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,                 # per-expert FFN width
    vocab_size=49155,
    num_experts=32,
    top_k=8,
    activation="silu",
    gated_mlp=True,
    layer_pattern=("global_attn",),
)
