"""Public jit'd wrappers for bucket pack/unpack."""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bucket_pack.bucket_pack import (TILE, aligned, pack_pallas,
                                                   unpack_pallas)


def pad_segments(vectors: Sequence[jnp.ndarray]) -> Tuple[jnp.ndarray, tuple]:
    """Ragged 1-D vectors → (K, Lmax) TILE-padded matrix + aligned lengths."""
    alens = tuple(aligned(int(v.shape[0])) for v in vectors)
    lmax = max(alens)
    rows = [jnp.pad(v, (0, lmax - v.shape[0])) for v in vectors]
    return jnp.stack(rows), alens


@functools.partial(jax.jit, static_argnames=("aligned_lengths", "interpret"))
def bucket_pack(segments: jnp.ndarray, aligned_lengths: tuple, *,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    return pack_pallas(segments, aligned_lengths, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("aligned_lengths", "lmax", "interpret"))
def bucket_unpack(flat: jnp.ndarray, aligned_lengths: tuple, lmax: int, *,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    return unpack_pallas(flat, aligned_lengths, lmax, interpret=interpret)
