"""The runtime registry: name → builder, and the one factory entry point.

``@register_runtime("dynamic-ps", description=...)`` on an adapter class
makes it buildable from a :class:`~repro.runtime.config.RuntimeConfig`
whose ``runtime`` field carries that name; :func:`build_runtime` is the
single construction path every launcher, example, and benchmark goes
through.  Adding a new execution regime is one registry entry — no
launcher edits.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

from repro.runtime.config import RUNTIME_REGIMES, RuntimeConfig
from repro.runtime.protocol import Trainer

RUNTIMES: Dict[str, "RuntimeSpec"] = {}


@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """One registered runtime."""

    name: str
    regime: str                    # local | zero | ps-sync | ps-async
    description: str
    builder: Callable[..., Trainer]


def register_runtime(name: str, *, description: str = ""
                     ) -> Callable[[Callable], Callable]:
    """Class decorator registering a runtime builder under ``name``.

    The decorated callable is invoked as ``builder(config, arch,
    batch_fn)`` and must return a :class:`Trainer`.
    """
    if name not in RUNTIME_REGIMES:
        raise ValueError(f"runtime {name!r} is not a known name; add it to "
                         f"repro.runtime.config.RUNTIME_REGIMES first")

    def deco(builder):
        if name in RUNTIMES:
            raise ValueError(f"runtime {name!r} registered twice")
        RUNTIMES[name] = RuntimeSpec(name=name,
                                     regime=RUNTIME_REGIMES[name],
                                     description=description,
                                     builder=builder)
        return builder

    return deco


def runtime_names() -> Tuple[str, ...]:
    """Every registered runtime name, sorted."""
    _ensure_registered()
    return tuple(sorted(RUNTIMES))


def _ensure_registered() -> None:
    from repro.runtime import adapters  # noqa: F401  (registers on import)


def _as_config(config) -> RuntimeConfig:
    if isinstance(config, RuntimeConfig):
        return config
    if isinstance(config, dict):
        return RuntimeConfig.from_dict(config)
    if isinstance(config, str):
        return RuntimeConfig.from_json(config)
    raise TypeError(f"config must be a RuntimeConfig, dict, or JSON "
                    f"string, got {type(config).__name__}")


def build_runtime(config, model: Optional[Any] = None,
                  data: Optional[Any] = None) -> Trainer:
    """Build the configured runtime: the factory behind every launcher.

    Parameters
    ----------
    config:
        a :class:`RuntimeConfig` (or a dict / JSON string of one).
    model:
        an ``ArchConfig`` (or arch name) overriding ``config.arch``;
        ``None`` resolves ``config.arch`` (reduced per ``config.reduced``).
    data:
        a ``batch_fn(i) -> batch`` callable or a pipeline exposing
        ``.batch(i)``; ``None`` builds the deterministic
        ``SyntheticText`` stream from the config.
    """
    config = _as_config(config)
    _ensure_registered()
    if config.runtime not in RUNTIMES:
        raise ValueError(f"unknown runtime {config.runtime!r}; registered: "
                         f"{sorted(RUNTIMES)}")

    from repro.configs import get_config
    if model is None:
        arch = get_config(config.arch)
        if config.reduced:
            arch = arch.reduced()
    elif isinstance(model, str):
        arch = get_config(model)
        if config.reduced:
            arch = arch.reduced()
    else:
        arch = model

    if data is None:
        from repro.data.pipeline import SyntheticText
        batch_fn = SyntheticText(arch.vocab_size, config.seq, config.batch,
                                 seed=config.seed).batch
    elif callable(data):
        batch_fn = data
    elif hasattr(data, "batch"):
        batch_fn = data.batch
    else:
        raise TypeError(f"data must be a batch_fn or expose .batch(i), "
                        f"got {type(data).__name__}")

    return RUNTIMES[config.runtime].builder(config, arch, batch_fn)
