"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (RecurrentGemma/Griffin).

All three expose a full-sequence form for train/prefill and an O(1)-state
step for decode — the property that qualifies these families for the
``long_500k`` shape.

* mLSTM — matrix-memory LSTM (arXiv:2405.04517 eq. 19-27).  Training uses
  the stabilized quadratic parallel form; decode carries (C, n, m).
* sLSTM — scalar-memory LSTM with exponential gating and state
  normalization; inherently sequential → ``lax.scan`` over time.
* RG-LRU — real-gated linear recurrent unit (arXiv:2402.19427 §2.4) inside
  the Griffin recurrent block (proj → temporal conv4 → RG-LRU → gated out).
  Full-sequence form uses ``jax.lax.associative_scan``; a Pallas TPU kernel
  (repro.kernels.rglru_scan) implements the same scan blockwise in VMEM.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import dense, init_dense

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, H, hd, hd) matrix memory
    n: jnp.ndarray   # (B, H, hd) normalizer
    m: jnp.ndarray   # (B, H) stabilizer


def init_mlstm_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    ks = jax.random.split(key, 8)
    return {
        "up": init_dense(ks[0], d, di, dtype),
        "up_gate": init_dense(ks[1], d, di, dtype),
        "wq": init_dense(ks[2], di, di, dtype),
        "wk": init_dense(ks[3], di, di, dtype),
        "wv": init_dense(ks[4], di, di, dtype),
        "wi": init_dense(ks[5], di, cfg.num_heads, dtype),
        "wf": init_dense(ks[6], di, cfg.num_heads, dtype),
        "down": init_dense(ks[7], di, d, dtype),
    }


def _mlstm_parallel(q, k, v, i_gate, f_gate):
    """Stabilized parallel form.  q,k,v: (B,H,T,hd); gates: (B,H,T)."""
    hd = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))       # (B,H,T)
    F = jnp.cumsum(logf, axis=-1)                                # Σ_{s<=t} log f_s
    # D̃[t,s] = F_t - F_s + ĩ_s  for s<=t
    dtil = F[..., :, None] - F[..., None, :] + i_gate.astype(jnp.float32)[..., None, :]
    t = q.shape[2]
    causal = jnp.tril(jnp.ones((t, t), bool))
    dtil = jnp.where(causal, dtil, -np.inf)
    m = jnp.max(dtil, axis=-1, keepdims=True)                    # (B,H,T,1)
    dmat = jnp.exp(dtil - m)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    sd = s * dmat
    norm = jnp.maximum(jnp.abs(jnp.sum(sd, axis=-1, keepdims=True)),
                       jnp.exp(-m))
    h = jnp.einsum("bhts,bhsd->bhtd", sd / norm, v.astype(jnp.float32))
    return h.astype(q.dtype)


# Sequences longer than this use the chunkwise form in train/prefill (the
# full T×T decay matrix would blow HBM) — the TPU-native adaptation noted in
# DESIGN.md: intra-chunk parallel (MXU-friendly c×c tiles), inter-chunk
# recurrent carry (C, n, m), mathematically identical to the parallel form.
MLSTM_CHUNK = 256


def _mlstm_chunkwise(q, k, v, i_gate, f_gate, chunk: int | None = None):
    """q,k,v: (B,H,T,hd); gates: (B,H,T) → h: (B,H,T,hd), final state."""
    if chunk is None:
        chunk = MLSTM_CHUNK          # module attr: patchable for perf sweeps
    b, h, t, hd = q.shape
    while t % chunk:
        chunk //= 2
    n_c = t // chunk
    scale = 1.0 / np.sqrt(hd)

    def reshape_c(x):
        return x.reshape(x.shape[0], x.shape[1], n_c, chunk, *x.shape[3:])

    qc = reshape_c(q).transpose(2, 0, 1, 3, 4)      # (n_c,B,H,c,hd)
    kc = reshape_c(k).transpose(2, 0, 1, 3, 4)
    vc = reshape_c(v).transpose(2, 0, 1, 3, 4)
    ic = i_gate.reshape(b, h, n_c, chunk).transpose(2, 0, 1, 3)  # (n_c,B,H,c)
    fc_ = f_gate.reshape(b, h, n_c, chunk).transpose(2, 0, 1, 3)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inp):
        C, n, m_run = carry            # C:(B,H,hd,hd) n:(B,H,hd) m:(B,H)
        qq, kk, vv, ii, ff = inp
        qq32, kk32, vv32 = (x.astype(jnp.float32) for x in (qq, kk, vv))
        lf = jax.nn.log_sigmoid(ff.astype(jnp.float32))       # (B,H,c)
        a = jnp.cumsum(lf, axis=-1)                           # local decay to j
        A = a[..., -1]                                        # (B,H)
        ii32 = ii.astype(jnp.float32)

        # intra-chunk scores D̃[t,j] = a_t - a_j + ĩ_j (j<=t)
        dtil = a[..., :, None] - a[..., None, :] + ii32[..., None, :]
        dtil = jnp.where(causal, dtil, -jnp.inf)
        inter_log = a + m_run[..., None]                      # (B,H,c)
        m_t = jnp.maximum(jnp.max(dtil, axis=-1), inter_log)  # (B,H,c)

        d = jnp.exp(dtil - m_t[..., None])
        s = jnp.einsum("bhtd,bhjd->bhtj", qq32, kk32) * scale
        sd = s * d
        num_intra = jnp.einsum("bhtj,bhjd->bhtd", sd, vv32)
        den_intra = jnp.sum(sd, axis=-1)

        w_inter = jnp.exp(inter_log - m_t)                    # (B,H,c)
        num_inter = jnp.einsum("bhde,bhte->bhtd", C, qq32) * w_inter[..., None]
        den_inter = jnp.einsum("bhd,bhtd->bht", n, qq32) * w_inter

        denom = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        h_out = (num_intra + num_inter) / denom[..., None]

        # state update to chunk end
        bj = A[..., None] - a + ii32                          # (B,H,c)
        m_new = jnp.maximum(m_run + A, jnp.max(bj, axis=-1))
        w_old = jnp.exp(m_run + A - m_new)
        wj = jnp.exp(bj - m_new[..., None])
        kfs = kk32 * scale
        C_new = w_old[..., None, None] * C \
            + jnp.einsum("bhj,bhjd,bhje->bhde", wj, vv32, kfs)
        n_new = w_old[..., None] * n + jnp.einsum("bhj,bhjd->bhd", wj, kfs)
        return (C_new, n_new, m_new), h_out.astype(q.dtype)

    C0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    (C, n, m_run), hs = jax.lax.scan(body, (C0, n0, m0),
                                     (qc, kc, vc, ic, fc_))
    h_full = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, t, hd)
    return h_full, MLSTMState(c=C, n=n, m=m_run)


def _mlstm_step(q, k, v, i_gate, f_gate, state: MLSTMState):
    """One decode step.  q,k,v: (B,H,hd); gates: (B,H)."""
    hd = q.shape[-1]
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    m_new = jnp.maximum(logf + state.m, i_gate.astype(jnp.float32))
    f_p = jnp.exp(logf + state.m - m_new)
    i_p = jnp.exp(i_gate.astype(jnp.float32) - m_new)
    kf = k.astype(jnp.float32) / np.sqrt(hd)
    c = f_p[..., None, None] * state.c \
        + i_p[..., None, None] * jnp.einsum("bhd,bhe->bhde",
                                            v.astype(jnp.float32), kf)
    n = f_p[..., None] * state.n + i_p[..., None] * kf
    num = jnp.einsum("bhde,bhe->bhd", c, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n,
                                         q.astype(jnp.float32)))[..., None],
                      jnp.exp(-m_new)[..., None])
    h = (num / den).astype(q.dtype)
    return h, MLSTMState(c=c, n=n, m=m_new)


def init_mlstm_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> MLSTMState:
    di = int(cfg.mlstm_proj_factor * cfg.d_model)
    hd = di // cfg.num_heads
    return MLSTMState(
        c=jnp.zeros((batch, cfg.num_heads, hd, hd), jnp.float32),
        n=jnp.zeros((batch, cfg.num_heads, hd), jnp.float32),
        m=jnp.full((batch, cfg.num_heads), 0.0, jnp.float32),
    )


def apply_mlstm(params, x: jnp.ndarray, cfg: ArchConfig, *, mode: str,
                state: Optional[MLSTMState] = None
                ) -> Tuple[jnp.ndarray, Optional[MLSTMState]]:
    b, t, _ = x.shape
    h_heads = cfg.num_heads
    up = dense(x, params["up"])
    gate = jax.nn.silu(dense(x, params["up_gate"]))
    di = up.shape[-1]
    hd = di // h_heads

    q = dense(up, params["wq"]).reshape(b, t, h_heads, hd).transpose(0, 2, 1, 3)
    k = dense(up, params["wk"]).reshape(b, t, h_heads, hd).transpose(0, 2, 1, 3)
    v = dense(up, params["wv"]).reshape(b, t, h_heads, hd).transpose(0, 2, 1, 3)
    ig = dense(up, params["wi"]).transpose(0, 2, 1)      # (B, H, T)
    fg = dense(up, params["wf"]).transpose(0, 2, 1)

    if mode in ("train", "prefill"):
        if t > MLSTM_CHUNK:
            h, final_state = _mlstm_chunkwise(q, k, v, ig, fg)
        else:
            h = _mlstm_parallel(q, k, v, ig, fg)         # (B,H,T,hd)
            final_state = None
            if mode == "prefill":
                _, final_state = _mlstm_chunkwise(q, k, v, ig, fg,
                                                  chunk=min(t, MLSTM_CHUNK))
        new_state = final_state if mode == "prefill" else None
        out = h.transpose(0, 2, 1, 3).reshape(b, t, di)
    else:
        assert state is not None and t == 1
        h, new_state = _mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                   ig[:, :, 0], fg[:, :, 0], state)
        out = h.reshape(b, 1, di)

    return dense(out * gate, params["down"]), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, D)
    n: jnp.ndarray   # (B, D)
    h: jnp.ndarray   # (B, D)
    m: jnp.ndarray   # (B, D)


def init_slstm_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    p = {}
    for idx, gate in enumerate(("i", "f", "z", "o")):
        p[f"w{gate}"] = init_dense(ks[idx], d, d, dtype)
        p[f"r{gate}"] = init_dense(ks[4 + idx], d, d, dtype) * 0.1
    p["down"] = init_dense(ks[8], d, d, dtype)
    return p


def init_slstm_state(cfg: ArchConfig, batch: int) -> SLSTMState:
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=z)


def _slstm_step(params, x_t, s: SLSTMState):
    def gate(name):
        return (dense(x_t, params[f"w{name}"])
                + dense(s.h.astype(x_t.dtype), params[f"r{name}"])
                ).astype(jnp.float32)
    itil, ftil = gate("i"), gate("f")
    z = jnp.tanh(gate("z"))
    o = jax.nn.sigmoid(gate("o"))
    logf = jax.nn.log_sigmoid(ftil)
    m_new = jnp.maximum(logf + s.m, itil)
    i_p = jnp.exp(itil - m_new)
    f_p = jnp.exp(logf + s.m - m_new)
    c = f_p * s.c + i_p * z
    n = f_p * s.n + i_p
    h = o * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new)


def apply_slstm(params, x: jnp.ndarray, cfg: ArchConfig, *, mode: str,
                state: Optional[SLSTMState] = None
                ) -> Tuple[jnp.ndarray, Optional[SLSTMState]]:
    b, t, d = x.shape
    if mode in ("train", "prefill"):
        s0 = init_slstm_state(cfg, b)
        def body(s, x_t):
            s2 = _slstm_step(params, x_t, s)
            return s2, s2.h
        final, hs = jax.lax.scan(body, s0, x.transpose(1, 0, 2))
        out = hs.transpose(1, 0, 2).astype(x.dtype)
        new_state = final if mode == "prefill" else None
    else:
        assert state is not None and t == 1
        s2 = _slstm_step(params, x[:, 0], state)
        out = s2.h[:, None].astype(x.dtype)
        new_state = s2
    return dense(out, params["down"]), new_state


# ---------------------------------------------------------------------------
# RG-LRU (Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0
_CONV_WIDTH = 4


class RGLRUState(NamedTuple):
    h: jnp.ndarray      # (B, W) recurrent state
    conv: jnp.ndarray   # (B, CONV_WIDTH-1, W) trailing inputs for the conv


def init_rglru_params(key, cfg: ArchConfig, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.rglru_lru_width or d
    ks = jax.random.split(key, 7)
    # Λ init so a^c stays in (0.9, 0.999) — Griffin appendix
    lam = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    lam_param = jnp.log(jnp.exp(-jnp.log(lam) / _RGLRU_C) - 1.0)  # softplus^-1
    return {
        "in_x": init_dense(ks[0], d, w, dtype),
        "in_gate": init_dense(ks[1], d, w, dtype),
        "conv": (jax.random.normal(ks[2], (_CONV_WIDTH, w)) * 0.1).astype(dtype),
        "w_rgate": init_dense(ks[3], w, w, dtype),
        "w_igate": init_dense(ks[4], w, w, dtype),
        "lam": lam_param.astype(jnp.float32),
        "out": init_dense(ks[6], w, d, dtype),
    }


def init_rglru_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    w = cfg.rglru_lru_width or cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, w), jnp.float32),
                      conv=jnp.zeros((batch, _CONV_WIDTH - 1, w), dtype))


def _rglru_gates(params, u):
    """u: (..., W) post-conv activations → (log_a, gated_input) float32."""
    r = jax.nn.sigmoid(dense(u, params["w_rgate"]).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(u, params["w_igate"]).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    x_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * u.astype(jnp.float32)
    return a, x_in


def rglru_scan(a, x):
    """h_t = a_t h_{t-1} + x_t along axis=1 via associative scan."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h


def apply_rglru(params, x: jnp.ndarray, cfg: ArchConfig, *, mode: str,
                state: Optional[RGLRUState] = None, use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, Optional[RGLRUState]]:
    b, t, _ = x.shape
    gate = jax.nn.gelu(dense(x, params["in_gate"]), approximate=True)
    u = dense(x, params["in_x"])                                   # (B, T, W)

    if mode in ("train", "prefill"):
        pad = jnp.zeros((b, _CONV_WIDTH - 1, u.shape[-1]), u.dtype)
        upad = jnp.concatenate([pad, u], axis=1)
        conv = sum(upad[:, i:i + t] * params["conv"][i].astype(u.dtype)
                   for i in range(_CONV_WIDTH))
        a, x_in = _rglru_gates(params, conv)
        if use_kernel:
            from repro.kernels.rglru_scan import ops as _kops
            h = _kops.rglru_scan(a, x_in)
        else:
            h = rglru_scan(a, x_in)
        new_state = None
        if mode == "prefill":
            new_state = RGLRUState(h=h[:, -1], conv=upad[:, -(_CONV_WIDTH - 1):])
        out = h.astype(x.dtype)
    else:
        assert state is not None and t == 1
        hist = jnp.concatenate([state.conv, u], axis=1)            # (B, 4, W)
        conv = sum(hist[:, i] * params["conv"][i].astype(u.dtype)
                   for i in range(_CONV_WIDTH))
        a, x_in = _rglru_gates(params, conv)
        h = a * state.h + x_in
        new_state = RGLRUState(h=h, conv=hist[:, 1:])
        out = h[:, None].astype(x.dtype)

    return dense(out * gate, params["out"]), new_state
