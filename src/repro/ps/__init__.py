"""Parameter-Server execution subsystem — the paper's actual topology.

``repro.dist`` executes DynaComm plans through symmetric ZeRO collectives
(the TPU-native adaptation); this package executes them in the paper's
own deployment shape: S server shards × W edge workers, segmented
parameter pulls down and gradient pushes up over per-worker asymmetric
links, synchronously (``PSTrainer``, bit-identical to the ZeRO trainer)
or asynchronously under a bounded staleness ``k`` (``AsyncPSTrainer``).
"""

from repro.ps.async_mode import (AsyncPSTrainer, AsyncPushEvent,
                                 AsyncRunLog)
from repro.ps.server import (PSServer, PushResult, StaleVersion,
                             TransferLedger)
from repro.ps.topology import LinkModel, PSTopology, asymmetric_link
from repro.ps.worker import PSTrainer

__all__ = [
    "LinkModel", "PSTopology", "asymmetric_link",
    "PSServer", "PushResult", "StaleVersion", "TransferLedger",
    "PSTrainer",
    "AsyncPSTrainer", "AsyncPushEvent", "AsyncRunLog",
]
