"""Edge parameter-server demo: the paper's deployment shape, end to end.

A heterogeneous edge fleet — fast and slow workers behind asymmetric
links (edge uplinks are 5-20x slower than downlinks) — trains against
``--servers`` parameter-server shards:

1. **per-topology scheduling** — DynaComm plans per *worker* (each has
   its own fc/bc and pt/gt/Δt); the per-worker optimal decompositions
   differ, and the sync consensus plan minimizes the straggler makespan;
2. **sync mode** — the ``ps`` runtime, built from one ``RuntimeConfig``
   whose ``TopologyConfig`` carries the per-worker link/compute lists
   (heterogeneity is config data, not wiring code), executes the
   consensus plan with one pull + one push transmission per segment;
   per-worker timelines show who gates the barrier;
3. **async mode** — `AsyncPSTrainer` drops the barrier: bounded
   staleness k lets fast workers run ahead up to k versions, the server
   rejects anything staler, and the smoke CNN still converges.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/edge_ps.py
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import (consensus_decision, decision_from_plan,
                        plan_from_decision, schedule_topology)
from repro.core.viz import render_ps_timeline
from repro.models.cnn import small_cnn_init, small_cnn_loss
from repro.models.profiles import layer_profiles
from repro.optim import sgd
from repro.ps import AsyncPSTrainer
from repro.runtime import (RuntimeConfig, ScheduleConfig, TopologyConfig,
                           build_runtime)


def heterogeneous_fleet(num_workers: int, base_flops: float):
    """Half fast workers on good links, half slow ones on degraded links,
    as per-worker config lists (down Gbps, up Gbps, FLOP/s)."""
    down, up, flops = [], [], []
    for w in range(num_workers):
        slow = w >= num_workers // 2
        down.append(2.5 if slow else 10.0)
        up.append(0.25 if slow else 1.0)
        flops.append(base_flops / 4 if slow else base_flops)
    return tuple(down), tuple(up), tuple(flops)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--worker-flops", type=float, default=1e10)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--async-pushes", type=int, default=30)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    down, up, flops = heterogeneous_fleet(n_dev, args.worker_flops)
    config = RuntimeConfig(
        runtime="ps", arch=args.arch, batch=args.batch, seq=args.seq,
        optimizer="adamw", lr=1e-3,
        schedule=ScheduleConfig(topology=TopologyConfig(
            servers=args.servers, down_gbps=down, up_gbps=up,
            worker_flops=flops)))
    print(f"topology: {args.servers} server shards x {n_dev} workers "
          f"(half at 1/4 compute on 1/4 bandwidth)")

    # --- 1. per-worker planning: the decompositions genuinely differ ----
    cfg = get_config(args.arch).reduced()
    shape = InputShape("edge-ps", args.seq, args.batch, "train")
    topo = (config.schedule.topology or TopologyConfig()).build(n_dev)
    costs = topo.topology_costs(layer_profiles(cfg, shape))
    per_worker = schedule_topology(costs, "dynacomm")
    from repro.core import iteration_time
    for w, (f, b) in enumerate(per_worker):
        t = iteration_time(costs.workers[w], f, b)
        print(f"  worker {w}: optimal plan {len(f)} pull / {len(b)} push "
              f"segments, own iter {t:.4f}s")
    decision, makespan = consensus_decision(costs, "dynacomm")
    print(f"  consensus (sync): {len(decision[0])} pull / "
          f"{len(decision[1])} push segments, straggler makespan "
          f"{makespan:.4f}s\n")

    # --- 2. sync mode on the device mesh, via the runtime factory -------
    rt = build_runtime(config)
    tr = rt.trainer
    print(render_ps_timeline(costs, decision_from_plan(tr.plan)))
    owners = tr.segment_owners()
    print(f"segment -> shard routing: pulls {owners['forward']}, "
          f"pushes {owners['backward']}")
    rt.fit(args.steps, log_every=10)

    # --- 3. async bounded staleness on the smoke CNN (library API) ------
    print(f"\nasync bounded-staleness (k={args.staleness}) on the smoke "
          f"CNN:")
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    cnn_plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)

    def loss_fn(layers, batch):
        return small_cnn_loss({"layers": layers}, batch["images"],
                              batch["labels"])

    atr = AsyncPSTrainer(init_layers=params["layers"], loss_fn=loss_fn,
                         optimizer=sgd(0.05, 0.9), topology=topo,
                         plan=cnn_plan, staleness=args.staleness)

    def batch_fn(w, i):
        r = np.random.default_rng(100003 * w + i)
        return {"images": jnp.asarray(r.normal(size=(args.batch, 32, 32, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, 10, size=(args.batch,)),
                                      jnp.int32)}

    log = atr.run(args.async_pushes, batch_fn)
    print(f"  {len(log.accepted)} accepted / {log.num_rejected} stale-"
          f"rejected pushes; max staleness {log.max_staleness} <= "
          f"k={args.staleness}")
    per_worker_counts = {w: sum(1 for e in log.accepted if e.worker == w)
                         for w in range(topo.num_workers)}
    print(f"  accepted pushes per worker: {per_worker_counts} — no "
          f"barrier: fast workers commit at their own rate, and gradients "
          f"computed more than k versions ago are rejected (raise "
          f"--staleness, or see examples/dynamic_ps.py for the SSP "
          f"wait throttle that lets 4x-slower workers contribute at any k)")
    print(f"  loss {log.losses[0]:.4f} -> {log.losses[-1]:.4f} over "
          f"{len(log.losses)} versions")


if __name__ == "__main__":
    main()
