"""Edge parameter-server demo: the paper's deployment shape, end to end.

A heterogeneous edge fleet — fast and slow workers behind asymmetric
links (edge uplinks are 5-20x slower than downlinks) — trains against
``--servers`` parameter-server shards:

1. **per-topology scheduling** — DynaComm plans per *worker* (each has
   its own fc/bc and pt/gt/Δt); the per-worker optimal decompositions
   differ, and the sync consensus plan minimizes the straggler makespan;
2. **sync mode** — `PSTrainer` executes the consensus plan with one pull
   + one push transmission per segment (bit-identical losses to the ZeRO
   trainer); per-worker timelines show who gates the barrier;
3. **async mode** — `AsyncPSTrainer` drops the barrier: bounded
   staleness k lets fast workers run ahead up to k versions, the server
   rejects anything staler, and the smoke CNN still converges.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/edge_ps.py
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import (consensus_decision, decision_from_plan,
                        plan_from_decision, schedule_topology)
from repro.core.viz import render_ps_timeline
from repro.data.pipeline import SyntheticText
from repro.models.cnn import small_cnn_init, small_cnn_loss
from repro.models.profiles import layer_profiles
from repro.optim import adamw, sgd
from repro.ps import AsyncPSTrainer, PSTopology, PSTrainer, asymmetric_link


def heterogeneous_topology(num_servers: int, num_workers: int,
                           base_flops: float) -> PSTopology:
    """Half fast workers on good links, half slow ones on degraded links."""
    links, flops = [], []
    for w in range(num_workers):
        slow = w >= num_workers // 2
        links.append(asymmetric_link(down_bps=(2.5e9 if slow else 10e9),
                                     up_bps=(0.25e9 if slow else 1e9)))
        flops.append(base_flops / 4 if slow else base_flops)
    return PSTopology(num_servers=num_servers, links=tuple(links),
                      worker_flops=tuple(flops))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--worker-flops", type=float, default=1e10)
    ap.add_argument("--staleness", type=int, default=1)
    ap.add_argument("--async-pushes", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    devs = jax.devices()
    mesh = Mesh(np.array(devs).reshape(len(devs),), ("data",))
    topo = heterogeneous_topology(args.servers, len(devs), args.worker_flops)
    shape = InputShape("edge-ps", args.seq, args.batch, "train")
    print(f"topology: {topo.num_servers} server shards x "
          f"{topo.num_workers} workers "
          f"(half at 1/4 compute on 1/4 bandwidth)")

    # --- 1. per-worker planning: the decompositions genuinely differ ----
    costs = topo.topology_costs(layer_profiles(cfg, shape))
    per_worker = schedule_topology(costs, "dynacomm")
    from repro.core import iteration_time
    for w, (f, b) in enumerate(per_worker):
        t = iteration_time(costs.workers[w], f, b)
        print(f"  worker {w}: optimal plan {len(f)} pull / {len(b)} push "
              f"segments, own iter {t:.4f}s")
    decision, makespan = consensus_decision(costs, "dynacomm")
    print(f"  consensus (sync): {len(decision[0])} pull / "
          f"{len(decision[1])} push segments, straggler makespan "
          f"{makespan:.4f}s\n")

    # --- 2. sync mode on the device mesh --------------------------------
    tr = PSTrainer.from_topology(cfg, mesh, topo, adamw(1e-3), shape)
    print(render_ps_timeline(costs, decision_from_plan(tr.plan)))
    owners = tr.segment_owners()
    print(f"segment -> shard routing: pulls {owners['forward']}, "
          f"pushes {owners['backward']}")
    state = tr.init_state(jax.random.PRNGKey(0))
    step = jax.jit(tr.build_train_step())
    pipe = SyntheticText(cfg.vocab_size, args.seq, args.batch, seed=0)
    for i in range(args.steps):
        state, loss = step(state, pipe.batch(i))
        if (i + 1) % 10 == 0:
            print(f"  sync step {i + 1:3d}  loss {float(loss):.4f}")

    # --- 3. async bounded staleness on the smoke CNN --------------------
    print(f"\nasync bounded-staleness (k={args.staleness}) on the smoke "
          f"CNN:")
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    cnn_plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)

    def loss_fn(layers, batch):
        return small_cnn_loss({"layers": layers}, batch["images"],
                              batch["labels"])

    atr = AsyncPSTrainer(init_layers=params["layers"], loss_fn=loss_fn,
                         optimizer=sgd(0.05, 0.9), topology=topo,
                         plan=cnn_plan, staleness=args.staleness)

    def batch_fn(w, i):
        r = np.random.default_rng(100003 * w + i)
        return {"images": jnp.asarray(r.normal(size=(args.batch, 32, 32, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, 10, size=(args.batch,)),
                                      jnp.int32)}

    log = atr.run(args.async_pushes, batch_fn)
    print(f"  {len(log.accepted)} accepted / {log.num_rejected} stale-"
          f"rejected pushes; max staleness {log.max_staleness} <= "
          f"k={args.staleness}")
    per_worker_counts = {w: sum(1 for e in log.accepted if e.worker == w)
                         for w in range(topo.num_workers)}
    print(f"  accepted pushes per worker: {per_worker_counts} — no "
          f"barrier: fast workers commit at their own rate, and gradients "
          f"computed more than k versions ago are rejected (raise "
          f"--staleness, or see examples/dynamic_ps.py for the SSP "
          f"wait throttle that lets 4x-slower workers contribute at any k)")
    print(f"  loss {log.losses[0]:.4f} -> {log.losses[-1]:.4f} over "
          f"{len(log.losses)} versions")


if __name__ == "__main__":
    main()
