"""Fused compression kernels for the transmission hot path."""

from repro.kernels.compress.ops import (TILE, aligned, densify,
                                        dequantize_unpack, quantize_pack,
                                        sparsify, topk_indices)

__all__ = ["TILE", "aligned", "quantize_pack", "dequantize_unpack",
           "topk_indices", "sparsify", "densify"]
