from repro.train.loop import TrainLoop, build_train_step

__all__ = ["build_train_step", "TrainLoop"]
