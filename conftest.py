# Ensures `import benchmarks` and `import repro` work from pytest (adds
# repo root + src/ to sys.path), and installs the in-repo hypothesis
# fallback when the real package is absent (hermetic containers; CI installs
# the real one via the `test` extra in pyproject.toml).
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_fallback
    hypothesis_fallback.install()
