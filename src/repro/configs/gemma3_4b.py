"""gemma3-4b [hf:google/gemma-3-1b-pt family] — 5:1 local:global, 128k ctx."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    citation="hf:google/gemma-3-1b-pt",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,
    activation="geglu",
    gated_mlp=True,
    layer_pattern=("local_attn",) * 5 + ("global_attn",),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    supports_long_context=True,   # sliding window; global-layer KV data-sharded
)
