"""Reproduce the paper's CNN case study from the command line.

Prints the per-strategy normalized execution times for any of the paper's
four models under the calibrated edge testbed, plus the chosen decisions.

    PYTHONPATH=src:. python examples/paper_cnn_study.py --model resnet152
"""

import argparse

from benchmarks.edge_setup import cnn_costs
from repro.core import evaluate, schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="resnet152",
                    choices=["vgg19", "googlenet", "inception-v4",
                             "resnet152"])
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    costs = cnn_costs(args.model, batch=args.batch)
    print(f"{args.model} (batch {args.batch}): L={costs.num_layers}, "
          f"Δt={costs.dt * 1e3:.1f} ms")
    seq = None
    for strategy in ("sequential", "lbl", "ibatch", "dynacomm"):
        decision = schedule(costs, strategy)
        t = evaluate(costs, decision)
        seq = seq or t["total"]
        fwd, bwd = decision
        print(f"  {strategy:10s} iter {t['total']:7.3f}s "
              f"(normalized {t['total'] / seq:.3f}, "
              f"reduced {100 * (1 - t['total'] / seq):5.2f}%)  "
              f"buckets fwd={len(fwd)} bwd={len(bwd)}")


if __name__ == "__main__":
    main()
