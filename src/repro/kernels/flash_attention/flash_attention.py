"""Pallas TPU flash attention: blockwise causal/windowed softmax attention.

Grid ``(B*H, Tq/bq, Tk/bk)`` with the kv axis innermost and sequential
("arbitrary" semantics): each (bh, qi) pair streams kv blocks through VMEM,
maintaining the online-softmax state (m, l, acc) in VMEM scratch and writing
the normalized output on the last visited kv block.  Causal and
sliding-window masks are applied blockwise from iota, never materializing a
(Tq, Tk) matrix; fully-masked kv blocks are skipped via ``pl.when``.

Block shapes default to (128, 128): MXU-aligned on both matmul dims, with
the head dim padded to a lane multiple by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro._compat.pallas import CompilerParams as _CompilerParams
from repro._compat.pallas import resolve_interpret

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_kv: int, causal: bool, window: int,
                  softcap: float, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    k_lo = ki * bk
    # blockwise mask from iota — no (Tq, Tk) materialization
    qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window

    # skip blocks that are entirely masked (future / out-of-window)
    live = True
    if causal:
        live = k_lo <= q_lo + bq - 1
    if window > 0:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _step():
        q = q_ref[...].astype(jnp.float32)          # (bq, hd)
        k = k_ref[...].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[...].astype(jnp.float32)          # (bk, hd)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int = 0,
                           softcap: float = 0.0, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK, scale: float | None = None,
                           interpret: bool | None = None) -> jnp.ndarray:
    """q,k,v: (BH, T, hd) head-major; T % bq == T % bk == 0.

    ``scale`` must be 1/sqrt(true head dim) when hd is lane-padded.
    """
    bh, tq, hd = q.shape
    tk = k.shape[1]
    assert tq % bq == 0 and tk % bk == 0
    n_q, n_kv = tq // bq, tk // bk
    if scale is None:
        scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal,
        window=window, softcap=softcap, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((None, bk, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),       # m
            pltpu.VMEM((bq, 1), jnp.float32),       # l
            pltpu.VMEM((bq, hd), jnp.float32),      # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(q, k, v)
