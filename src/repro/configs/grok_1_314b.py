"""grok-1-314b [hf:xai-org/grok-1] — MoE 8 experts top-2; multi-pod stress case."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    citation="hf:xai-org/grok-1",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,               # per-expert FFN width
    vocab_size=131072,
    head_dim=128,
    num_experts=8,
    top_k=2,
    activation="gelu",
    gated_mlp=True,
    attn_logit_softcap=30.0,
    final_logit_softcap=30.0,
)
