"""Subprocess helper: multi-device checks for the pipeline trainer.

Run with 4 forged host devices (XLA_FLAGS set here, before jax imports).
Prints one JSON line the parent asserts on.  Checks:

1. stage isolation — each stage's compiled forward/backward program
   contains zero cross-device collectives (boundary traffic is explicit
   host-mediated buffer hand-off, never a hidden all-reduce);
2. exactness — losses are bit-identical across stage counts S in
   {1, 2, 4} at fixed micro-batching (the S=1 run *is* the single-device
   execution of the same decomposition), with S=4 placed on 4 distinct
   forged devices;
3. single-device reference — pipeline losses match the fused
   ``jax.value_and_grad(train_loss)`` step to fp32 roundoff;
4. ledger audit — boundary pulls/pushes counted exactly:
   per step, M activations per boundary forward, M activation grads per
   boundary backward, one tied-embedding broadcast and M embedding-grad
   returns when the head lives off the embedding stage.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json

import jax
import jax.numpy as jnp

from repro.analysis import collective_counts
from repro.configs import get_config
from repro.models import init_params, train_loss
from repro.optim import adamw
from repro.pipeline import PipelineTrainer

STEPS = 3


def run(cfg, batch, S, M, devices=None):
    tr = PipelineTrainer(cfg=cfg, optimizer=adamw(1e-3), num_stages=S,
                         num_microbatches=M, stage_devices=devices)
    state = tr.init_state(jax.random.PRNGKey(0))
    losses = []
    for _ in range(STEPS):
        state, loss = tr.step(state, batch)
        losses.append(float(loss))
    return tr, losses


def main():
    cfg = get_config("granite-3-2b").reduced()
    B, T = 8, 32
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    devices = jax.devices()

    out = {"num_devices": len(devices), "losses": {}}
    trainers = {}
    for M in (1, 4):
        for S in (1, 2, 4):
            devs = devices[:S] if S == 4 else None
            tr, losses = run(cfg, batch, S, M, devices=devs)
            trainers[(S, M)] = tr
            out["losses"][f"S{S}M{M}"] = losses

    # per-stage collective audit on the 4-stage 4-device trainer
    tr4 = trainers[(4, 4)]
    stage_collectives = []
    for fwd_hlo, bwd_hlo in tr4.stage_hlo(batch):
        cf = collective_counts(fwd_hlo)
        cb = collective_counts(bwd_hlo)
        stage_collectives.append(
            {"fwd": sum(cf.values()), "bwd": sum(cb.values())})
    out["stage_collectives"] = stage_collectives

    # ledger audit: S=4, M=4, STEPS steps, 3 boundaries, tied embed split
    act_bytes = tr4.activation_bytes()
    led = tr4.ledger
    M, nb = 4, len(act_bytes)
    embed_bytes = tr4.specs[0].total * 4
    out["ledger"] = {
        "num_pulls": led["num_pulls"],
        "expected_pulls": STEPS * (M * nb + 1),
        "num_pushes": led["num_pushes"],
        "expected_pushes": STEPS * (M * nb + M),
        "pull_bytes": led["pull_bytes"],
        "expected_pull_bytes": STEPS * (M * sum(act_bytes) + embed_bytes),
        "push_bytes": led["push_bytes"],
        "expected_push_bytes": STEPS * (M * sum(act_bytes)
                                        + M * embed_bytes),
    }

    # single-device fused reference (same init, optimizer, aux weight)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    ostate = opt.init(params)

    @jax.jit
    def ref_step(params, ostate, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch, aux_weight=0.01))(params)
        params, ostate = opt.update(grads, ostate, params)
        return params, ostate, loss

    ref_losses = []
    for _ in range(STEPS):
        params, ostate, loss = ref_step(params, ostate, batch)
        ref_losses.append(float(loss))
    out["reference_losses"] = ref_losses
    print(json.dumps(out))


if __name__ == "__main__":
    main()
