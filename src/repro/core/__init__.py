"""DynaComm core: the paper's contribution (scheduling) as a library."""

from repro.core.costmodel import (LayerCosts, Segment, TopologyCosts,
                                  backward_time, forward_time, iteration_time)
from repro.core.dp import (DPResult, PartitionResult, dp_backward, dp_forward,
                           dp_partition, dynacomm_schedule)
from repro.core.greedy import ibatch_backward, ibatch_forward, ibatch_schedule
from repro.core.baselines import (lbl_backward, lbl_forward,
                                  sequential_backward, sequential_forward)
from repro.core.bruteforce import bruteforce_backward, bruteforce_forward
from repro.core.scheduler import (STRATEGIES, Decision, DynaCommScheduler,
                                  TopologyScheduler, consensus_decision,
                                  evaluate, schedule, schedule_topology)
from repro.core.planner import AsyncPlanner, Planner, PlannerStats, cost_key
from repro.core.buckets import (BucketPlan, decision_from_plan,
                                plan_from_decision)
from repro.core.profiler import (EwmaDriftDetector, LayerProfile,
                                 LayerTimingHook, costs_from_profiles,
                                 measure_layer_costs, random_costs)
from repro.core.netmodel import (EdgeNetworkModel, NetworkSchedule,
                                 TPUSystemModel, TPU_HBM_BW,
                                 TPU_ICI_BW_PER_LINK, TPU_PEAK_FLOPS_BF16,
                                 as_schedule, bandwidth_shift)
from repro.core.simulator import (IterationTimeline, PSReplanTimeline,
                                  PSTimeline, check_partial_orders,
                                  simulate_backward, simulate_forward,
                                  simulate_iteration, simulate_ps_iteration,
                                  simulate_ps_replan)

__all__ = [
    "LayerCosts", "Segment", "TopologyCosts",
    "forward_time", "backward_time", "iteration_time",
    "DPResult", "PartitionResult", "dp_forward", "dp_backward",
    "dp_partition", "dynacomm_schedule",
    "ibatch_forward", "ibatch_backward", "ibatch_schedule",
    "lbl_forward", "lbl_backward", "sequential_forward", "sequential_backward",
    "bruteforce_forward", "bruteforce_backward",
    "STRATEGIES", "Decision", "DynaCommScheduler", "TopologyScheduler",
    "evaluate", "schedule", "schedule_topology", "consensus_decision",
    "AsyncPlanner", "Planner", "PlannerStats", "cost_key",
    "BucketPlan", "plan_from_decision", "decision_from_plan",
    "EwmaDriftDetector", "LayerProfile", "LayerTimingHook",
    "costs_from_profiles", "measure_layer_costs", "random_costs",
    "EdgeNetworkModel", "NetworkSchedule", "TPUSystemModel",
    "as_schedule", "bandwidth_shift",
    "TPU_HBM_BW", "TPU_ICI_BW_PER_LINK", "TPU_PEAK_FLOPS_BF16",
    "IterationTimeline", "PSReplanTimeline", "PSTimeline",
    "simulate_forward", "simulate_backward", "simulate_iteration",
    "simulate_ps_iteration", "simulate_ps_replan", "check_partial_orders",
]
