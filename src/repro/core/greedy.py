"""iBatch / iPart greedy scheduling (paper Algorithms 1 and 2).

The competing method the paper benchmarks against.  Implemented literally as
printed in the DynaComm paper, including its known deficiencies (the greedy
choice property does not hold, so it lands in local optima — reproducing
Fig. 5(c) where iBatch loses to plain layer-by-layer).

Where the pseudo-code is silent we resolve as follows (documented so the
§Faithful experiments are auditable):

* Alg. 1 forward — if no boundary satisfies the overlap condition, the
  remainder of the network is batched into one final segment (j = L).
  The companion algorithm that "does the opposite" (scans from the last
  layer to the first, only sketched in [16]) is implemented as the mirror
  of Alg. 1 on reversed cost vectors; iBatch returns whichever of the two
  candidates has the lower estimated time, as the paper states.
* Alg. 2 backward — if no x in [1, m-1] satisfies the condition, the x with
  maximal (least-negative) slack is chosen, i.e. the smallest next segment.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.costmodel import (LayerCosts, Segment, backward_time,
                                  forward_time)


def _fwd_candidate_literal(pt: np.ndarray, fc: np.ndarray, dt: float,
                           L: int) -> Tuple[Segment, ...]:
    """Algorithm 1, as printed (boundary list D_f, D_f[0] = 0)."""
    if L == 1:
        return ((1, 1),)
    pt_pref = np.concatenate([[0.0], np.cumsum(pt)])
    fc_pref = np.concatenate([[0.0], np.cumsum(fc)])

    def pt_sum(lo, hi):  # Σ pt_{lo..hi}, 1-indexed inclusive
        return pt_pref[hi] - pt_pref[lo - 1]

    def fc_sum(lo, hi):
        return fc_pref[hi] - fc_pref[lo - 1]

    # Lines 1-5: pick the first two boundaries (D_f[1], D_f[2]).
    s2 = [(d1, d2) for d1 in range(1, L) for d2 in range(d1 + 1, L + 1)
          if dt + pt_sum(d1 + 1, d2) >= fc_sum(1, d1)]
    if not s2:
        return ((1, L),)  # degenerate: fall back to a single batch
    max_fc = max(fc_sum(1, d1) for d1, _ in s2)
    s3 = [pair for pair in s2 if fc_sum(1, pair[0]) == max_fc]
    d1, d2 = min(s3, key=lambda pair: dt + pt_sum(1, pair[0]))

    bounds = [0, d1, d2]
    n, m = d1, d2
    # Lines 6-17 (greedy extension).  NB: the listing never re-assigns n,
    # so the compute side is the *cumulative* fc since D_f[1] — kept literal.
    while m != L:
        options = [x for x in range(m + 1, L + 1)
                   if dt + pt_sum(m + 1, x) >= fc_sum(n + 1, m)]
        if options:
            j = min(options,
                    key=lambda x: dt + pt_sum(m + 1, x) - fc_sum(n + 1, m))
        else:
            j = L
        m = j
        bounds.append(m)
    return tuple((bounds[i] + 1, bounds[i + 1]) for i in range(len(bounds) - 1))


def ibatch_forward(costs: LayerCosts) -> Tuple[Tuple[Segment, ...], float]:
    """Best of the two greedy forward candidates (paper Section III-C)."""
    L = costs.num_layers
    cand_a = _fwd_candidate_literal(costs.pt, costs.fc, costs.dt, L)
    # Mirror candidate: run the same greedy from the last layer to the first.
    mirrored = _fwd_candidate_literal(costs.pt[::-1], costs.fc[::-1],
                                      costs.dt, L)
    cand_b = tuple(sorted(((L - hi + 1, L - lo + 1) for lo, hi in mirrored)))
    best = min((cand_a, cand_b), key=lambda s: forward_time(costs, s))
    return best, forward_time(costs, best)


def ibatch_backward(costs: LayerCosts) -> Tuple[Tuple[Segment, ...], float]:
    """Algorithm 2 (iPart's greedy gradient scheduling), as printed."""
    L = costs.num_layers
    if L == 1:
        segs = ((1, 1),)
        return segs, backward_time(costs, segs)

    bc_pref = np.concatenate([[0.0], np.cumsum(costs.bc)])
    gt_pref = np.concatenate([[0.0], np.cumsum(costs.gt)])

    def bc_sum(lo, hi):
        return bc_pref[hi] - bc_pref[lo - 1] if hi >= lo else 0.0

    def gt_sum(lo, hi):
        return gt_pref[hi] - gt_pref[lo - 1] if hi >= lo else 0.0

    candidates: List[Tuple[Segment, ...]] = []
    for n in range(2, L + 1):
        bounds = [L + 1, n]   # first segment = layers L..n
        k, m = 1, n
        while m != 1:
            slack = {x: k * costs.dt_push + gt_sum(m, L) - bc_sum(x, m - 1)
                     for x in range(1, m)}
            options = [x for x, s in slack.items() if s >= 0]
            j = (min(options, key=lambda x: slack[x]) if options
                 else max(slack, key=lambda x: slack[x]))
            bounds.append(j)
            m = j
            k += 1
        segs = tuple((bounds[i + 1], bounds[i] - 1 if i else L)
                     for i in range(len(bounds) - 1))
        candidates.append(segs)

    best = min(candidates, key=lambda s: backward_time(costs, s))
    return best, backward_time(costs, best)


def ibatch_schedule(costs: LayerCosts):
    f_segs, f_t = ibatch_forward(costs)
    b_segs, b_t = ibatch_backward(costs)
    return (f_segs, b_segs), f_t + b_t
