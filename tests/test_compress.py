"""Compressed push-pull: kernels, Compressor algebra, cost-model
re-segmentation, trainer threading, and wire accounting.

Kernel tests run the Pallas path in interpret mode and assert bit-exact
agreement with the pure-jnp oracles (the production CPU path), so the
TPU kernels and the jnp math can never drift apart.  Training tests
exercise the error-feedback residuals end-to-end on the smoke CNN.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.compress import (Compressor, Int8Compressor, TopKCompressor,
                            make_compressor)
from repro.kernels.compress.ops import (TILE, aligned, densify,
                                        dequantize_unpack, quantize_pack,
                                        sparsify, topk_indices)
from repro.kernels.compress.ref import (densify_ref, dequantize_unpack_ref,
                                        quantize_pack_ref, sparsify_ref)


def _segments(lengths, seed=0):
    key = jax.random.PRNGKey(seed)
    lmax = max(lengths)
    rows = [jnp.pad(jax.random.normal(jax.random.fold_in(key, i), (n,)),
                    (0, lmax - n))
            for i, n in enumerate(lengths)]
    return jnp.stack(rows), tuple(lengths)


# ---------------------------------------------------------------------------
# kernels vs oracles (bit-exact, interpret mode)
# ---------------------------------------------------------------------------


class TestQuantizeKernels:
    @pytest.mark.parametrize("lengths", [
        (512,), (512, 1024), (2048, 512, 512, 1024), (512,) * 7,
    ])
    def test_quantize_pack_matches_ref(self, lengths):
        segs, alens = _segments(lengths)
        payload, scales = quantize_pack(segs, alens)
        payload_ref, scales_ref = quantize_pack_ref(segs, alens)
        assert payload.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(payload),
                                      np.asarray(payload_ref))
        np.testing.assert_array_equal(np.asarray(scales),
                                      np.asarray(scales_ref))
        out = dequantize_unpack(payload, scales, alens, segs.shape[1])
        out_ref = dequantize_unpack_ref(payload_ref, scales_ref, alens,
                                        segs.shape[1])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))

    def test_quantization_error_bounded_per_tile(self):
        segs, alens = _segments((1024, 512))
        out = dequantize_unpack(*quantize_pack(segs, alens), alens,
                                segs.shape[1])
        err = np.abs(np.asarray(out) - np.asarray(segs))
        tiles = np.asarray(segs).reshape(2, -1, TILE)
        absmax = np.abs(tiles).max(axis=2, keepdims=True)
        bound = np.broadcast_to(absmax / 127.0 * 0.5 + 1e-6,
                                tiles.shape).reshape(2, -1)
        assert (err <= bound).all()

    def test_zero_tile_stays_zero(self):
        segs = jnp.zeros((1, 512))
        payload, scales = quantize_pack(segs, (512,))
        out = dequantize_unpack(payload, scales, (512,), 512)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((1, 512)))

    def test_padding_rows_zeroed(self):
        """Positions past a row's aligned length decode to exact zeros."""
        segs, alens = _segments((512, 1536))
        out = dequantize_unpack(*quantize_pack(segs, alens), alens,
                                segs.shape[1])
        np.testing.assert_array_equal(np.asarray(out)[0, 512:],
                                      np.zeros(1024))

    def test_bad_inputs_raise_value_error(self):
        from repro.kernels.compress.compress import (
            dequantize_unpack_pallas, quantize_pack_pallas)
        good = jnp.ones((2, 512))
        with pytest.raises(ValueError, match="float32"):
            quantize_pack_pallas(good.astype(jnp.bfloat16), (512, 512))
        with pytest.raises(ValueError, match="multiple of"):
            quantize_pack_pallas(jnp.ones((2, 100)), (512, 512))
        with pytest.raises(ValueError, match="aligned lengths"):
            quantize_pack_pallas(good, (512,))
        with pytest.raises(ValueError, match="must be \\(K, Lmax\\)"):
            quantize_pack_pallas(jnp.ones((512,)), (512,))
        payload, scales = quantize_pack_ref(good, (512, 512))
        with pytest.raises(ValueError, match="payload"):
            dequantize_unpack_pallas(payload[:-1], scales, (512, 512), 512)
        with pytest.raises(ValueError, match="scales"):
            dequantize_unpack_pallas(payload, scales[:-1], (512, 512), 512)


class TestTopKKernels:
    @pytest.mark.parametrize("lengths,k", [
        ((512,), 5), ((512, 1024), 32), ((256, 700, 513), 17),
    ])
    def test_sparsify_densify_match_refs(self, lengths, k):
        segs, _ = _segments(lengths, seed=3)
        idx = topk_indices(segs, lengths, k)
        vals = sparsify(segs, idx)
        vals_ref = sparsify_ref(segs, idx)
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals_ref))
        dense = densify(vals, idx, segs.shape[1])
        dense_ref = densify_ref(vals_ref, idx, segs.shape[1])
        np.testing.assert_array_equal(np.asarray(dense),
                                      np.asarray(dense_ref))

    def test_topk_selects_largest_magnitudes(self):
        row = jnp.asarray([[0.1, -5.0, 0.0, 3.0, -0.2, 2.0]])
        idx = topk_indices(row, (6,), 3)
        assert sorted(np.asarray(idx)[0].tolist()) == [1, 3, 5]
        dense = densify_ref(sparsify_ref(row, idx), idx, 6)
        np.testing.assert_array_equal(
            np.asarray(dense), [[0.0, -5.0, 0.0, 3.0, 0.0, 2.0]])

    def test_topk_short_rows_pad_minus_one(self):
        """Rows with fewer valid positions than k pad indices with -1,
        which sparsify/densify treat as 'no coordinate'."""
        segs = jnp.asarray([[1.0, 2.0, 0.0, 0.0]])
        idx = topk_indices(segs, (2,), 3)
        assert np.asarray(idx)[0].tolist() == [-1, 0, 1]
        dense = densify_ref(sparsify_ref(segs, idx), idx, 4)
        np.testing.assert_array_equal(np.asarray(dense),
                                      [[1.0, 2.0, 0.0, 0.0]])

    def test_topk_tie_breaks_to_lower_index(self):
        segs = jnp.asarray([[2.0, 2.0, 2.0, 1.0]])
        idx = topk_indices(segs, (4,), 2)
        assert np.asarray(idx)[0].tolist() == [0, 1]

    def test_bad_inputs_raise_value_error(self):
        from repro.kernels.compress.compress import (densify_pallas,
                                                     sparsify_pallas)
        segs = jnp.ones((2, 16))
        idx = jnp.zeros((2, 4), jnp.int32)
        with pytest.raises(ValueError, match="out of range"):
            topk_indices(segs, (16, 16), 0)
        with pytest.raises(ValueError, match="lengths"):
            topk_indices(segs, (16,), 4)
        with pytest.raises(ValueError, match="indices must be"):
            sparsify_pallas(segs, jnp.zeros((3, 4), jnp.int32))
        with pytest.raises(ValueError, match="integer"):
            sparsify_pallas(segs, idx.astype(jnp.float32))
        with pytest.raises(ValueError, match="indices must be"):
            densify_pallas(jnp.ones((3, 4)), idx, 16)


# ---------------------------------------------------------------------------
# Compressor algebra
# ---------------------------------------------------------------------------


class TestCompressor:
    def test_int8_wire_ratio(self):
        comp = Int8Compressor()
        # 1 byte per element + one fp32 scale per TILE ⇒ just under 4x
        assert comp.ratio(4 * TILE * 64) == pytest.approx(
            4.0 / (1.0 + 4.0 / TILE), rel=1e-12)
        assert comp.ratio(4 * TILE * 64) > 3.5
        np.testing.assert_allclose(
            comp.wire_bytes(np.asarray([4.0 * TILE, 8.0 * TILE])),
            [TILE + 4.0, 2 * TILE + 8.0])

    def test_topk_wire_ratio(self):
        comp = TopKCompressor(fraction=0.05)
        n = 10_000
        assert comp.wire_bytes(4.0 * n) == 8.0 * np.ceil(0.05 * n)
        assert comp.ratio(4.0 * n) == pytest.approx(
            4.0 * n / (8.0 * np.ceil(0.05 * n)))
        assert comp.segment_overhead_bytes == 8.0

    def test_identity_compressor(self):
        comp = Compressor()
        flat = jnp.arange(8.0)
        np.testing.assert_array_equal(np.asarray(comp.roundtrip(flat)),
                                      np.asarray(flat))
        assert comp.ratio(1234.0) == 1.0

    def test_kernel_and_ref_paths_bit_identical(self):
        flat = jax.random.normal(jax.random.PRNGKey(5), (1000,))
        for scheme, kw in (("int8", {}), ("topk", {"topk_fraction": 0.1})):
            a = make_compressor(scheme, use_kernel=True, **kw).roundtrip(flat)
            b = make_compressor(scheme, use_kernel=False, **kw).roundtrip(flat)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_error_feedback_algebra_exact(self):
        """compressed + residual' == flat + residual, exactly (the
        residual is literally what the wire dropped)."""
        comp = Int8Compressor(error_feedback=True)
        flat = jax.random.normal(jax.random.PRNGKey(1), (700,))
        residual = jax.random.normal(jax.random.PRNGKey(2), (700,)) * 1e-3
        compressed, new_res = comp.feedback_roundtrip(flat, residual)
        np.testing.assert_array_equal(
            np.asarray(compressed + new_res), np.asarray(flat + residual))

    def test_make_compressor_validation(self):
        with pytest.raises(ValueError, match="unknown compression scheme"):
            make_compressor("gzip")
        with pytest.raises(ValueError, match="topk_fraction"):
            make_compressor("int8", topk_fraction=0.1)
        with pytest.raises(ValueError, match="topk_fraction"):
            make_compressor("none", topk_fraction=0.1)
        with pytest.raises(ValueError, match="requires topk_fraction"):
            make_compressor("topk")
        with pytest.raises(ValueError, match="in \\(0, 1\\]"):
            make_compressor("topk", topk_fraction=1.5)

    def test_use_kernel_auto_detects_backend(self):
        from repro._compat.pallas import default_interpret
        comp = make_compressor("int8")
        # off-TPU the auto route is the jnp math; on TPU the fused kernels
        assert comp.use_kernel == (not default_interpret())
        assert default_interpret() == (jax.default_backend() != "tpu")


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

_floats = st.floats(-100.0, 100.0)
_vec = st.integers(1, 900).flatmap(
    lambda n: st.lists(_floats, min_size=n, max_size=n))
_Lvec = lambda L: st.lists(st.floats(0.0, 100.0), min_size=L, max_size=L)
_inst = st.integers(2, 8).flatmap(
    lambda L: st.tuples(_Lvec(L), _Lvec(L), _Lvec(L), _Lvec(L),
                        st.floats(0.0, 10.0)))


class TestCompressProperties:
    @settings(max_examples=50, deadline=None)
    @given(_vec)
    def test_int8_error_within_one_quantum_of_tile_absmax(self, values):
        flat = jnp.asarray(values, jnp.float32)
        out = np.asarray(Int8Compressor().roundtrip(flat))
        n = len(values)
        tiles = np.zeros((aligned(n),), np.float32)
        tiles[:n] = np.asarray(flat)
        tiles = tiles.reshape(-1, TILE)
        absmax = np.abs(tiles).max(axis=1)
        err = np.abs(out - np.asarray(flat))
        for t in range(tiles.shape[0]):
            lo, hi = t * TILE, min((t + 1) * TILE, n)
            if hi > lo:
                # per-element error ≤ half a quantum = absmax / (2·127)
                assert err[lo:hi].max() <= absmax[t] / 127.0 * 0.51 + 1e-6

    @settings(max_examples=50, deadline=None)
    @given(_inst, st.floats(0.05, 1.0), st.floats(0.05, 1.0))
    def test_makespan_monotone_in_compression_ratio(self, tup, r1, r2):
        """A strictly better push ratio can never worsen the DP optimum
        (costs shrink pointwise, so the optimal schedule's time does
        too) — the guarantee that lets the planner trust compressed gt."""
        from repro.core import LayerCosts, dp_backward
        pt, fc, bc, gt, dt = tup
        c = LayerCosts(pt=np.array(pt), fc=np.array(fc), bc=np.array(bc),
                       gt=np.array(gt), dt=dt)
        hi, lo = max(r1, r2), min(r1, r2)
        t_hi = dp_backward(c.compressed(gt_ratio=hi)).time
        t_lo = dp_backward(c.compressed(gt_ratio=lo)).time
        assert t_lo <= t_hi + 1e-9

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 400), st.integers(1, 400))
    def test_int8_wire_bytes_monotone_and_below_fp32(self, a, b):
        comp = Int8Compressor()
        small, big = 4.0 * min(a, b), 4.0 * max(a, b)
        assert comp.wire_bytes(small) <= comp.wire_bytes(big)
        assert comp.wire_bytes(big) < big


# ---------------------------------------------------------------------------
# cost model + planning under compression
# ---------------------------------------------------------------------------


class TestCompressedPlanning:
    def _topology(self, workers=4):
        from repro.ps import PSTopology, asymmetric_link
        return PSTopology(
            num_servers=2,
            links=tuple(asymmetric_link(10e9, 0.2e9) for _ in range(workers)),
            worker_flops=(1e10,) * workers)

    def _profiles(self):
        from repro.models.cnn import PAPER_CNNS
        return PAPER_CNNS["vgg19"](batch=32)

    def test_compressed_costs_shrink_gt_only(self):
        topo = self._topology()
        plain = topo.topology_costs(self._profiles())
        comp = topo.topology_costs(self._profiles(),
                                   compressor=Int8Compressor())
        for w in range(topo.num_workers):
            assert (comp.workers[w].gt < plain.workers[w].gt).all()
            np.testing.assert_array_equal(comp.workers[w].pt,
                                          plain.workers[w].pt)
            np.testing.assert_array_equal(comp.workers[w].fc,
                                          plain.workers[w].fc)

    def test_consensus_makespan_drops_under_int8(self):
        from repro.core.scheduler import consensus_decision
        topo = self._topology()
        _, plain = consensus_decision(topo.topology_costs(self._profiles()),
                                      "dynacomm")
        _, compressed = consensus_decision(
            topo.topology_costs(self._profiles(),
                                compressor=Int8Compressor()),
            "dynacomm")
        assert compressed < plain

    def test_topk_header_lands_in_dt_bwd(self):
        topo = self._topology(workers=1)
        comp = TopKCompressor(fraction=0.01)
        costs = topo.topology_costs(self._profiles(), compressor=comp)
        plain = topo.topology_costs(self._profiles())
        link_up = topo.links[0].up
        expect = link_up.dt + link_up.transfer_time(8.0)
        assert costs.workers[0].dt_bwd == pytest.approx(expect)
        assert plain.workers[0].dt_bwd == pytest.approx(link_up.dt)

    def test_layer_costs_compressed_validation(self):
        from repro.core import LayerCosts
        c = LayerCosts(pt=np.ones(3), fc=np.ones(3), bc=np.ones(3),
                       gt=np.ones(3), dt=0.1)
        with pytest.raises(ValueError, match="gt_ratio"):
            c.compressed(gt_ratio=0.0)
        with pytest.raises(ValueError, match="pt_ratio"):
            c.compressed(pt_ratio=1.5)
        with pytest.raises(ValueError, match="dt_bwd_extra"):
            c.compressed(dt_bwd_extra=-1.0)


# ---------------------------------------------------------------------------
# trainers end-to-end (smoke CNN + reduced text arch)
# ---------------------------------------------------------------------------


def _cnn_loss(layers, batch):
    from repro.models.cnn import small_cnn_loss
    return small_cnn_loss({"layers": layers}, batch["images"],
                          batch["labels"])


def _fixed_batch(*_):
    r = np.random.default_rng(7)
    return {"images": jnp.asarray(r.normal(size=(8, 32, 32, 3)), jnp.float32),
            "labels": jnp.asarray(r.integers(0, 10, size=(8,)), jnp.int32)}


def _async_trainer(compressor, optimizer=None, workers=3, staleness=1):
    from repro.core import plan_from_decision
    from repro.models.cnn import small_cnn_init
    from repro.optim import sgd
    from repro.ps import AsyncPSTrainer, PSTopology, asymmetric_link
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)
    topo = PSTopology(
        num_servers=2,
        links=tuple(asymmetric_link(10e9, 1e9) for _ in range(workers)),
        worker_flops=(1e10,) * workers)
    return AsyncPSTrainer(init_layers=params["layers"], loss_fn=_cnn_loss,
                          optimizer=optimizer or sgd(0.02), topology=topo,
                          plan=plan, staleness=staleness,
                          compressor=compressor)


class TestCompressedAsyncTraining:
    def test_int8_ef_final_loss_within_2pct_of_fp32(self):
        base = _async_trainer(None).run(30, _fixed_batch).losses
        i8 = _async_trainer(make_compressor("int8")).run(
            30, _fixed_batch).losses
        assert base[-1] < base[0] * 0.55          # both actually train
        assert abs(i8[-1] - base[-1]) <= 0.02 * abs(base[-1])

    def test_topk_ef_converges(self):
        tr = _async_trainer(make_compressor("topk", topk_fraction=0.1))
        losses = tr.run(30, _fixed_batch).losses
        assert losses[-1] < losses[0] * 0.75

    def test_push_wire_ratio_exceeds_3_5x_at_int8(self):
        tr = _async_trainer(make_compressor("int8"))
        tr.run(12, _fixed_batch)
        led = tr.server.ledger
        assert led.compression_ratio("push") > 3.5
        # pulls stay fp32
        assert led.compression_ratio("pull") == pytest.approx(1.0)
        assert sum(led.pushed_wire_bytes.values()) < \
            sum(led.pushed_bytes.values())

    def test_scheme_none_is_normalized_away(self):
        tr = _async_trainer(make_compressor("none"))
        assert tr.compressor is None
        assert tr.server.compressor is None

    def test_residuals_reset_with_loop(self):
        tr = _async_trainer(make_compressor("int8"))
        tr.run(6, _fixed_batch)
        assert tr._residuals
        tr.reset_loop()
        assert not tr._residuals

    def test_dynamic_async_replans_with_compressed_costs(self):
        from repro.models.cnn import small_cnn_init
        from repro.optim import sgd
        from repro.ps import DynamicAsyncPSTrainer, PSTopology, \
            asymmetric_link, uplink_degradation
        params = small_cnn_init(jax.random.PRNGKey(0))
        topo = uplink_degradation(
            PSTopology(num_servers=2,
                       links=tuple(asymmetric_link(10e9, 1e9)
                                   for _ in range(3)),
                       worker_flops=(1e10,) * 3),
            factor=4.0, at_epoch=1)
        tr = DynamicAsyncPSTrainer(
            init_layers=params["layers"], loss_fn=_cnn_loss,
            optimizer=sgd(0.02), topology=topo, pushes_per_epoch=4,
            staleness=1, compressor=make_compressor("int8"))
        log = tr.run_pushes(8, _fixed_batch)
        assert len(log.accepted) == 8
        assert tr.compressor is not None
        # every epoch's planning costs carry the compressed gt
        c0 = tr.costs_for_epoch(0)
        plain = topo.topology_at(0).topology_costs(tr._profiles)
        assert (c0.workers[0].gt < plain.workers[0].gt).all()


class TestCompressedSyncTraining:
    def _trainer(self, compressor):
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.core.buckets import BucketPlan
        from repro.models import num_sched_layers
        from repro.optim import sgd
        from repro.ps import PSTopology, PSTrainer
        cfg = get_config("granite-3-2b").reduced()
        Ls = num_sched_layers(cfg)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        plan = BucketPlan(forward=(tuple(range(Ls)),),
                          backward=(tuple(range(Ls - 1, -1, -1)),))
        return cfg, PSTrainer(cfg=cfg, mesh=mesh, plan=plan,
                              optimizer=sgd(0.05),
                              topology=PSTopology.uniform(2, 1),
                              compressor=compressor)

    def _batch(self, cfg):
        key = jax.random.PRNGKey(3)
        toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    def test_int8_ef_state_carries_residuals_and_trains(self):
        cfg, tr = self._trainer(make_compressor("int8"))
        state = tr.init_state(jax.random.PRNGKey(0))
        assert "residuals" in state
        assert len(state["residuals"]) == tr.num_layers
        for l, spec in enumerate(tr.specs):
            assert state["residuals"][l].shape == (1, spec.padded)
        step = jax.jit(tr.build_train_step())
        batch = self._batch(cfg)
        losses = []
        for _ in range(4):
            state, loss = step(state, batch)
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        # residuals are live: after a step they hold quantization error
        assert float(jnp.abs(state["residuals"][0]).max()) > 0

    def test_no_error_feedback_keeps_state_shape(self):
        _, tr = self._trainer(make_compressor("int8", error_feedback=False))
        state = tr.init_state(jax.random.PRNGKey(0))
        assert "residuals" not in state

    def test_wire_byte_views(self):
        _, tr = self._trainer(make_compressor("int8"))
        logical = tr.transfer_bytes()
        wire = tr.transfer_wire_bytes()
        assert wire["pull"] == logical["pull"]
        assert 3.5 < logical["push"] / wire["push"] < 4.0

    def test_from_topology_plans_with_compressed_costs(self):
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.optim import sgd
        from repro.ps import PSTopology, PSTrainer, asymmetric_link
        cfg = get_config("granite-3-2b").reduced()
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        topo = PSTopology(num_servers=2,
                          links=(asymmetric_link(10e9, 0.05e9),),
                          worker_flops=(1e10,))
        shape = InputShape("t", 16, 4, "train")
        tr = PSTrainer.from_topology(cfg, mesh, topo, sgd(0.05), shape,
                                     compressor=make_compressor("int8"))
        assert tr.compressor is not None
        costs = tr.topology_costs(shape)
        plain = topo.topology_costs(
            __import__("repro.models.profiles",
                       fromlist=["layer_profiles"]).layer_profiles(cfg, shape))
        assert (costs.workers[0].gt < plain.workers[0].gt).all()


# ---------------------------------------------------------------------------
# TransferLedger wire accounting
# ---------------------------------------------------------------------------


class TestLedgerWireAccounting:
    def _ledger(self):
        from repro.ps.server import TransferLedger
        return TransferLedger()

    def test_wire_defaults_to_logical(self):
        led = self._ledger()
        led.record_push(0, 1000)
        led.record_pull(0, 500)
        assert led.pushed_wire_bytes[0] == 1000
        assert led.pulled_wire_bytes[0] == 500
        assert led.compression_ratio("push") == 1.0

    def test_per_worker_and_direction_ratios(self):
        led = self._ledger()
        led.record_push(0, 1000, wire_bytes=250)
        led.record_push(1, 1000, wire_bytes=500)
        led.record_pull(0, 1000, wire_bytes=1000)
        assert led.compression_ratio("push", worker=0) == 4.0
        assert led.compression_ratio("push", worker=1) == 2.0
        assert led.compression_ratio("push") == pytest.approx(2000 / 750)
        assert led.compression_ratio("pull") == 1.0

    def test_empty_ledger_ratio_is_one(self):
        assert self._ledger().compression_ratio("push") == 1.0

    def test_bad_direction_raises(self):
        with pytest.raises(ValueError, match="direction"):
            self._ledger().compression_ratio("sideways")


# ---------------------------------------------------------------------------
# runtime config + launcher threading
# ---------------------------------------------------------------------------


class TestCompressionConfig:
    def test_validation(self):
        from repro.runtime import CompressionConfig
        with pytest.raises(ValueError, match="unknown compression scheme"):
            CompressionConfig(scheme="gzip")
        with pytest.raises(ValueError, match="topk_fraction"):
            CompressionConfig(scheme="topk")
        with pytest.raises(ValueError, match="in \\(0, 1\\]"):
            CompressionConfig(scheme="topk", topk_fraction=2.0)
        with pytest.raises(ValueError, match="topk_fraction"):
            CompressionConfig(scheme="int8", topk_fraction=0.1)

    def test_build(self):
        from repro.runtime import CompressionConfig
        assert CompressionConfig().build() is None
        comp = CompressionConfig(scheme="topk", topk_fraction=0.05,
                                 error_feedback=False).build()
        assert comp.scheme == "topk"
        assert comp.fraction == 0.05
        assert comp.error_feedback is False

    def test_json_round_trip(self):
        from repro.runtime import (CompressionConfig, RuntimeConfig,
                                   ScheduleConfig, TopologyConfig)
        cfg = RuntimeConfig(
            runtime="ps",
            schedule=ScheduleConfig(topology=TopologyConfig()),
            compression=CompressionConfig(scheme="int8"))
        assert RuntimeConfig.from_json(cfg.to_json()) == cfg
        assert cfg.compression.enabled

    def test_compression_rejected_on_non_ps_runtimes(self):
        from repro.runtime import CompressionConfig, RuntimeConfig
        with pytest.raises(ValueError, match="ps-\\*"):
            RuntimeConfig(runtime="zero",
                          compression=CompressionConfig(scheme="int8"))
        with pytest.raises(ValueError, match="ps-\\*"):
            RuntimeConfig(runtime="local",
                          compression=CompressionConfig(scheme="int8"))

    def test_launcher_flags_map_to_config(self):
        import argparse
        from repro.launch.train import config_from_flags
        args = argparse.Namespace(
            runtime="ps", staleness=1, arch="granite-3-2b", reduced=True,
            batch=4, seq=16, optimizer="adamw", lr=3e-4,
            strategy="dynacomm", steps_per_epoch=20, drift_detect=False,
            async_planning=False, plan_cache_size=256,
            bw_gbps=10.0, bw_shift_gbps=None, shift_epoch=1,
            cost_source="analytic", ps_servers=2, ps_workers=3,
            down_gbps=10.0, up_gbps=1.0, up_shift_gbps=None,
            worker_flops=1e10, throttle="reject", aggregate=False,
            compress="topk", topk_fraction=0.02, no_error_feedback=True,
            fleet_schedule=None, workers_per_shard=0)
        cfg = config_from_flags(args)
        assert cfg.runtime == "ps-async"        # staleness upgrades
        assert cfg.compression.scheme == "topk"
        assert cfg.compression.topk_fraction == 0.02
        assert cfg.compression.error_feedback is False
        args.compress = "int8"
        cfg = config_from_flags(args)
        assert cfg.compression.scheme == "int8"
        assert cfg.compression.topk_fraction is None


# ---------------------------------------------------------------------------
# fit() eval hook
# ---------------------------------------------------------------------------


class TestEvalHook:
    def test_eval_every_validation(self):
        from repro.runtime.adapters import RuntimeAdapter
        with pytest.raises(ValueError, match="eval_every"):
            RuntimeAdapter._check_eval(lambda: 0.0, 0)
        RuntimeAdapter._check_eval(None, 0)     # no eval_fn: fine

    def test_sync_runtime_records_eval_events(self):
        from repro.configs import get_config
        from repro.runtime import EvalEvent, RuntimeConfig, build_runtime
        cfg = RuntimeConfig(runtime="local", reduced=True, batch=2, seq=16)
        vocab = get_config(cfg.arch).reduced().vocab_size

        def batch_fn(i):
            r = np.random.default_rng(i)
            toks = jnp.asarray(r.integers(0, vocab, (2, 16)), jnp.int32)
            return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

        rt = build_runtime(cfg, data=batch_fn)
        evals = []
        rt.fit(4, eval_fn=lambda: evals.append(1) or 0.25, eval_every=2)
        events = [e for e in rt.events if isinstance(e, EvalEvent)]
        assert len(events) == len(evals) == 2
        assert [e.unit for e in events] == [2, 4]
        assert all(e.loss == 0.25 for e in events)
