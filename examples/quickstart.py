"""Quickstart: schedule a model's communications with DynaComm.

Profiles a reduced granite-3-2b analytically, runs every strategy, prints
the decisions and the predicted iteration times, and shows the timeline
breakdown — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config
from repro.core import (EdgeNetworkModel, costs_from_profiles, evaluate,
                        schedule, simulate_iteration)
from repro.models.profiles import layer_profiles


def main():
    cfg = get_config("granite-3-2b")
    shape = INPUT_SHAPES["train_4k"]

    # analytic per-layer profile → cost vectors under an edge network
    profiles = layer_profiles(cfg, shape, param_dtype=jnp.float32)
    costs = costs_from_profiles(
        profiles,
        net=EdgeNetworkModel(bandwidth_bps=2e9),    # 2 Gbps edge uplink
        compute_flops_per_s=5e12,                   # edge accelerator
    )
    print(f"model: {cfg.name}  sched-layers: {costs.num_layers}  "
          f"Δt: {costs.dt * 1e3:.1f} ms")

    for strategy in ("sequential", "lbl", "ibatch", "dynacomm"):
        decision = schedule(costs, strategy)
        times = evaluate(costs, decision)
        fwd, bwd = decision
        print(f"{strategy:10s}  fwd buckets {len(fwd):3d}  "
              f"bwd buckets {len(bwd):3d}  iteration {times['total']:.3f}s")

    # timeline breakdown for the optimal schedule (paper Figs. 5-8 bars)
    fwd, bwd = schedule(costs, "dynacomm")
    tl = simulate_iteration(costs, fwd, bwd)
    for phase in ("forward", "backward"):
        br = tl.breakdown(phase)
        print(f"{phase:8s}: compute-only {br.comp_only:.3f}s  "
              f"overlap {br.overlap:.3f}s  comm-only {br.comm_only:.3f}s")

    # and the Gantt view (paper Fig. 2/3)
    from repro.core.viz import render_timeline
    for strategy in ("sequential", "dynacomm"):
        f, _ = schedule(costs, strategy)
        print(f"\n[{strategy}]")
        print(render_timeline(costs, f, phase="forward"))


if __name__ == "__main__":
    main()
