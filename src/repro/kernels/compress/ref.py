"""Pure-jnp oracles for the compression kernels.

Each oracle performs exactly the per-tile / per-row math of its Pallas
kernel on the same partitioning, so interpret-mode kernel outputs must
match bit-for-bit (``np.testing.assert_array_equal``).
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

from repro.kernels.bucket_pack.bucket_pack import TILE


def quantize_pack_ref(segments: jnp.ndarray,
                      aligned_lengths: Sequence[int]
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(K, Lmax) f32 segments → (int8 payload, per-TILE f32 scales).

    Per tile: scale = absmax / 127, q = round(x * 127 / absmax); an
    all-zero tile quantizes to zeros with scale 0.
    """
    qs, scales = [], []
    for k, n in enumerate(aligned_lengths):
        tiles = segments[k, :n].reshape(-1, TILE)
        absmax = jnp.max(jnp.abs(tiles), axis=1)
        inv = jnp.where(absmax > 0, 127.0 / absmax, 0.0)
        qs.append(jnp.round(tiles * inv[:, None]).astype(jnp.int8).reshape(-1))
        scales.append(absmax / 127.0)
    return jnp.concatenate(qs), jnp.concatenate(scales)


def dequantize_unpack_ref(payload: jnp.ndarray, scales: jnp.ndarray,
                          aligned_lengths: Sequence[int],
                          lmax: int) -> jnp.ndarray:
    """(int8 payload, scales) → (K, Lmax) f32, zero-padded past lengths."""
    rows = []
    off = toff = 0
    for n in aligned_lengths:
        tiles = payload[off:off + n].reshape(-1, TILE).astype(jnp.float32)
        s = scales[toff:toff + n // TILE]
        row = (tiles * s[:, None]).reshape(-1)
        rows.append(jnp.pad(row, (0, lmax - n)))
        off += n
        toff += n // TILE
    return jnp.stack(rows)


def sparsify_ref(segments: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """Gather values at per-row ``indices``; -1 index slots yield 0."""
    gathered = jnp.take_along_axis(segments, jnp.maximum(indices, 0), axis=1)
    return jnp.where(indices >= 0, gathered, 0.0).astype(segments.dtype)


def densify_ref(values: jnp.ndarray, indices: jnp.ndarray,
                lmax: int) -> jnp.ndarray:
    """Scatter (values, indices) back to dense (K, Lmax); -1 slots drop."""
    k_count = values.shape[0]
    vals = jnp.where(indices >= 0, values, 0.0)
    out = jnp.zeros((k_count, lmax), values.dtype)
    rows = jnp.arange(k_count)[:, None]
    return out.at[rows, jnp.maximum(indices, 0)].add(vals)
