"""Typed findings emitted by the ``repro.analysis`` passes.

Every analyzer — the HLO schedule-conformance pass and the AST lints —
reports problems as :class:`Finding` records so the CLI, tests and CI
share one serialization (JSON) and one human rendering.  A finding is
identified by a short stable ``code`` (catalogued in the README) plus a
free-form message; ``path``/``line`` locate it when it maps to source.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = ["Finding", "findings_to_json", "render_findings"]

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One problem located by an analysis pass.

    ``code`` is a stable machine-readable identifier (e.g.
    ``SCHED-AG-COUNT``, ``DET-RANDOM``); ``detail`` carries
    pass-specific JSON-serializable context (expected/actual values,
    operand names, ...).
    """

    code: str
    message: str
    severity: str = ERROR
    path: Optional[str] = None
    line: Optional[int] = None
    detail: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "code": self.code,
            "severity": self.severity,
            "message": self.message,
        }
        if self.path is not None:
            d["path"] = self.path
        if self.line is not None:
            d["line"] = self.line
        if self.detail:
            d["detail"] = dict(self.detail)
        return d

    def format(self) -> str:
        loc = ""
        if self.path is not None:
            loc = f"{self.path}:{self.line}: " if self.line else f"{self.path}: "
        return f"{loc}{self.severity}[{self.code}] {self.message}"


def findings_to_json(findings: Iterable[Finding], **extra: Any) -> str:
    """Serialize findings (plus top-level metadata) to a JSON document."""
    fs: List[Finding] = list(findings)
    doc: Dict[str, Any] = {
        "findings": [f.to_dict() for f in fs],
        "num_findings": len(fs),
        "num_errors": sum(1 for f in fs if f.severity == ERROR),
    }
    doc.update(extra)
    return json.dumps(doc, indent=2, sort_keys=True)


def render_findings(findings: Iterable[Finding],
                    header: Optional[str] = None) -> str:
    """Human-readable multi-line rendering; empty-finding sets say so."""
    fs = list(findings)
    lines: List[str] = []
    if header:
        lines.append(header)
    if not fs:
        lines.append("no findings")
    lines.extend(f.format() for f in fs)
    return "\n".join(lines)
