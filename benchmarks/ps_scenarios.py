"""Parameter-server benchmark scenarios (beyond-paper).

Three benches:

* ``ps_topology`` — DynaComm vs competing strategies in the PS regime:
  the paper's CNN cost tables mapped onto a heterogeneous S×W topology
  (per-worker compute rates, asymmetric per-link bandwidth), comparing
  the synchronous straggler makespan of each strategy's consensus plan
  and the per-worker async plan times — the scenario space the symmetric
  cluster regime (Figs. 5-8) cannot express.
* ``ps_staleness`` — the sync-vs-async trade: simulated time to apply N
  gradient pushes on the smoke CNN as the staleness bound k grows
  (k=0 serializes; larger k reclaims barrier-wait time at the price of
  stale-gradient rejections), under both throttle disciplines — the
  ``wait`` rows show SSP wait-at-barrier keeping every worker
  contributing at small k where ``reject`` starves the slow one.
* ``dynamic_ps_drift`` — the run-time loop's payoff in the PS regime:
  per-epoch uplink degradation over the paper's CNN cost tables,
  comparing each epoch's re-planned consensus makespan against freezing
  the epoch-0 plan (the stale-plan penalty ``DynamicPSTrainer`` exists
  to reclaim), plus the Table I scheduling-overhead-hidden check.
  CI publishes this bench as ``BENCH_dynamic_ps.json``.
"""

from __future__ import annotations

from typing import Dict, List

from benchmarks.edge_setup import cnn_costs
from repro.core import (Planner, consensus_decision, iteration_time,
                        schedule_topology, simulate_ps_iteration,
                        simulate_ps_replan)
from repro.core.costmodel import TopologyCosts, LayerCosts
from repro.core.scheduler import TopologyScheduler

MODELS = ("vgg19", "googlenet", "inception-v4", "resnet152")
STRATS = ("sequential", "lbl", "ibatch", "dynacomm")


def _hetero_topology_costs(base: LayerCosts, num_workers: int = 4
                           ) -> TopologyCosts:
    """Half the fleet at 1/4 compute behind a 4x-slower asymmetric uplink."""
    workers = []
    for w in range(num_workers):
        slow = w >= num_workers // 2
        comp = 4.0 if slow else 1.0
        comm = 4.0 if slow else 1.0
        c = base.scaled(compute=comp, comm=comm)
        # uplink (push) is 8x the downlink cost for the slow half: gradient
        # pushes dominate, the asymmetric-Δt path is exercised
        workers.append(LayerCosts(pt=c.pt, fc=c.fc, bc=c.bc,
                                  gt=c.gt * 2.0, dt=c.dt,
                                  dt_bwd=c.dt * 1.5))
    return TopologyCosts(workers=tuple(workers))


def ps_topology() -> List[Dict]:
    """Sync makespan + async per-worker times per strategy and model."""
    rows = []
    for model in MODELS:
        topo = _hetero_topology_costs(cnn_costs(model, batch=32))
        seq_makespan = None
        for strat in STRATS:
            decision, makespan = consensus_decision(topo, strat)
            if strat == "sequential":
                seq_makespan = makespan
            tl = simulate_ps_iteration(topo, decision)
            per_worker = schedule_topology(topo, strat)
            async_times = [iteration_time(c, *d)
                           for c, d in zip(topo.workers, per_worker)]
            rows.append({
                "model": model, "strategy": strat,
                "workers": topo.num_workers,
                "fwd_segments": len(decision[0]),
                "bwd_segments": len(decision[1]),
                "sync_makespan_s": round(makespan, 4),
                "straggler": tl.straggler,
                "barrier_wait_mean_s": round(
                    sum(tl.barrier_waits) / tl.num_workers, 4),
                "async_mean_iter_s": round(
                    sum(async_times) / len(async_times), 4),
                "reduced_vs_sequential_pct": round(
                    100 * (1 - makespan / seq_makespan), 2),
            })
    return rows


def ps_staleness() -> List[Dict]:
    """Simulated seconds per accepted push vs the staleness bound k."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import plan_from_decision
    from repro.models.cnn import small_cnn_init, small_cnn_loss
    from repro.optim import sgd
    from repro.ps import AsyncPSTrainer, PSTopology, asymmetric_link

    links = tuple(asymmetric_link(10e9, 1e9) for _ in range(3))
    topo = PSTopology(num_servers=2, links=links,
                      worker_flops=(1e10, 1e10, 5e9))
    params = small_cnn_init(jax.random.PRNGKey(0))
    L = len(params["layers"])
    plan = plan_from_decision(((1, 3), (4, L)), ((4, L), (1, 3)), L)

    def loss_fn(layers, batch):
        return small_cnn_loss({"layers": layers}, batch["images"],
                              batch["labels"])

    def batch_fn(w, i):
        r = np.random.default_rng(100003 * w + i)
        return {"images": jnp.asarray(r.normal(size=(8, 32, 32, 3)),
                                      jnp.float32),
                "labels": jnp.asarray(r.integers(0, 10, size=(8,)),
                                      jnp.int32)}

    rows = []
    pushes = 24
    # ("wait", True) is the BSP aggregation mode: same-version pushes
    # commit as one mean-gradient step.  Under aggregation every worker
    # is admitted in full-fleet cohorts at the head version, so k is
    # inert — one k=0 row, not a fake sweep.
    for throttle, aggregate in (("reject", False), ("wait", False),
                                ("wait", True)):
        for k in ((0,) if aggregate else (0, 1, 2, 4)):
            tr = AsyncPSTrainer(init_layers=params["layers"],
                                loss_fn=loss_fn, optimizer=sgd(0.02),
                                topology=topo, plan=plan, staleness=k,
                                throttle=throttle, aggregate=aggregate)
            log = tr.run(pushes, batch_fn)
            slow_accepted = log.accepted_by_worker().get(2, 0)
            rows.append({
                "throttle": f"{throttle}+agg" if aggregate else throttle,
                "staleness_k": k, "accepted": len(log.accepted),
                "rejected": log.num_rejected,
                "slow_worker_accepted": slow_accepted,
                "max_staleness": log.max_staleness,
                "optimizer_steps": max(e.result.version
                                       for e in log.accepted),
                "barrier_wait_s": round(log.total_wait_s, 4),
                "sim_makespan_s": round(log.makespan, 4),
                "sim_s_per_push": round(log.makespan / pushes, 4),
                "final_loss": round(log.losses[-1], 4),
            })
    return rows


def dynamic_ps_drift() -> List[Dict]:
    """Stale-plan penalty per epoch under uplink degradation.

    Four heterogeneous workers (the ``ps_topology`` fleet); each epoch
    multiplies every worker's gradient-push costs (uplink congestion
    building up 1x → 8x), the consensus plan is re-derived per epoch, and
    ``simulate_ps_replan`` compares it against freezing the epoch-0 plan.
    """
    drift = (1.0, 2.0, 4.0, 8.0)          # uplink slowdown per epoch
    rows = []
    for model in MODELS:
        base = _hetero_topology_costs(cnn_costs(model, batch=32))
        epoch_costs = [
            TopologyCosts(workers=tuple(
                LayerCosts(pt=c.pt, fc=c.fc, bc=c.bc, gt=c.gt * s,
                           dt=c.dt, dt_bwd=c.dt_push)
                for c in base.workers))
            for s in drift]
        sched = TopologyScheduler(strategy="dynacomm", reschedule_every=1,
                                  planner=Planner())
        decisions, hidden, sched_ms = [], [], []
        for costs in epoch_costs:
            # reschedule_every=1: every call re-plans against fresh costs
            decisions.append(sched.decision_for_iteration(costs))
            hidden.append(sched.scheduling_overhead_hidden(costs))
            sched_ms.append(sched.last_scheduling_seconds * 1e3)
        # Second sweep over the same knots — a piecewise-constant
        # ``TopologySchedule`` cycling back to earlier conditions.  With
        # the content-keyed planner every re-plan is a dictionary hit:
        # this is the scheduling-seconds-per-replan "after" column next
        # to the cold "before" above.
        revisit_ms, revisit_decisions = [], []
        for costs in epoch_costs:
            sched.invalidate()
            revisit_decisions.append(sched.decision_for_iteration(costs))
            revisit_ms.append(sched.last_scheduling_seconds * 1e3)
        assert revisit_decisions == decisions   # memoization is exact
        stats = sched.planner.stats
        tl = simulate_ps_replan(epoch_costs, decisions)
        for e, scale in enumerate(drift):
            penalty = tl.stale_plan_penalty(e)
            rows.append({
                "model": model, "epoch": e, "uplink_slowdown": scale,
                "fwd_segments": len(decisions[e][0]),
                "bwd_segments": len(decisions[e][1]),
                "replanned_makespan_s": round(tl.makespans[e], 4),
                "frozen_plan_makespan_s": round(tl.frozen_makespans[e], 4),
                "stale_plan_penalty_s": round(penalty, 4),
                "stale_plan_penalty_pct": round(
                    100 * penalty / tl.frozen_makespans[e], 2),
                "sched_ms": round(sched_ms[e], 3),
                "revisit_sched_ms": round(revisit_ms[e], 3),
                "sched_speedup_on_revisit": round(
                    sched_ms[e] / max(revisit_ms[e], 1e-6), 1),
                "plan_cache_hit_rate": round(stats.hit_rate, 4),
                "overhead_hidden": hidden[e],
            })
    return rows


def runtime_matrix() -> List[Dict]:
    """Every registered runtime, built from its checked-in smoke config
    through ``repro.runtime.build_runtime`` and driven for a few units —
    the registry-as-benchmark view: adding a regime is one config file,
    and this bench (plus CI's smoke step) picks it up with zero wiring."""
    import glob
    import os

    from repro.runtime import RuntimeConfig, build_runtime

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    for path in sorted(glob.glob(
            os.path.join(here, "examples", "runtime_configs", "*.json"))):
        config = RuntimeConfig.load(path)
        rt = build_runtime(config)
        losses = rt.fit(4)
        led = rt.ledger
        rows.append({
            "runtime": config.runtime,
            "regime": config.regime,
            "units": len(losses),
            "first_loss": round(losses[0], 4),
            "final_loss": round(losses[-1], 4),
            "reschedules": len(rt.events),
            "pull_mb": round(led["pull_bytes"] / 1e6, 2),
            "push_mb": round(led["push_bytes"] / 1e6, 2),
        })
    return rows


PS_BENCHES = {
    "ps_topology": ps_topology,
    "ps_staleness": ps_staleness,
    "dynamic_ps_drift": dynamic_ps_drift,
    "runtime_matrix": runtime_matrix,
}
