"""Deterministic synthetic data pipelines.

Streams are a pure function of (seed, step), so every worker/process can
re-derive its shard without coordination, resumption after checkpoint
restore is exact, and the with/without-DynaComm accuracy experiment sees
bit-identical batches.

Text batches model a Zipf-ish token distribution with a learnable
next-token structure (labels = tokens shifted with a deterministic
permutation applied) so small models actually descend.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import frontend


@dataclasses.dataclass(frozen=True)
class SyntheticText:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # zipf-ish marginal over the vocab
        ranks = np.arange(1, self.vocab_size + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(self.vocab_size, size=(self.batch_size, self.seq_len),
                          p=probs).astype(np.int32)
        # learnable structure: label_t = perm[token_t]
        perm = np.random.default_rng(self.seed).permutation(self.vocab_size)
        labels = perm[toks].astype(np.int32)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class SyntheticCIFAR:
    batch_size: int
    num_classes: int = 10
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        labels = rng.integers(0, self.num_classes,
                              size=(self.batch_size,)).astype(np.int32)
        # class-conditional means => learnable
        base = rng.standard_normal((self.batch_size, 32, 32, 3)) * 0.3
        means = np.linspace(-1, 1, self.num_classes)[labels]
        images = (base + means[:, None, None, None]).astype(np.float32)
        return {"images": jnp.asarray(images), "labels": jnp.asarray(labels)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def batch_for(cfg: ArchConfig, shape: InputShape, *, step: int = 0,
              seed: int = 0) -> Dict[str, jnp.ndarray]:
    """A concrete (allocated) batch matching ``launch.dryrun.input_specs``.

    Only safe for reduced configs / small shapes on CPU — full shapes go
    through ShapeDtypeStructs in the dry-run instead.
    """
    b, t = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        return {"frames": frontend.audio_frames(cfg, b, t, seed=seed),
                "labels": jnp.zeros((b, t), jnp.int32)}
    if cfg.frontend == "vision":
        nv = min(cfg.num_vision_tokens, t - 1)
        text = SyntheticText(cfg.vocab_size, t - nv, b, seed).batch(step)
        return {"tokens": text["tokens"],
                "vision_embeds": frontend.vision_embeddings(cfg, b, seed=seed)[:, :nv],
                "labels": text["labels"]}
    return SyntheticText(cfg.vocab_size, t, b, seed).batch(step)


def make_pipeline(cfg: ArchConfig, shape: InputShape, seed: int = 0):
    if cfg.frontend == "none":
        return SyntheticText(cfg.vocab_size, shape.seq_len,
                             shape.global_batch, seed)
    raise ValueError("streaming pipeline implemented for text archs; "
                     "use batch_for() for stubbed modalities")
